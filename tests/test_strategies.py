"""FL strategy algebra tests — the paper's equations hold exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import FedConfig
from repro.core import tree as T
from repro.core.strategies import get_strategy


def quad_grad(target):
    """grad of 1/2‖θ − target‖² (θ-dependent, well-behaved)."""
    def grad_fn(theta, _):
        g = jax.tree.map(lambda t, tt: t - tt, theta, target)
        return g, jnp.zeros(())
    return grad_fn


def const_grad(gval):
    def grad_fn(theta, _):
        return jax.tree.map(lambda g: g, gval), jnp.zeros(())
    return grad_fn


def run_round(strategy_name, fed, theta, grad_fn, server_state=None,
              n_clients=3):
    s = get_strategy(strategy_name)
    server_state = server_state if server_state is not None \
        else s.server_init(theta)
    ctx = s.client_setup(server_state, theta, fed)
    deltas = []
    for i in range(n_clients):
        th = theta
        extra = s.init_extra(theta, fed)
        for tau in range(fed.local_steps):
            th, extra, _ = s.local_step(th, ctx, grad_fn, None, fed, extra)
        deltas.append(T.sub(theta, th))
    mean_delta = jax.tree.map(lambda *ds: sum(ds) / len(ds), *deltas)
    new_theta, new_state = s.server_update(server_state, theta, mean_delta,
                                           fed)
    return new_theta, new_state, mean_delta


def make_theta(seed=0):
    k = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(k)
    return {"w": jax.random.normal(k1, (4, 3)),
            "b": jax.random.normal(k2, (3,))}


class TestFedADCAlgebra:
    def test_eq4_delta_decomposition(self):
        """Eq. (4): Δ = η(Σ_τ g_τ + β_local·m) for the heavy-ball variant."""
        fed = FedConfig(strategy="fedadc", variant="heavyball",
                        local_steps=5, eta=0.07, beta_local=0.6,
                        beta_global=0.6)
        theta = make_theta()
        m = jax.tree.map(lambda x: x * 0.3 + 0.1, theta)
        g = jax.tree.map(lambda x: x * 0.05 - 0.02, theta)  # constant grads
        s = get_strategy("fedadc")
        ctx = s.client_setup({"m": m}, theta, fed)
        th, extra = theta, s.init_extra(theta, fed)
        for _ in range(fed.local_steps):
            th, extra, _ = s.local_step(th, ctx, const_grad(g), None, fed,
                                        extra)
        delta = T.sub(theta, th)
        expect = jax.tree.map(
            lambda gi, mi: fed.eta * (fed.local_steps * gi
                                      + fed.beta_local * mi), g, m)
        for a, b in zip(jax.tree.leaves(delta), jax.tree.leaves(expect)):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)

    def test_eq5_server_momentum_matches_slowmo_form(self):
        """After the (β_g − β_l)m correction, the pseudo momentum equals the
        SlowMo recursion β·m + ḡ on constant gradients (Sec. II)."""
        fed = FedConfig(strategy="fedadc", variant="heavyball", local_steps=4,
                        eta=0.05, beta_local=0.8, beta_global=0.8, alpha=1.0)
        theta = make_theta(1)
        g = jax.tree.map(lambda x: jnp.ones_like(x) * 0.1, theta)
        m0 = jax.tree.map(lambda x: jnp.ones_like(x) * 0.5, theta)
        _, new_state, _ = run_round("fedadc", fed, theta, const_grad(g),
                                    {"m": m0})
        # SlowMo form: m' = β·m + Σ_τ g  (ḡ = Δ/η with H local steps)
        expect = jax.tree.map(
            lambda mi, gi: fed.beta_global * mi + fed.local_steps * gi,
            m0, g)
        for a, b in zip(jax.tree.leaves(new_state["m"]),
                        jax.tree.leaves(expect)):
            np.testing.assert_allclose(a, b, rtol=1e-5)

    def test_fedadc_beta0_equals_fedavg(self):
        """β_local = β_global = 0, α = 1 ⇒ FedADC degenerates to FedAvg."""
        fed0 = FedConfig(strategy="fedadc", variant="heavyball",
                         local_steps=3, eta=0.1, beta_local=0.0,
                         beta_global=0.0, alpha=1.0)
        fedavg = FedConfig(strategy="fedavg", local_steps=3, eta=0.1)
        theta = make_theta(2)
        target = jax.tree.map(jnp.zeros_like, theta)
        t1, _, _ = run_round("fedadc", fed0, theta, quad_grad(target))
        t2, _, _ = run_round("fedavg", fedavg, theta, quad_grad(target))
        for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)):
            np.testing.assert_allclose(a, b, rtol=1e-6)

    def test_nesterov_vs_heavyball_same_delta_on_constant_grads(self):
        """With θ-independent gradients the red/blue variants coincide."""
        theta = make_theta(3)
        g = jax.tree.map(lambda x: x * 0.02, theta)
        outs = []
        for variant in ("nesterov", "heavyball"):
            fed = FedConfig(strategy="fedadc", variant=variant,
                            local_steps=4, eta=0.05, beta_local=0.7,
                            beta_global=0.7)
            m = jax.tree.map(jnp.ones_like, theta)
            s = get_strategy("fedadc")
            ctx = s.client_setup({"m": m}, theta, fed)
            th, extra = theta, s.init_extra(theta, fed)
            for _ in range(4):
                th, extra, _ = s.local_step(th, ctx, const_grad(g), None,
                                            fed, extra)
            outs.append(th)
        for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])):
            np.testing.assert_allclose(a, b, rtol=1e-5)

    def test_momentum_controls_drift(self):
        """The paper's drift-control claim, miniaturised: two clients with
        opposite targets.  The FedADC momentum term must shrink the spread
        of the local models relative to FedAvg."""
        fed = FedConfig(strategy="fedadc", variant="heavyball", local_steps=8,
                        eta=0.2, beta_local=0.9, beta_global=0.9)
        theta = {"w": jnp.zeros((2,))}
        # consensus direction from history: momentum points at +1 axis
        m = {"w": jnp.array([1.0, 0.0])}
        targets = [{"w": jnp.array([0.0, +4.0])},
                   {"w": jnp.array([0.0, -4.0])}]
        s = get_strategy("fedadc")
        ctx = s.client_setup({"m": m}, theta, fed)
        locals_ = []
        for tgt in targets:
            th, extra = theta, s.init_extra(theta, fed)
            for _ in range(fed.local_steps):
                th, extra, _ = s.local_step(th, ctx, quad_grad(tgt), None,
                                            fed, extra)
            locals_.append(th["w"])
        # both locals got pulled along the consensus direction (−m, since
        # the server update is θ ← θ − αη·m: momentum accumulates pseudo-
        # GRADIENTS, so parameter motion is opposite to m)
        assert locals_[0][0] < 0 and locals_[1][0] < 0
        # and the pull is identical — drift orthogonal to consensus
        np.testing.assert_allclose(locals_[0][0], locals_[1][0], rtol=1e-6)


class TestBaselines:
    def test_fedavg_is_mean_of_locals(self):
        fed = FedConfig(strategy="fedavg", local_steps=2, eta=0.1)
        theta = make_theta(4)
        target = jax.tree.map(jnp.ones_like, theta)
        t1, _, mean_delta = run_round("fedavg", fed, theta,
                                      quad_grad(target))
        expect = T.sub(theta, mean_delta)
        for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(expect)):
            np.testing.assert_allclose(a, b)

    def test_fedprox_mu0_equals_fedavg(self):
        theta = make_theta(5)
        target = jax.tree.map(jnp.zeros_like, theta)
        f1 = FedConfig(strategy="fedprox", mu_prox=0.0, local_steps=3, eta=0.1)
        f2 = FedConfig(strategy="fedavg", local_steps=3, eta=0.1)
        t1, _, _ = run_round("fedprox", f1, theta, quad_grad(target))
        t2, _, _ = run_round("fedavg", f2, theta, quad_grad(target))
        for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)):
            np.testing.assert_allclose(a, b, rtol=1e-6)

    def test_fedprox_pulls_towards_global(self):
        theta = make_theta(6)
        target = jax.tree.map(lambda x: x + 5.0, theta)
        small = FedConfig(strategy="fedprox", mu_prox=0.0, local_steps=5,
                          eta=0.1)
        big = FedConfig(strategy="fedprox", mu_prox=5.0, local_steps=5,
                        eta=0.1)
        t_small, _, _ = run_round("fedprox", small, theta, quad_grad(target))
        t_big, _, _ = run_round("fedprox", big, theta, quad_grad(target))
        d_small = T.global_norm(T.sub(t_small, theta))
        d_big = T.global_norm(T.sub(t_big, theta))
        assert float(d_big) < float(d_small)

    def test_slowmo_accumulates_momentum(self):
        fed = FedConfig(strategy="slowmo", local_steps=2, eta=0.1,
                        beta_global=0.5, alpha=1.0)
        theta = make_theta(7)
        g = jax.tree.map(lambda x: jnp.ones_like(x) * 0.1, theta)
        state = None
        t = theta
        ms = []
        for _ in range(3):
            t, state, _ = run_round("slowmo", fed, t, const_grad(g), state)
            ms.append(float(T.global_norm(state["m"])))
        assert ms[1] > ms[0] and ms[2] > ms[1]          # (1+β+β²) growth

    def test_fedadc_double_no_server_carry(self):
        fed = FedConfig(strategy="fedadc_double", local_steps=3, eta=0.05,
                        phi=0.9, beta_global=0.8, beta_local=0.8)
        theta = make_theta(8)
        g = jax.tree.map(lambda x: x * 0.03, theta)
        _, state, mean_delta = run_round("fedadc_double", fed, theta,
                                         const_grad(g))
        expect = T.scale(mean_delta, 1.0 / fed.eta)     # Alg.4 line 21
        for a, b in zip(jax.tree.leaves(state["m"]), jax.tree.leaves(expect)):
            np.testing.assert_allclose(a, b, rtol=1e-5)

    def test_scaffold_variance_reduction_identity(self):
        """With c = c_i = true mean gradient, SCAFFOLD local updates follow
        the global direction exactly."""
        s = get_strategy("scaffold")
        fed = FedConfig(strategy="scaffold", local_steps=1, eta=0.1)
        theta = make_theta(9)
        g_local = jax.tree.map(lambda x: x * 0.0 + 2.0, theta)
        g_mean = jax.tree.map(lambda x: x * 0.0 + 1.0, theta)
        ctx = {"c": g_mean}
        extra = {"c_i": g_local}
        th, _, _ = s.local_step(theta, ctx, const_grad(g_local), None, fed,
                                extra)
        # g + c − c_i = g_mean
        expect = jax.tree.map(lambda t, gm: t - fed.eta * gm, theta, g_mean)
        for a, b in zip(jax.tree.leaves(th), jax.tree.leaves(expect)):
            np.testing.assert_allclose(a, b, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(eta=st.floats(1e-4, 0.5), beta=st.floats(0.0, 0.95),
       h=st.integers(1, 8))
def test_property_eq4_holds_for_any_hparams(eta, beta, h):
    """Property: Δ = η(Σg + β_l·m) for all (η, β, H) — heavy-ball variant,
    constant gradients (eq. 4)."""
    fed = FedConfig(strategy="fedadc", variant="heavyball", local_steps=h,
                    eta=eta, beta_local=beta, beta_global=beta)
    theta = {"w": jnp.array([1.0, -2.0, 0.5])}
    m = {"w": jnp.array([0.3, 0.3, -0.1])}
    g = {"w": jnp.array([0.05, -0.01, 0.02])}
    s = get_strategy("fedadc")
    ctx = s.client_setup({"m": m}, theta, fed)
    th, extra = theta, s.init_extra(theta, fed)
    for _ in range(h):
        th, extra, _ = s.local_step(
            th, ctx, lambda t, _: (g, jnp.zeros(())), None, fed, extra)
    delta = th["w"] - theta["w"]
    expect = -eta * (h * g["w"] + beta * m["w"])
    np.testing.assert_allclose(delta, expect, rtol=2e-4, atol=1e-6)
