"""Static-analysis subsystem (``repro.analysis``).

Per-rule positive/negative fixture snippets through ``check_source``, the
committed-baseline contract (clean repo + minimal baseline: no new findings,
no stale entries), the trace-time audits' clean verdict on the current
tree, the telemetry-envelope JSONL export, and the CLI exit-code contract.
"""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import RULES, load_baseline, run_ast_rules
from repro.analysis.ast_rules import RepoContext, build_context, check_source
from repro.analysis.baseline import Baseline, write_baseline
from repro.analysis.findings import Finding, findings_to_jsonl, sort_findings

ROOT = pathlib.Path(__file__).resolve().parents[1]

# deterministic fixture context — the live build_context() is exercised
# separately below
CTX = RepoContext(
    numeric_fields=frozenset({"eval_every", "buffer_k", "head_dim"}),
    frozen_configs=frozenset({"FedConfig"}))


def _check(src, rule, path="src/repro/fixture.py"):
    return check_source(textwrap.dedent(src), path, ctx=CTX,
                        rules={rule: RULES[rule]})


class TestRuleRegistry:
    def test_all_issue_rules_registered(self):
        assert set(RULES) == {
            "truthiness-on-config", "low-precision-accumulation",
            "unkeyed-config-cache", "host-sync-in-jit",
            "timer-without-barrier", "unbounded-host-accumulator"}

    def test_live_context_introspects_configs(self):
        ctx = build_context()
        # numeric fields with valid-zero semantics must be present
        assert {"eval_every", "buffer_k", "head_dim"} <= ctx.numeric_fields
        # FedConfig is frozen (the transport shim cache depends on it)
        assert "FedConfig" in ctx.frozen_configs
        # bool/str fields must NOT be numeric (truthiness on them is fine)
        assert "use_pallas" not in ctx.numeric_fields
        assert "strategy" not in ctx.numeric_fields


class TestTruthinessOnConfig:
    def test_if_on_numeric_field_flagged(self):
        got = _check("""
            def f(cfg):
                if cfg.eval_every:
                    return 1
        """, "truthiness-on-config")
        assert len(got) == 1 and "eval_every" in got[0].message

    def test_or_default_flagged(self):
        got = _check("""
            def f(cfg):
                k = cfg.buffer_k or 4
                return k
        """, "truthiness-on-config")
        assert len(got) == 1 and "buffer_k" in got[0].message

    def test_explicit_compare_clean(self):
        got = _check("""
            def f(cfg):
                if cfg.eval_every > 0:
                    return 1
                k = cfg.buffer_k if cfg.buffer_k > 0 else 4
                return k
        """, "truthiness-on-config")
        assert got == []

    def test_non_numeric_field_clean(self):
        got = _check("""
            def f(cfg):
                if cfg.use_pallas:
                    return 1
        """, "truthiness-on-config")
        assert got == []

    def test_or_final_operand_not_flagged(self):
        # `x or cfg.head_dim` — the final operand is the value, not a test
        got = _check("""
            def f(x, cfg):
                return x or cfg.head_dim
        """, "truthiness-on-config")
        assert got == []


class TestLowPrecisionAccumulation:
    def test_bf16_sum_flagged(self):
        got = _check("""
            import jax.numpy as jnp
            def f(x):
                return jnp.sum(x.astype(jnp.bfloat16))
        """, "low-precision-accumulation")
        assert len(got) == 1 and "bfloat16" in got[0].message

    def test_local_assignment_resolved(self):
        got = _check("""
            import jax.numpy as jnp
            def f(x):
                y = x.astype(jnp.bfloat16)
                return jnp.tensordot(w, y, axes=1)
        """, "low-precision-accumulation")
        assert len(got) == 1

    def test_fp32_dtype_kwarg_clean(self):
        got = _check("""
            import jax.numpy as jnp
            def f(x):
                return jnp.sum(x.astype(jnp.bfloat16), dtype=jnp.float32)
        """, "low-precision-accumulation")
        assert got == []

    def test_preferred_element_type_clean(self):
        got = _check("""
            import jax
            import jax.numpy as jnp
            def f(a, b):
                lo = a.astype(jnp.bfloat16)
                return jax.lax.dot(lo, b,
                                   preferred_element_type=jnp.float32)
        """, "low-precision-accumulation")
        assert got == []


class TestUnkeyedConfigCache:
    def test_unannotated_configish_param_flagged(self):
        got = _check("""
            import functools
            @functools.lru_cache(maxsize=None)
            def make(cfg):
                return cfg
        """, "unkeyed-config-cache")
        assert len(got) == 1 and "cfg" in got[0].message

    def test_frozen_config_annotation_clean(self):
        got = _check("""
            import functools
            @functools.lru_cache(maxsize=None)
            def make(fed: FedConfig):
                return fed
        """, "unkeyed-config-cache")
        assert got == []

    def test_scalar_annotations_clean(self):
        got = _check("""
            import functools
            @functools.lru_cache(maxsize=None)
            def make(n: int, name: str, frac: float):
                return n
        """, "unkeyed-config-cache")
        assert got == []

    def test_non_scalar_annotation_flagged(self):
        got = _check("""
            import functools
            @functools.lru_cache(maxsize=None)
            def make(spec: dict):
                return spec
        """, "unkeyed-config-cache")
        assert len(got) == 1


class TestHostSyncInJit:
    def test_float_in_jit_decorated_flagged(self):
        got = _check("""
            import jax
            @jax.jit
            def f(x):
                return float(x)
        """, "host-sync-in-jit")
        assert len(got) == 1 and "float()" in got[0].message

    def test_returned_inner_def_of_jitted_maker_flagged(self):
        got = _check("""
            import jax
            def make_step():
                def step(x):
                    return x.item()
                return step
            step = jax.jit(make_step())
        """, "host-sync-in-jit")
        assert len(got) == 1 and ".item()" in got[0].message

    def test_host_helper_outside_jit_clean(self):
        got = _check("""
            def summarize(x):
                return float(x)
        """, "host-sync-in-jit")
        assert got == []

    def test_np_call_in_traced_body_flagged(self):
        got = _check("""
            import jax
            import numpy as np
            @jax.jit
            def f(x):
                return np.mean(x)
        """, "host-sync-in-jit")
        assert len(got) == 1 and "np.mean" in got[0].message


class TestTimerWithoutBarrier:
    POS = """
        import time
        def bench(f, x):
            t0 = time.perf_counter()
            f(x)
            return time.perf_counter() - t0
    """

    def test_unbarriered_interval_flagged(self):
        got = _check(self.POS, "timer-without-barrier",
                     path="benchmarks/bench_fixture.py")
        assert len(got) == 1 and "block_until_ready" in got[0].message

    def test_barriered_interval_clean(self):
        got = _check("""
            import time
            import jax
            def bench(f, x):
                t0 = time.perf_counter()
                jax.block_until_ready(f(x))
                return time.perf_counter() - t0
        """, "timer-without-barrier", path="benchmarks/bench_fixture.py")
        assert got == []

    def test_rule_scoped_to_benchmarks(self):
        got = _check(self.POS, "timer-without-barrier",
                     path="src/repro/not_a_benchmark.py")
        assert got == []


class TestUnboundedHostAccumulator:
    def test_append_only_attr_flagged(self):
        got = _check("""
            class Log:
                def __init__(self):
                    self.events = []
                def add(self, e):
                    self.events.append(e)
        """, "unbounded-host-accumulator")
        assert len(got) == 1 and "events" in got[0].message

    def test_cleared_attr_clean(self):
        got = _check("""
            class Log:
                def __init__(self):
                    self.events = []
                def add(self, e):
                    self.events.append(e)
                def reset(self):
                    self.events.clear()
        """, "unbounded-host-accumulator")
        assert got == []

    def test_rebound_attr_clean(self):
        got = _check("""
            class Log:
                def __init__(self):
                    self.events = []
                def add(self, e):
                    self.events.append(e)
                def flush(self):
                    self.events = []
        """, "unbounded-host-accumulator")
        assert got == []

    def test_unbounded_deque_flagged(self):
        """PR 9 coverage extension (fleet bookkeeping): a deque without
        maxlen is as unbounded as a list."""
        got = _check("""
            from collections import deque
            class Spill:
                def __init__(self):
                    self.waiting = deque()
                def push(self, e):
                    self.waiting.appendleft(e)
        """, "unbounded-host-accumulator")
        assert len(got) == 1 and "waiting" in got[0].message

    def test_bounded_deque_clean(self):
        got = _check("""
            from collections import deque
            class Spill:
                def __init__(self):
                    self.waiting = deque(maxlen=64)
                def push(self, e):
                    self.waiting.appendleft(e)
        """, "unbounded-host-accumulator")
        assert got == []

    def test_set_add_flagged(self):
        got = _check("""
            class Seen:
                def __init__(self):
                    self.ids = set()
                def mark(self, i):
                    self.ids.add(i)
        """, "unbounded-host-accumulator")
        assert len(got) == 1 and "ids" in got[0].message

    def test_ordereddict_with_popitem_clean(self):
        """The paged store's LRU shape: an OrderedDict page table whose
        admit path also evicts (popitem) is page-table-bounded, not a
        grow-only accumulator."""
        got = _check("""
            from collections import OrderedDict
            class Table:
                def __init__(self):
                    self.pages = OrderedDict()
                def admit(self, k, v):
                    self.pages[k] = v
                    self.pages.update({k: v})
                def evict(self):
                    self.pages.popitem(last=False)
        """, "unbounded-host-accumulator")
        assert got == []


# ---------------------------------------------------------------------------
# baseline contract
# ---------------------------------------------------------------------------
class TestBaseline:
    def test_repo_is_clean_and_baseline_minimal(self):
        """The committed tree fires no unsuppressed AST finding AND every
        committed baseline entry still matches (no ghost suppressions)."""
        baseline = load_baseline(str(ROOT / "analysis_baseline.json"))
        findings = run_ast_rules(str(ROOT))
        new, suppressed, stale = baseline.apply(findings)
        assert new == [], [f.format() for f in new]
        assert stale == [], stale
        assert len(suppressed) == len(baseline.entries)

    def test_every_committed_entry_has_written_reason(self):
        baseline = load_baseline(str(ROOT / "analysis_baseline.json"))
        for e in baseline.entries:
            assert e["reason"] and "TODO" not in e["reason"], e

    def test_stale_entry_detected(self):
        b = Baseline(entries=[{
            "rule": "truthiness-on-config", "path": "src/gone.py",
            "context": "", "snippet": "if cfg.rounds:",
            "reason": "fixture"}])
        new, suppressed, stale = b.apply([])
        assert stale == b.entries and new == [] and suppressed == []

    def test_identity_is_line_number_free(self):
        f1 = Finding("r", "p.py", 10, "msg", context="C.f", snippet="x = 1")
        f2 = Finding("r", "p.py", 99, "other msg", context="C.f",
                     snippet="x = 1")
        assert f1.key() == f2.key()

    def test_missing_baseline_is_empty(self, tmp_path):
        b = load_baseline(str(tmp_path / "nope.json"))
        assert b.entries == []

    def test_reasonless_entry_rejected(self, tmp_path):
        p = tmp_path / "b.json"
        p.write_text(json.dumps({"version": 1, "entries": [
            {"rule": "r", "path": "p.py", "context": "", "snippet": "s",
             "reason": ""}]}))
        with pytest.raises(ValueError, match="reason"):
            load_baseline(str(p))

    def test_update_baseline_round_trips(self, tmp_path):
        p = tmp_path / "b.json"
        f = Finding("rule-x", "a.py", 3, "m", context="g", snippet="s")
        write_baseline(str(p), [f], reason="because")
        b = load_baseline(str(p))
        new, suppressed, stale = b.apply([f])
        assert new == [] and stale == [] and suppressed[0].suppressed


# ---------------------------------------------------------------------------
# JSONL export rides the telemetry envelope
# ---------------------------------------------------------------------------
class TestJsonlExport:
    def test_events_validate_and_round_trip(self, tmp_path):
        from repro.telemetry.schema import validate_event
        p = tmp_path / "findings.jsonl"
        fs = [Finding("rule-a", "x.py", 1, "m1"),
              Finding("rule-b", "y.py", 2, "m2", suppressed=True)]
        n = findings_to_jsonl(fs, str(p), ts=123.0)
        assert n == 2
        lines = [json.loads(l) for l in p.read_text().splitlines()]
        for ev in lines:
            validate_event(ev)
            assert ev["kind"] == "finding" and ev["engine"] == "analysis"
        assert lines[1]["suppressed"] is True

    def test_sort_is_stable_by_path_line_rule(self):
        fs = [Finding("b", "z.py", 9, "m"), Finding("a", "a.py", 2, "m"),
              Finding("a", "a.py", 1, "m")]
        got = sort_findings(fs)
        assert [(f.path, f.line) for f in got] == [
            ("a.py", 1), ("a.py", 2), ("z.py", 9)]


# ---------------------------------------------------------------------------
# Layer 2 subset: the cheap trace audits stay green in tier-1 (the full
# matrix incl. retrace runs in the CI `analysis` job)
# ---------------------------------------------------------------------------
class TestTraceAudits:
    def test_kernel_coverage_clean(self):
        from repro.analysis.trace_audit import audit_kernel_coverage
        assert audit_kernel_coverage(str(ROOT)) == []

    def test_kernel_coverage_detects_missing_oracle(self, tmp_path):
        from repro.analysis.trace_audit import audit_kernel_coverage
        k = tmp_path / "src" / "repro" / "kernels"
        k.mkdir(parents=True)
        (k / "ops.py").write_text(
            "def my_kernel(x):\n"
            "    return pl.pallas_call(_body, interpret=True)(x)\n")
        (k / "ref.py").write_text("")
        t = tmp_path / "tests"
        t.mkdir()
        (t / "test_kernels.py").write_text("")
        got = audit_kernel_coverage(str(tmp_path))
        assert any("my_kernel" in f.message for f in got)

    def test_accumulation_dtype_clean(self):
        """weighted_reduce jaxprs, the FedADC momentum update (fp32 AND
        bf16 param regimes), and the pod client-serial scan all hold ≥fp32
        accumulators."""
        from repro.analysis.trace_audit import audit_accumulation_dtype
        got = audit_accumulation_dtype()
        assert got == [], [f.format() for f in got]


# ---------------------------------------------------------------------------
# CLI contract
# ---------------------------------------------------------------------------
class TestCli:
    def _run(self, *args):
        env = dict(os.environ)
        env["PYTHONPATH"] = str(ROOT / "src")
        return subprocess.run(
            [sys.executable, "-m", "repro.analysis", *args],
            capture_output=True, text=True, cwd=str(ROOT), env=env,
            timeout=300)

    def test_ast_layer_clean_exit_zero(self):
        r = self._run("--skip-trace", "--require-clean")
        assert r.returncode == 0, r.stdout + r.stderr
        assert "0 finding(s)" in r.stdout

    def test_list_rules(self):
        r = self._run("--list-rules")
        assert r.returncode == 0
        for rid in RULES:
            assert rid in r.stdout
        assert "trace-retrace" in r.stdout

    def test_unknown_rule_subset_is_usage_error(self):
        r = self._run("--skip-trace", "--rules", "no-such-rule")
        assert r.returncode == 2

    def test_jsonl_artifact_written(self, tmp_path):
        out = tmp_path / "f.jsonl"
        r = self._run("--skip-trace", "--jsonl", str(out))
        assert r.returncode == 0
        lines = [json.loads(l) for l in out.read_text().splitlines()]
        # the committed baseline's suppressed findings ride the artifact
        assert lines and all(e["kind"] == "finding" for e in lines)
        assert all(e["suppressed"] for e in lines)
