"""Partitioners, client selection, checkpointing, schedules, optimizers."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpointing import latest_step, restore_checkpoint, save_checkpoint
from repro.core.selection import class_coverage_selection, random_selection
from repro.data.partition import (class_counts, dirichlet_partition,
                                  sort_and_partition)
from repro.data.synthetic import make_image_dataset, make_token_dataset
from repro.optim import (adamw_init, adamw_update, momentum_init,
                         momentum_update, sgd_update, warmup_cosine)


class TestPartition:
    @settings(max_examples=15, deadline=None)
    @given(s=st.integers(1, 5), n_clients=st.integers(2, 20),
           seed=st.integers(0, 10))
    def test_sort_partition_label_budget(self, s, n_clients, seed):
        from hypothesis import assume
        # label budget needs block size ≤ class size (the paper's regime:
        # 100 clients, s∈{2,3,4}, 10 balanced classes of 5000)
        assume(n_clients * s >= 10)
        rng = np.random.RandomState(seed)
        labels = rng.permutation(np.repeat(np.arange(10), 100)).astype(int)
        parts = sort_and_partition(labels, n_clients, s, seed)
        # exact cover, no duplication
        allidx = np.sort(np.concatenate(parts))
        np.testing.assert_array_equal(allidx, np.sort(np.argsort(labels)))
        # each client sees at most 2s distinct labels (each of its s sorted
        # blocks can straddle one label boundary)
        for p in parts:
            assert len(np.unique(labels[p])) <= 2 * s

    @settings(max_examples=10, deadline=None)
    @given(alpha=st.floats(0.05, 10.0), seed=st.integers(0, 5))
    def test_dirichlet_exact_cover(self, alpha, seed):
        rng = np.random.RandomState(seed)
        labels = rng.randint(0, 10, size=2000)
        parts = dirichlet_partition(labels, 10, alpha, seed)
        allidx = np.sort(np.concatenate(parts))
        np.testing.assert_array_equal(allidx, np.arange(2000))

    def test_dirichlet_skew_monotone(self):
        labels = np.random.RandomState(0).randint(0, 10, size=20000)
        def skew(alpha):
            parts = dirichlet_partition(labels, 20, alpha, 0)
            cts = class_counts(labels, parts, 10)
            props = cts / cts.sum(1, keepdims=True)
            return float(np.mean(props.max(1)))
        assert skew(0.1) > skew(10.0)   # smaller α ⇒ more skew

    def test_class_counts(self):
        labels = np.array([0, 0, 1, 2, 2, 2])
        parts = [np.array([0, 1, 2]), np.array([3, 4, 5])]
        cts = class_counts(labels, parts, 3)
        np.testing.assert_array_equal(cts, [[2, 1, 0], [0, 0, 3]])


class TestSelection:
    def test_coverage_selector_covers(self):
        rng = np.random.RandomState(0)
        # 10 clients each holding exactly one class of 5
        counts = np.zeros((10, 5))
        for i in range(10):
            counts[i, i % 5] = 10
        for _ in range(20):
            pick = class_coverage_selection(rng, 10, 5, counts)
            assert (counts[pick].sum(0) > 0).all()

    def test_random_selector_no_replacement(self):
        rng = np.random.RandomState(0)
        pick = random_selection(rng, 10, 10)
        assert len(set(pick.tolist())) == 10

    @staticmethod
    def _check_pick(pick, counts, n_clients, n_pick):
        """Validity + single-swap local optimality: greedy repair may not
        claim coverage it doesn't have, and must not stop while one swap
        could still add a class."""
        pick = pick.tolist()
        assert len(pick) == n_pick
        assert len(set(pick)) == n_pick
        assert all(0 <= c < n_clients for c in pick)
        cov = int((counts[pick].sum(0) > 0).sum())
        if cov == counts.shape[1]:
            return
        outside = [c for c in range(n_clients) if c not in set(pick)]
        for cand in outside:
            for j in range(n_pick):
                rest = pick[:j] + pick[j + 1:] + [cand]
                assert int((counts[rest].sum(0) > 0).sum()) <= cov, \
                    (pick, j, cand)

    def test_greedy_repair_does_not_lose_covered_classes(self):
        """Regression: the old repair swapped out a member without checking
        the removed member's classes stayed covered and never recomputed
        `missing`, so it could return an incomplete pick while claiming
        coverage.  Adversarial fixture: sole holders of some classes plus
        decoy clients that force the repair path."""
        rng = np.random.RandomState(3)
        n_clients, n_classes, n_pick = 8, 6, 3
        counts = np.zeros((n_clients, n_classes))
        counts[0, 0] = 5                      # sole holder of class 0
        counts[1, 1] = 5                      # sole holder of class 1
        counts[2, [2, 3]] = 5
        counts[3, [4, 5]] = 5
        counts[4:, 0] = 1                     # decoys: class 0 only
        for seed in range(30):
            rng = np.random.RandomState(seed)
            pick = class_coverage_selection(rng, n_clients, n_pick, counts,
                                            max_tries=3)
            self._check_pick(pick, counts, n_clients, n_pick)

    def test_selectors_deterministic_under_seed(self):
        """Both selectors are pure functions of (rng state, arguments):
        same seed + same counts ⇒ same picks — the property the fleet
        scheduler's per-region delegation rests on."""
        counts = (np.random.RandomState(7).rand(20, 6) < 0.4) * 5
        for seed in range(5):
            r1 = random_selection(np.random.RandomState(seed), 20, 6)
            r2 = random_selection(np.random.RandomState(seed), 20, 6)
            np.testing.assert_array_equal(r1, r2)
            c1 = class_coverage_selection(np.random.RandomState(seed),
                                          20, 6, counts, max_tries=4)
            c2 = class_coverage_selection(np.random.RandomState(seed),
                                          20, 6, counts, max_tries=4)
            np.testing.assert_array_equal(c1, c2)

    @settings(max_examples=60, deadline=None)
    @given(seed=st.integers(0, 10_000), n_clients=st.integers(2, 12),
           n_classes=st.integers(2, 8), density=st.floats(0.05, 0.9))
    def test_greedy_repair_property(self, seed, n_clients, n_classes,
                                    density):
        rng = np.random.RandomState(seed)
        counts = (rng.rand(n_clients, n_classes) < density) * \
            rng.randint(1, 20, size=(n_clients, n_classes))
        n_pick = rng.randint(1, n_clients + 1)
        pick = class_coverage_selection(np.random.RandomState(seed + 1),
                                        n_clients, n_pick, counts,
                                        max_tries=5)
        self._check_pick(pick, counts, n_clients, n_pick)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "nested": {"b": jnp.ones((4,), jnp.bfloat16),
                           "c": [jnp.zeros(2), jnp.ones(3)]}}
        save_checkpoint(str(tmp_path), 7, tree)
        assert latest_step(str(tmp_path)) == 7
        like = jax.tree.map(jnp.zeros_like, tree)
        restored = restore_checkpoint(str(tmp_path), 7, like)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_shape_mismatch_rejected(self, tmp_path):
        save_checkpoint(str(tmp_path), 0, {"a": jnp.ones((2, 2))})
        with pytest.raises(ValueError):
            restore_checkpoint(str(tmp_path), 0, {"a": jnp.ones((3, 3))})

    def test_structure_mismatch_rejected(self, tmp_path):
        save_checkpoint(str(tmp_path), 0, {"a": jnp.ones(2)})
        with pytest.raises(ValueError):
            restore_checkpoint(str(tmp_path), 0, {"b": jnp.ones(2)})

    def test_extra_key_rejected(self, tmp_path):
        save_checkpoint(str(tmp_path), 0, {"a": jnp.ones(2),
                                           "b": jnp.ones(2)})
        with pytest.raises(ValueError, match="mismatch"):
            restore_checkpoint(str(tmp_path), 0, {"a": jnp.ones(2)})

    def test_bf16_exact_bit_roundtrip(self, tmp_path):
        """bf16 leaves go through npz as raw uint16 bits: restore must be
        exact-BIT equality, not just value-close (subnormals, -0.0, large
        magnitudes must survive)."""
        vals = jnp.asarray([0.0, -0.0, 1.0, -1.5, 3.14159e8, 1e-40,
                            65504.0, 2.0 ** -126], jnp.bfloat16)
        tree = {"p": vals, "n": {"q": jnp.full((3, 2), -2.718, jnp.bfloat16)}}
        save_checkpoint(str(tmp_path), 1, tree)
        restored = restore_checkpoint(str(tmp_path), 1,
                                      jax.tree.map(jnp.zeros_like, tree))
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            assert np.asarray(b).dtype == np.asarray(a).dtype
            np.testing.assert_array_equal(
                np.asarray(a).view(np.uint16), np.asarray(b).view(np.uint16))

    @pytest.mark.parametrize("dt", ["float8_e4m3fn", "float8_e5m2"])
    def test_fp8_exact_bit_roundtrip(self, tmp_path, dt):
        dtype = jnp.dtype(dt)
        rng = np.random.RandomState(0)
        tree = {"w": jnp.asarray(rng.randn(4, 5), dtype)}
        save_checkpoint(str(tmp_path), 2, tree)
        restored = restore_checkpoint(str(tmp_path), 2,
                                      jax.tree.map(jnp.zeros_like, tree))
        a, b = np.asarray(tree["w"]), np.asarray(restored["w"])
        assert b.dtype == a.dtype
        np.testing.assert_array_equal(a.view(np.uint8), b.view(np.uint8))

    def test_eight_byte_nonbuiltin_roundtrip(self, tmp_path):
        """Parameterised 8-byte dtypes (datetime64[ns] reports isbuiltin
        != 1) take the raw-bits path via uint64, not a KeyError."""
        tree = {"t": np.array([0, 1_700_000_000_000_000_000],
                              "datetime64[ns]")}
        save_checkpoint(str(tmp_path), 4, tree)
        restored = restore_checkpoint(str(tmp_path), 4,
                                      {"t": np.zeros(2, "datetime64[ns]")})
        np.testing.assert_array_equal(restored["t"], tree["t"])

    def test_failed_save_leaves_no_tmp_file(self, tmp_path, monkeypatch):
        """A save that crashes mid-write must clean up its tmp file so the
        checkpoint directory never accumulates torn partials."""
        import repro.checkpointing.checkpoint as C

        def boom(*a, **k):
            raise OSError("disk full")
        monkeypatch.setattr(C.np, "savez", boom)
        with pytest.raises(OSError, match="disk full"):
            save_checkpoint(str(tmp_path), 3, {"a": jnp.ones(2)})
        assert os.listdir(str(tmp_path)) == []
        assert latest_step(str(tmp_path)) is None


class TestOptim:
    def test_sgd_descends_quadratic(self):
        p = {"w": jnp.array([4.0, -2.0])}
        for _ in range(50):
            g = p
            p = sgd_update(p, g, 0.1)
        assert float(jnp.abs(p["w"]).max()) < 0.1

    def test_momentum_faster_than_sgd_on_illconditioned(self):
        A = jnp.array([1.0, 25.0])
        def grad(p): return {"w": A * p["w"]}
        p_s = {"w": jnp.array([1.0, 1.0])}
        p_m, m = {"w": jnp.array([1.0, 1.0])}, momentum_init({"w": jnp.zeros(2)})
        for _ in range(60):
            p_s = sgd_update(p_s, grad(p_s), 0.03)
            p_m, m = momentum_update(p_m, grad(p_m), m, 0.03, beta=0.9)
        assert float(jnp.abs(p_m["w"]).sum()) < float(jnp.abs(p_s["w"]).sum())

    def test_adamw_decouples_weight_decay(self):
        p = {"w": jnp.array([1.0])}
        st_ = adamw_init(p)
        p2, _ = adamw_update(p, {"w": jnp.zeros(1)}, st_, lr=0.1,
                             weight_decay=0.5)
        np.testing.assert_allclose(p2["w"], 0.95)   # only decay moves it

    def test_warmup_cosine(self):
        f = warmup_cosine(1.0, warmup=10, total=110)
        assert float(f(0)) == 0.0
        np.testing.assert_allclose(float(f(10)), 1.0, atol=1e-6)
        assert float(f(110)) < 0.01


class TestSyntheticData:
    def test_image_dataset_learnable_structure(self):
        x, y, xt, yt = make_image_dataset(200, 50, 5, image_size=8, seed=0)
        assert x.shape == (200, 8, 8, 3) and y.max() < 5
        # class templates separate in pixel space (centroid distance >> 0)
        mus = np.stack([x[y == c].mean(0) for c in range(5)])
        d = np.linalg.norm(mus[0] - mus[1])
        assert d > 0.05

    def test_token_dataset_domain_structure(self):
        toks, doms = make_token_dataset(20, 64, 256, seed=0)
        assert toks.shape == (20, 64) and toks.max() < 256
        assert doms.shape == (20,)
