import os

# Tests run on the single real CPU device (the 512-device flag belongs ONLY
# to the dry-run).  Force float32 matmuls for reproducible allclose bounds.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")


@pytest.fixture
def steady_state_guard():
    """Warmup-then-guard transfer discipline (DESIGN.md §Static analysis).

    Returns a zero-arg factory for a ``jax.transfer_guard("disallow")``
    context.  The pattern: run the code path once UN-guarded (compilation
    and the initial host->device sync of params/data are legitimately
    transfer-heavy), then re-run the steady-state iteration inside the
    guard.  Any np array silently fed to a jit'd function or fresh device
    constant materialised per round then fails loudly.  Explicit transfers
    stay allowed — ``jnp.asarray`` on the round's batch and the round's
    single sanctioned ``jax.device_get`` at eval ARE the declared
    wire/fetch points, so no opt-out block is needed around them.
    """
    return lambda: jax.transfer_guard("disallow")
