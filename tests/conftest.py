import os

# Tests run on the single real CPU device (the 512-device flag belongs ONLY
# to the dry-run).  Force float32 matmuls for reproducible allclose bounds.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")
