"""Regression tests for the dry-run lowering machinery on the 1×1 host mesh
(the 512-device production lowering is exercised by launch/dryrun.py; these
pin the ShapeDtypeStruct/sharding plumbing so it cannot rot)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.configs.base import FedConfig, RunConfig, ShapeConfig
from repro.launch import inputs as I
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import make_prefill_step, make_serve_step
from repro.launch.train import make_train_step

SMALL_TRAIN = ShapeConfig("train_small", seq_len=64, global_batch=16,
                          kind="train")
SMALL_PREFILL = ShapeConfig("prefill_small", seq_len=128, global_batch=2,
                            kind="prefill")
SMALL_DECODE = ShapeConfig("decode_small", seq_len=128, global_batch=2,
                           kind="decode")

FED = FedConfig(strategy="fedadc", clients_per_round=2, local_steps=2,
                eta=0.05)
RUN = RunConfig(remat="none")


@pytest.mark.parametrize("arch", ["qwen3-4b", "zamba2-1.2b",
                                  "llama4-scout-17b-a16e", "whisper-small"])
def test_train_step_lowers_on_host_mesh(arch):
    mcfg = ARCHS[arch].reduced()
    mesh = make_host_mesh()
    with mesh:
        state_sds = I.state_inputs(mcfg, FED, RUN, mesh)
        batch_sds = I.train_inputs(mcfg, SMALL_TRAIN, FED, mesh, False)
        step = make_train_step(mcfg, FED, RUN)
        compiled = jax.jit(step).lower(state_sds, batch_sds).compile()
        assert compiled.cost_analysis() is not None


@pytest.mark.parametrize("arch", ["qwen3-4b", "internvl2-26b"])
def test_prefill_lowers_on_host_mesh(arch):
    mcfg = ARCHS[arch].reduced()
    mesh = make_host_mesh()
    with mesh:
        state_sds = I.state_inputs(mcfg, FED, RUN, mesh, mode="serve")
        batch_sds = I.prefill_inputs(mcfg, SMALL_PREFILL, mesh, False)
        step = make_prefill_step(mcfg)
        compiled = jax.jit(step).lower(state_sds["params"],
                                       batch_sds).compile()
        assert compiled is not None


@pytest.mark.parametrize("arch", ["qwen3-4b", "xlstm-350m",
                                  "deepseek-v3-671b"])
def test_serve_step_lowers_on_host_mesh(arch):
    mcfg = ARCHS[arch].reduced()
    mesh = make_host_mesh()
    with mesh:
        state_sds = I.state_inputs(mcfg, FED, RUN, mesh, mode="serve")
        cache_sds, tokens, cur_pos, active = I.decode_inputs(
            mcfg, SMALL_DECODE, mesh, False, cache_dtype=jnp.float32)
        step = make_serve_step(mcfg)
        compiled = jax.jit(step).lower(state_sds["params"], cache_sds,
                                       tokens, cur_pos, active).compile()
        assert compiled is not None


def test_kd_loss_ignores_padding_tokens():
    """FedADC+ KD regression: positions with label == -100 must contribute to
    neither the CE/KD terms nor the ρ token statistics — junk content at
    padded tail positions cannot change the round."""
    import numpy as np
    mcfg = ARCHS["qwen3-4b"].reduced()
    fed = FedConfig(strategy="fedadc", clients_per_round=1, local_steps=2,
                    eta=0.05, distill=True, distill_lambda=0.35)
    run = RunConfig(remat="none", param_dtype="float32",
                    compute_dtype="float32")
    mesh = make_host_mesh()
    with mesh:
        from repro.launch.train import init_state
        state = init_state(jax.random.PRNGKey(0), mcfg, fed, run)
        step = make_train_step(mcfg, fed, run)
        rng = np.random.RandomState(0)
        b, L, pad_from = 2, 32, 20
        toks = rng.randint(0, mcfg.vocab_size, size=(1, 1, 2, b, L))
        labels = toks.copy()
        labels[..., pad_from:] = -100
        batch_a = {"tokens": jnp.asarray(toks, jnp.int32),
                   "labels": jnp.asarray(labels, jnp.int32)}
        junk = toks.copy()
        junk[..., pad_from:] = rng.randint(0, mcfg.vocab_size,
                                           size=junk[..., pad_from:].shape)
        batch_b = {"tokens": jnp.asarray(junk, jnp.int32),
                   "labels": jnp.asarray(labels, jnp.int32)}
        sa, ma = step(state, batch_a)
        sb, mb = step(state, batch_b)
        assert jnp.allclose(ma["loss"], mb["loss"], rtol=1e-6)
        for x, y in zip(jax.tree.leaves(sa["params"]),
                        jax.tree.leaves(sb["params"])):
            assert jnp.allclose(x, y, rtol=1e-5, atol=1e-7), \
                "padding tokens leaked into the KD round"


def test_round_decomposition_exact():
    from repro.launch.inputs import round_decomposition
    mesh = make_host_mesh()
    fed = FedConfig(clients_per_round=4, local_steps=4)
    from repro.configs.base import SHAPES
    CP, CS, H, b = round_decomposition(SHAPES["train_4k"], fed, mesh, False)
    assert CP * CS == 4 and H == 4 and CP * CS * H * b == 256
