"""Fleet subsystem (DESIGN.md §Fleet): two-tier hierarchical aggregation
units (balanced region split, R=1 bitwise identity, R>1 linearity against
a two-level oracle, sparse wires), the memory-bounded ``PagedClientStore``
(bitwise spill round-trips for fp32/bf16/fp8 leaves, 1-page-budget
eviction, scatter-to-evicted-page, hard budget, gauges, on-disk spill
tier, host-backend equivalence, steady-state transfer discipline), and
the deterministic region-aware ``FleetScheduler`` — plus engine
integration: a simulator run over the paged store is bit-identical to the
host store, and scheduler-driven runs are reproducible under seed.

Engine-level flat-vs-hierarchical parity lives in tests/test_transport.py
(the CI engine-parity matrix's ``Hierarchical`` axis)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpointing.checkpoint import storage_view
from repro.configs.base import FedConfig, HeteroConfig
from repro.core.strategies import get_strategy
from repro.data.partition import sort_and_partition
from repro.data.synthetic import make_image_dataset
from repro.federated import aggregation as A
from repro.federated.fleet import (Cohort, FleetScheduler,
                                   HierarchicalAggregator, PagedClientStore,
                                   hierarchical_aggregate, page_nbytes,
                                   region_sizes, region_slices)
from repro.federated.simulator import FederatedSimulator, SimConfig
from repro.federated.store import ClientStore
from repro.federated.transport import SparseTopKCodec
from repro.core import tree as T
from repro.telemetry.tracer import Counters


def _tree(seed=0, shapes=((33, 9), (17,))):
    ks = jax.random.split(jax.random.PRNGKey(seed), len(shapes))
    return {f"l{i}": jax.random.normal(k, s)
            for i, (k, s) in enumerate(zip(ks, shapes))}


def _bits_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(storage_view(np.asarray(x)),
                                      storage_view(np.asarray(y)))


# ---------------------------------------------------------------------------
# hierarchy units
# ---------------------------------------------------------------------------
class TestRegionSplit:
    def test_sizes_balanced_and_total(self):
        assert region_sizes(10, 3) == (4, 3, 3)
        assert region_sizes(6, 3) == (2, 2, 2)
        assert region_sizes(5, 5) == (1, 1, 1, 1, 1)
        for total, r in [(7, 2), (100, 9), (16, 16)]:
            sizes = region_sizes(total, r)
            assert sum(sizes) == total
            assert max(sizes) - min(sizes) <= 1

    def test_slices_cover_contiguously(self):
        slices = region_slices(11, 4)
        assert slices[0][0] == 0
        for (s0, n0), (s1, _) in zip(slices, slices[1:]):
            assert s0 + n0 == s1
        assert slices[-1][0] + slices[-1][1] == 11

    def test_rejects_bad_splits(self):
        with pytest.raises(ValueError, match=">= 1"):
            region_sizes(4, 0)
        with pytest.raises(ValueError, match="cannot fill"):
            region_sizes(2, 3)


class TestHierarchicalAggregate:
    def _stack(self, n=6):
        trees = [_tree(s) for s in range(n)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)

    def test_one_region_bitwise_flat(self):
        fed = FedConfig(fleet_regions=1, clients_per_round=6)
        strat = get_strategy("fedadc")
        deltas = self._stack(6)
        w = jnp.asarray([0.5, 1.2, 0.1, 2.0, 0.7, 0.9], jnp.float32)
        flat = strat.server_aggregate(deltas, w, fed)
        hier = hierarchical_aggregate(deltas, w, fed, strat)
        _bits_equal(flat, hier)

    def test_multi_region_matches_two_level_oracle(self):
        """R=3 equals the hand-computed two-level weighted mean (exact
        modulo fp reassociation) — the linearity argument, numerically."""
        fed = FedConfig(fleet_regions=3, clients_per_round=7)
        strat = get_strategy("fedadc")
        deltas = self._stack(7)
        w = jnp.asarray(np.random.RandomState(0).uniform(0.1, 2.0, 7),
                        jnp.float32)
        got = hierarchical_aggregate(deltas, w, fed, strat)
        wn = np.asarray(w, np.float64)
        oracle = {}
        for key, leaf in deltas.items():
            x = np.asarray(leaf, np.float64)
            parts, pw = [], []
            for start, size in region_slices(7, 3):
                ws = wn[start:start + size]
                parts.append(np.tensordot(ws / ws.sum(),
                                          x[start:start + size], axes=1))
                pw.append(ws.sum())
            pw = np.asarray(pw)
            oracle[key] = np.tensordot(pw / pw.sum(), np.stack(parts),
                                       axes=1)
        for key in oracle:
            np.testing.assert_allclose(np.asarray(got[key]), oracle[key],
                                       rtol=0, atol=1e-6)

    def test_sparse_one_region_bitwise(self):
        like = _tree(9)
        codec = SparseTopKCodec(0.2)
        wires = [codec.encode(_tree(s), T.zeros_like(like),
                              jax.random.PRNGKey(s))[0] for s in (1, 2, 3)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *wires)
        w = jnp.asarray([0.3, 0.5, 0.2], jnp.float32)
        fed = FedConfig(fleet_regions=1, clients_per_round=3)
        flat = A.sparse_weighted_mean(stacked, w, like)
        hier = hierarchical_aggregate(stacked, w, fed,
                                      get_strategy("fedadc"), like=like)
        _bits_equal(flat, hier)

    def test_sparse_requires_template(self):
        like = _tree(9)
        wire, _ = SparseTopKCodec(0.2).encode(_tree(1), T.zeros_like(like),
                                              jax.random.PRNGKey(0))
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), wire)
        fed = FedConfig(fleet_regions=1, clients_per_round=1)
        with pytest.raises(ValueError, match="like"):
            hierarchical_aggregate(stacked, jnp.ones((1,)), fed,
                                   get_strategy("fedadc"))

    def test_aggregator_rejects_more_regions_than_round(self):
        with pytest.raises(ValueError, match="region"):
            HierarchicalAggregator(
                FedConfig(fleet_regions=5, clients_per_round=3),
                get_strategy("fedadc"))
        # buffer_k is the async round size when set
        HierarchicalAggregator(
            FedConfig(fleet_regions=5, clients_per_round=3, buffer_k=5),
            get_strategy("fedadc"))


# ---------------------------------------------------------------------------
# paged client store
# ---------------------------------------------------------------------------
def _page_bytes(d=8, dtype=jnp.float32):
    return int(np.dtype(np.float32).itemsize if dtype == jnp.float32
               else jnp.zeros((), dtype).dtype.itemsize) * d


class TestPagedStore:
    def _store(self, budget, **kw):
        s = PagedClientStore(budget_bytes=budget, **kw)
        s.register("ef", lambda: jnp.zeros((8,), jnp.float32))
        return s

    def test_gather_initialises_then_round_trips(self):
        s = self._store(10 ** 6)
        got = s.gather("ef", [0, 1])
        assert got.shape == (2, 8) and float(jnp.sum(jnp.abs(got))) == 0
        vals = jnp.arange(16, dtype=jnp.float32).reshape(2, 8)
        s.scatter("ef", [0, 1], vals)
        _bits_equal(s.gather("ef", [0, 1]), vals)

    def test_eviction_under_one_page_budget(self):
        page = 8 * 4
        s = self._store(page, counters=Counters())
        vals = jnp.arange(24, dtype=jnp.float32).reshape(3, 8)
        s.scatter("ef", [0, 1, 2], vals)
        assert s.resident_pages == 1 and s.spilled_pages == 2
        assert s.resident_bytes == page <= s.budget_bytes
        # every page still reads back exactly, thrashing through the spill
        for c in (0, 1, 2):
            _bits_equal(s.gather("ef", [c]), vals[c:c + 1])
        assert s.counters.snapshot()["store.loads"] >= 2

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16",
                                       "float8_e4m3fn"])
    def test_spilled_page_round_trips_bitwise(self, dtype):
        """Evict → compress (uint bit-view, the checkpoint trick) → load
        must be bit-identical for non-builtin dtypes too, including values
        a float round-trip would mangle (negative zero, subnormals)."""
        dt = jnp.dtype(dtype)
        s = PagedClientStore(budget_bytes=16 * dt.itemsize)
        s.register("st", lambda: jnp.zeros((16,), dt))
        rng = np.random.RandomState(3)
        vals = jnp.asarray(rng.randn(3, 16), jnp.float32).astype(dt)
        vals = vals.at[:, 0].set(jnp.asarray(-0.0, dt))
        s.scatter("st", [0, 1, 2], vals)
        assert s.spilled_pages == 2            # budget holds one page
        _bits_equal(s.gather("st", [0, 1, 2]), vals)

    def test_scatter_to_evicted_page_supersedes_spill(self):
        page = 8 * 4
        s = self._store(page)
        v1 = jnp.ones((1, 8), jnp.float32)
        s.scatter("ef", [0], v1)
        s.scatter("ef", [1], v1 * 2)           # evicts client 0 to spill
        assert s.spilled_pages == 1
        v2 = jnp.full((1, 8), 7.0, jnp.float32)
        s.scatter("ef", [0], v2)               # write to the evicted page
        _bits_equal(s.gather("ef", [0]), v2)
        # exactly one live version per page: the stale spill copy is gone
        assert s.resident_pages + s.spilled_pages == 2

    def test_budget_never_exceeded(self):
        page = 8 * 4
        s = self._store(3 * page)
        rng = np.random.RandomState(0)
        for r in range(5):
            ids = rng.choice(20, size=4, replace=False)
            s.scatter("ef", ids, jnp.asarray(
                rng.randn(4, 8).astype(np.float32)))
            assert s.resident_bytes <= s.budget_bytes
        assert s.peak_resident_bytes <= s.budget_bytes
        assert s.peak_resident_bytes == 3 * page

    def test_gauges_published(self):
        c = Counters()
        page = 8 * 4
        s = self._store(2 * page, counters=c)
        s.scatter("ef", [0, 1, 2], jnp.ones((3, 8), jnp.float32))
        snap = c.snapshot()
        assert snap["store.resident_pages"] == 2
        assert snap["store.resident_bytes"] == 2 * page
        assert snap["store.spilled_pages"] == 1
        assert snap["store.spills"] == 1
        s.gather("ef", [0])
        assert c.snapshot()["store.loads"] == 1

    def test_spill_dir_on_disk(self, tmp_path):
        page = 8 * 4
        s = self._store(page, spill_dir=str(tmp_path))
        vals = jnp.arange(16, dtype=jnp.float32).reshape(2, 8)
        s.scatter("ef", [0, 1], vals)
        assert len(list(tmp_path.glob("*.page"))) == 1
        _bits_equal(s.gather("ef", [0]), vals[:1])   # load removes the file
        assert list(tmp_path.glob("*.page")) == [] or s.spilled_pages == 1

    def test_states_view_and_namespaces(self):
        s = self._store(8 * 4)
        assert s.namespaces() == ("ef",)
        s.scatter("ef", [3, 5], jnp.ones((2, 8), jnp.float32))
        view = s.states("ef")
        assert sorted(view) == [3, 5] and 3 in view and 4 not in view
        _bits_equal(view[5], jnp.ones((8,), jnp.float32))
        view[4] = jnp.zeros((8,), jnp.float32)
        assert len(view) == 3
        del view[4]
        assert sorted(view) == [3, 5]
        with pytest.raises(KeyError):
            view[99]

    def test_matches_host_backend_bitwise(self):
        """The same gather/scatter sequence against the host dict store and
        a 2-page paged store must produce identical device values."""
        host = ClientStore()
        paged = self._store(2 * 8 * 4)
        host.register("ef", lambda: jnp.zeros((8,), jnp.float32))
        rng = np.random.RandomState(1)
        for r in range(6):
            ids = rng.choice(12, size=3, replace=False)
            gh = host.gather("ef", ids)
            gp = paged.gather("ef", ids)
            _bits_equal(gh, gp)
            upd = jnp.asarray(rng.randn(3, 8).astype(np.float32))
            host.scatter("ef", ids, gh + upd)
            paged.scatter("ef", ids, gp + upd)
        assert paged.spilled_pages > 0          # the comparison saw spills

    def test_steady_state_transfer_guard(self, steady_state_guard):
        """gather's jnp.asarray and scatter's device_get are the only wire
        points — spill/load cycles stay clean under the disallow guard."""
        s = self._store(8 * 4)
        # warm: first gather materialises the namespace template (its init
        # fn may allocate on device), first scatter pays the initial H2D
        s.gather("ef", [0])
        s.scatter("ef", [0, 1], jnp.ones((2, 8), jnp.float32))
        with steady_state_guard():
            got = s.gather("ef", [0, 1, 2])
            s.scatter("ef", [0, 1, 2], got + got)
            s.gather("ef", [1])

    def test_page_nbytes_counts_all_leaves(self):
        page = {"a": np.zeros((4,), np.float32),
                "b": np.zeros((2, 3), np.int32)}
        assert page_nbytes(page) == 4 * 4 + 6 * 4

    def test_rejects_nonpositive_budget(self):
        with pytest.raises(ValueError, match="budget"):
            PagedClientStore(budget_bytes=0)


# ---------------------------------------------------------------------------
# per-client downlink reference pages through the paged tier: the unicast
# ReferenceStore parks each dispatched client's wire in the store's
# "downlink_ref" namespace, so at fleet scale the pages spill through the
# LRU/zlib bit-view tier and must reload the exact downlink
# ---------------------------------------------------------------------------
class TestReferencePages:
    def _refs(self, wire, budget_pages=1, **fed_kw):
        from repro.federated.reference import ReferenceStore
        from repro.federated.transport import Transport
        fed = FedConfig(strategy="fedadc", downlink_compressor="delta",
                        downlink_unicast=True, **fed_kw)
        t = Transport(fed, counters=Counters())
        t.set_wire_templates(wire[0], wire)
        store = PagedClientStore(budget_bytes=budget_pages * page_nbytes(wire),
                                 counters=t.counters)
        return ReferenceStore(fed, t, store=store), store

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16",
                                       "float8_e4m3fn"])
    def test_spilled_reference_reloads_downlink_bitwise(self, dtype):
        dt = jnp.dtype(dtype)
        rng = np.random.RandomState(0)
        wire = ({"w": jnp.asarray(rng.randn(16), jnp.float32).astype(dt)},
                {"m_bar": jnp.asarray(rng.randn(16),
                                      jnp.float32).astype(dt)})
        # negative zero: a value a float round-trip would normalise away
        wire[0]["w"] = wire[0]["w"].at[0].set(jnp.asarray(-0.0, dt))
        refs, store = self._refs(wire, budget_pages=1)
        refs.dispatch([0, 1, 2], 0, wire=wire)
        assert store.spilled_pages == 2, "budget must force the zlib tier"
        for c in (0, 1, 2):
            _bits_equal(refs.client_reference(c), wire)

    def test_newer_reference_supersedes_evicted_page(self):
        rng = np.random.RandomState(1)
        w0 = ({"w": jnp.asarray(rng.randn(16), jnp.float32)},
              {"m_bar": jnp.asarray(rng.randn(16), jnp.float32)})
        w1 = jax.tree.map(lambda x: x * 2.0, w0)
        refs, store = self._refs(w0, budget_pages=1)
        refs.dispatch([0, 1], 0, wire=w0)       # client 0's page spills
        assert store.spilled_pages == 1
        refs.dispatch([0], 1, wire=w1)          # newer wire over the spill
        _bits_equal(refs.client_reference(0), w1)
        _bits_equal(refs.client_reference(1), w0)
        # exactly one live version per page — the stale spill copy is gone
        assert store.resident_pages + store.spilled_pages == 2
        assert refs.client_staleness(0, 1) == 0
        assert refs.client_staleness(1, 1) == 1

    def test_simulator_pages_ride_paged_store_bitwise(self, small_data):
        """End to end: a unicast simulator over a one-page-budget paged
        store thrashes every reference page through the spill tier and
        still re-serves each client's exact last downlink; the trajectory
        is bit-identical to the host-store run."""
        x, y, xt, yt, parts = small_data
        fed = FedConfig(strategy="fedadc", n_clients=10, clients_per_round=3,
                        local_steps=2, downlink_compressor="delta",
                        downlink_unicast=True)
        sim = SimConfig(model="cnn", n_classes=10, batch_size=8, rounds=3,
                        eval_every=3, cnn_width=8, seed=0)
        host = FederatedSimulator(fed, sim, x, y, xt, yt, parts,
                                  store=ClientStore())
        host.run()
        wire_bytes = page_nbytes(jax.device_get(host.refs._wire))
        paged_store = PagedClientStore(budget_bytes=wire_bytes,
                                       counters=Counters())
        paged = FederatedSimulator(fed, sim, x, y, xt, yt, parts,
                                   store=paged_store)
        paged.run()
        _bits_equal(host.params, paged.params)
        assert paged_store.counters.snapshot()["store.spills"] > 0
        for c, v in paged.refs._client_version.items():
            if v == paged._rounds_done - 1:
                _bits_equal(paged.refs.client_reference(c),
                            paged.refs._wire)


# ---------------------------------------------------------------------------
# fleet scheduler
# ---------------------------------------------------------------------------
class TestFleetScheduler:
    def _fed(self, n=40, k=8, regions=4):
        return FedConfig(n_clients=n, clients_per_round=k,
                         fleet_regions=regions)

    def test_deterministic_under_seed(self):
        for seed in range(3):
            a = FleetScheduler(self._fed(), seed=seed)
            b = FleetScheduler(self._fed(), seed=seed)
            for _ in range(4):
                ca, cb = a.sample_cohort(), b.sample_cohort()
                np.testing.assert_array_equal(ca.clients, cb.clients)
                assert ca.sizes == cb.sizes
            np.testing.assert_array_equal(a.sample(5), b.sample(5))

    def test_cohort_is_region_major_with_shared_split(self):
        s = FleetScheduler(self._fed(n=40, k=10, regions=3))
        c = s.sample_cohort()
        assert c.sizes == region_sizes(10, 3)
        for r, (start, size) in enumerate(c.region_slices()):
            sub = c.clients[start:start + size]
            lo, n = s.bounds[r]
            assert ((sub >= lo) & (sub < lo + n)).all()
            assert len(set(sub.tolist())) == size
            assert all(s.region_of(int(cid)) == r for cid in sub)

    def test_pod_client_ids_grid(self):
        c = Cohort(np.arange(6), (3, 3))
        grid = c.pod_client_ids(2, 3)
        assert grid.shape == (2, 3) and grid.dtype == np.int32
        np.testing.assert_array_equal(grid.ravel(), np.arange(6))
        with pytest.raises(ValueError, match="pod grid"):
            c.pod_client_ids(2, 2)

    def test_class_coverage_delegation(self):
        """Per-region picks run selection.py's coverage selector on the
        region's sub-population and map back to global ids."""
        n, classes = 24, 4
        counts = np.zeros((n, classes))
        counts[np.arange(n), np.arange(n) % classes] = 5
        s = FleetScheduler(self._fed(n=n, k=8, regions=2),
                           selector="class_coverage", counts=counts, seed=0)
        c = s.sample_cohort()
        for start, size in c.region_slices():
            sub = c.clients[start:start + size]
            assert (counts[sub].sum(0) > 0).all()

    def test_speed_weights_bias_sampling(self):
        """A client with overwhelming speed weight appears in essentially
        every weighted draw."""
        het = HeteroConfig(enabled=True, speed_dist="constant")
        s = FleetScheduler(self._fed(n=10, k=2, regions=1), het, seed=0)
        s.speeds = np.ones(10)
        s.speeds[7] = 1e6
        hits = sum(7 in s.sample_cohort().clients for _ in range(50))
        assert hits >= 48

    def test_availability_thinning_never_underfills(self):
        het = HeteroConfig(enabled=True, availability=0.05, seed=1)
        s = FleetScheduler(self._fed(n=12, k=6, regions=2), het, seed=1)
        for _ in range(10):
            c = s.sample_cohort()
            assert len(c.clients) == 6
            assert len(set(c.clients.tolist())) == 6

    def test_validation(self):
        with pytest.raises(ValueError, match="selector"):
            FleetScheduler(self._fed(), selector="bogus")
        with pytest.raises(ValueError, match="counts"):
            FleetScheduler(self._fed(), selector="class_coverage")
        with pytest.raises(ValueError, match="n_regions"):
            FleetScheduler(self._fed(n=4), n_regions=5)


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def small_data():
    x, y, xt, yt = make_image_dataset(400, 100, 10, image_size=16, seed=0,
                                      noise=0.5)
    parts = sort_and_partition(y, 10, s=2, seed=0)
    return x, y, xt, yt, parts


def _sim(rounds=2):
    return SimConfig(model="cnn", n_classes=10, batch_size=16, rounds=rounds,
                     eval_every=rounds, cnn_width=8, seed=1)


def _fed(**kw):
    base = dict(strategy="fedadc", local_steps=2, clients_per_round=3,
                n_clients=10, eta=0.03, beta_global=0.6, beta_local=0.6)
    base.update(kw)
    return FedConfig(**base)


class TestEngineIntegration:
    def test_paged_store_bitwise_vs_host(self, small_data):
        """A full simulator run (top-k + EF exercises per-client state
        every round) over a paged store that cannot hold the cohort is
        bit-identical to the host-dict store."""
        x, y, xt, yt, parts = small_data
        fed = _fed(compressor="topk", topk_frac=0.2)
        a = FederatedSimulator(fed, _sim(3), x, y, xt, yt, parts)
        # a couple of pages fit (a CNN state page is ~0.75 MB) but the
        # 10-client fleet's state+EF pages do not -> steady-state spilling
        store = PagedClientStore(budget_bytes=2 << 20, counters=Counters())
        b = FederatedSimulator(fed, _sim(3), x, y, xt, yt, parts,
                               store=store)
        a.run(), b.run()
        _bits_equal(a.params, b.params)
        assert store.peak_resident_bytes <= store.budget_bytes
        assert store.counters.snapshot().get("store.spills", 0) > 0
        efa, efb = a.protocol.store.states("ef"), b.protocol.store.states("ef")
        assert sorted(efa) == sorted(efb)
        for cid in efa:
            _bits_equal(efa[cid], efb[cid])

    def test_scheduler_feeds_simulator_deterministically(self, small_data):
        x, y, xt, yt, parts = small_data
        fed = _fed(fleet_regions=3, n_clients=10, clients_per_round=6)
        runs = []
        for _ in range(2):
            sched = FleetScheduler(fed, seed=5)
            s = FederatedSimulator(fed, _sim(2), x, y, xt, yt, parts,
                                   scheduler=sched)
            s.run()
            runs.append(s.params)
        _bits_equal(runs[0], runs[1])
