"""Uplink compression subsystem: wire-format byte accounting, error-feedback
exactness (compressor-level bitwise, engine-level through the round loop),
identity-compressor bit-parity across all three engines, and the
engine/strategy validation rules (DESIGN.md §Compression)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import FedConfig, HeteroConfig, RunConfig
from repro.core import tree as T
from repro.data.partition import sort_and_partition
from repro.data.synthetic import make_image_dataset
from repro.federated import compression as C
from repro.federated.async_engine import AsyncFederatedSimulator
from repro.federated.simulator import FederatedSimulator, SimConfig


@pytest.fixture(scope="module")
def data():
    x, y, xt, yt = make_image_dataset(600, 150, 10, image_size=16, seed=0,
                                      noise=0.5)
    parts = sort_and_partition(y, 10, s=2, seed=0)
    return x, y, xt, yt, parts


def _fed(strategy="fedadc", **kw):
    base = dict(local_steps=4, clients_per_round=3, n_clients=10, eta=0.03,
                beta_global=0.6, beta_local=0.6)
    base.update(kw)
    return FedConfig(strategy=strategy, **base)


def _sim(rounds=3, **kw):
    base = dict(model="cnn", n_classes=10, batch_size=16, rounds=rounds,
                eval_every=rounds, cnn_width=8, seed=1)
    base.update(kw)
    return SimConfig(**base)


def _tree(seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {"w": jax.random.normal(k1, (64, 32)),
            "b": jax.random.normal(k2, (17,))}


def _assert_trees_equal(a, b, exact=True, atol=0.0):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        if exact:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        else:
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=0, atol=atol)


# ---------------------------------------------------------------------------
# wire-format byte accounting
# ---------------------------------------------------------------------------
class TestWireAccounting:
    def test_identity_equals_raw(self):
        t = _tree()
        assert C.IdentityCompressor().wire_nbytes(t) == C.raw_nbytes(t)
        assert C.raw_nbytes(t) == (64 * 32 + 17) * 4

    def test_topk10_reduction_at_least_5x(self):
        t = _tree()
        comp = C.TopKCompressor(0.10)
        assert C.raw_nbytes(t) / comp.wire_nbytes(t) >= 5.0

    def test_qsgd_formula(self):
        t = {"x": jnp.zeros((1000,))}
        comp = C.QSGDCompressor(4)
        # 1000 × (4 magnitude bits + 1 sign) + 32-bit scale, rounded up
        assert comp.wire_nbytes(t) == (1000 * 5 + 32 + 7) // 8

    def test_works_on_shape_structs(self):
        shapes = jax.eval_shape(lambda: _tree())
        comp = C.TopKCompressor(0.10)
        assert comp.wire_nbytes(shapes) == comp.wire_nbytes(_tree())

    def test_uplink_nbytes_dispatches_on_config(self):
        t = _tree()
        assert C.uplink_nbytes(_fed(), t) == C.raw_nbytes(t)
        assert C.uplink_nbytes(_fed(compressor="topk", topk_frac=0.1), t) \
            < C.raw_nbytes(t) / 5

    def test_validation(self):
        with pytest.raises(ValueError):
            C.get_compressor(_fed(compressor="bogus"))
        with pytest.raises(ValueError):
            C.TopKCompressor(0.0)
        with pytest.raises(ValueError):
            C.QSGDCompressor(0)
        assert C.get_compressor(_fed()) is None


# ---------------------------------------------------------------------------
# error feedback: the stored state IS the exact compression residual
# ---------------------------------------------------------------------------
class TestErrorFeedback:
    def test_topk_residual_bitwise_exact(self):
        delta, ef0 = _tree(1), T.zeros_like(_tree(1))
        comp = C.TopKCompressor(0.10)
        q, ef1 = comp.compress(delta, ef0, jax.random.PRNGKey(0))
        # select is pure masking, so q + e == v holds bitwise
        _assert_trees_equal(ef1, T.sub(delta, q), exact=True)

    def test_qsgd_residual_exact_to_ulp(self):
        delta, ef0 = _tree(2), T.zeros_like(_tree(2))
        comp = C.QSGDCompressor(4)
        q, ef1 = comp.compress(delta, ef0, jax.random.PRNGKey(0))
        _assert_trees_equal(ef1, T.sub(delta, q), exact=False, atol=1e-6)

    def test_ef_accumulates_across_calls(self):
        delta, comp = _tree(3), C.TopKCompressor(0.10)
        _, ef1 = comp.compress(delta, T.zeros_like(delta),
                               jax.random.PRNGKey(0))
        q2, ef2 = comp.compress(delta, ef1, jax.random.PRNGKey(1))
        # round 2 quantises v = Δ + e₁ and keeps exactly v − q
        _assert_trees_equal(ef2, T.sub(T.add(delta, ef1), q2), exact=True)

    def test_ef_bounded_vs_no_feedback_bias(self):
        """With EF the cumulative transported mass converges to the
        cumulative delta (residual stays one round's worth); the residual
        never grows unboundedly."""
        delta, comp = _tree(4), C.TopKCompressor(0.25)
        ef = T.zeros_like(delta)
        sent = T.zeros_like(delta)
        for i in range(30):
            q, ef = comp.compress(delta, ef, jax.random.PRNGKey(i))
            sent = T.add(sent, q)
        # Σq = 30·Δ − e_final, so the relative shortfall is e/(30·Δ)
        total = T.scale(delta, 30.0)
        err = float(T.global_norm(T.sub(total, sent))
                    / T.global_norm(total))
        assert err < 0.1, f"EF failed to drain the residual (err={err:.3f})"

    def test_engine_ef_state_is_round_residual(self, data):
        """After round 1 (single client, FedAvg) the stored EF state equals
        the raw delta minus the transported reconstruction, both recovered
        from the two params trajectories."""
        x, y, xt, yt, parts = data
        kw = dict(strategy="fedavg", clients_per_round=1)
        s_raw = FederatedSimulator(_fed(**kw), _sim(1), x, y, xt, yt, parts)
        s_cmp = FederatedSimulator(
            _fed(compressor="topk", topk_frac=0.1, **kw), _sim(1),
            x, y, xt, yt, parts)
        theta0 = s_raw.params
        s_raw.run()
        s_cmp.run()
        assert len(s_cmp.ef_states) == 1        # exactly the picked client
        (ef,) = s_cmp.ef_states.values()
        # FedAvg, one client: θ' = θ − Δ, so Δ_raw − q = θ'_cmp − θ'_raw
        expect = T.sub(s_cmp.params, s_raw.params)
        _assert_trees_equal(ef, expect, exact=False, atol=1e-5)
        # and the residual is genuinely nonzero (the compressor was lossy)
        assert float(T.global_norm(ef)) > 0
        del theta0

    def test_engine_ef_disabled_not_stored(self, data):
        x, y, xt, yt, parts = data
        s = FederatedSimulator(
            _fed(compressor="topk", topk_frac=0.1, error_feedback=False),
            _sim(2), x, y, xt, yt, parts)
        s.run()
        assert s.ef_states == {} and not s.ef_enabled


# ---------------------------------------------------------------------------
# identity compressor: bit-identical to the uncompressed path, everywhere
# ---------------------------------------------------------------------------
class TestIdentityBitParity:
    def test_simulator(self, data):
        x, y, xt, yt, parts = data
        a = FederatedSimulator(_fed(), _sim(), x, y, xt, yt, parts)
        b = FederatedSimulator(_fed(compressor="identity"), _sim(),
                               x, y, xt, yt, parts)
        a.run(), b.run()
        _assert_trees_equal(a.params, b.params, exact=True)
        assert b.uplink_bytes == b.uplink_bytes_raw > 0

    def test_async_engine(self, data):
        x, y, xt, yt, parts = data
        het = HeteroConfig()
        a = AsyncFederatedSimulator(_fed(), _sim(), het, x, y, xt, yt, parts)
        b = AsyncFederatedSimulator(_fed(compressor="identity"), _sim(), het,
                                    x, y, xt, yt, parts)
        a.run(), b.run()
        _assert_trees_equal(a.params, b.params, exact=True)

    def test_pod_engine(self):
        from repro.launch.mesh import make_host_mesh
        from repro.launch.train import init_state, make_train_step
        mcfg = ARCHS["qwen3-4b"].reduced()
        run = RunConfig(remat="none", param_dtype="float32",
                        compute_dtype="float32")
        rng = np.random.RandomState(0)
        toks = rng.randint(0, mcfg.vocab_size, size=(1, 2, 2, 2, 32))
        batch = {"tokens": jnp.asarray(toks, jnp.int32),
                 "labels": jnp.asarray(toks, jnp.int32)}
        kw = dict(strategy="fedadc", clients_per_round=2, local_steps=2,
                  eta=0.05)
        with make_host_mesh():
            state = init_state(jax.random.PRNGKey(0), mcfg,
                               FedConfig(**kw), run)
            sa, _ = make_train_step(mcfg, FedConfig(**kw), run)(state, batch)
            sb, _ = make_train_step(
                mcfg, FedConfig(compressor="identity", **kw), run)(
                    state, batch)
            _assert_trees_equal(sa["params"], sb["params"], exact=True)


# ---------------------------------------------------------------------------
# lossy engines end-to-end + validation
# ---------------------------------------------------------------------------
class TestLossyEngines:
    def test_simulator_topk_bytes_and_run(self, data):
        x, y, xt, yt, parts = data
        s = FederatedSimulator(_fed(compressor="topk", topk_frac=0.1),
                               _sim(2), x, y, xt, yt, parts)
        h = s.run()
        assert np.isfinite(h[-1]["loss"])
        assert s.uplink_bytes_raw / s.uplink_bytes >= 5.0
        assert len(s.ef_states) > 0

    def test_async_qsgd_runs_with_staleness(self, data):
        x, y, xt, yt, parts = data
        het = HeteroConfig(enabled=True, speed_dist="bimodal",
                           straggler_frac=0.3, straggler_slowdown=3.0)
        s = AsyncFederatedSimulator(
            _fed(compressor="qsgd", qsgd_bits=6, buffer_k=2), _sim(3), het,
            x, y, xt, yt, parts)
        h = s.run()
        assert np.isfinite(h[-1]["loss"])
        assert 0 < s.uplink_bytes < s.uplink_bytes_raw

    def test_async_drop_restores_ef_mass(self, data):
        """A dropped upload must not lose transported mass: the engine folds
        the undelivered reconstruction back into the client's EF memory
        (Σ arrived q + e = Σ Δ)."""
        x, y, xt, yt, parts = data
        het = HeteroConfig(enabled=True, drop_prob=0.5, seed=3)
        s = AsyncFederatedSimulator(
            _fed(compressor="topk", topk_frac=0.1), _sim(3), het,
            x, y, xt, yt, parts)
        h = s.run()
        kinds = [e[0] for e in s.event_log]
        assert "drop" in kinds, "no drop occurred; raise drop_prob/seed"
        assert np.isfinite(h[-1]["loss"])
        dropped = {e[2] for e in s.event_log if e[0] == "drop"}
        assert any(c in s.ef_states for c in dropped)

    def test_scaffold_feddyn_reject_lossy(self, data):
        x, y, xt, yt, parts = data
        for strat in ("scaffold", "feddyn"):
            with pytest.raises(ValueError, match="compressor"):
                FederatedSimulator(
                    _fed(strat, compressor="topk"), _sim(),
                    x, y, xt, yt, parts)

    def test_pod_supports_lossy_with_ef(self):
        """The old stateless-client rejection is lifted: lossy + EF on the
        pod engine builds (the sharded ClientStore carries the residuals;
        residual exactness is pinned in
        test_transport.TestPodErrorFeedback)."""
        from repro.launch.train import make_train_step
        mcfg = ARCHS["qwen3-4b"].reduced()
        step = make_train_step(mcfg, FedConfig(strategy="fedadc",
                                               compressor="qsgd"),
                               RunConfig())
        assert callable(step)

    def test_qsgd_unbiased_under_averaging(self):
        """Stochastic rounding: the mean reconstruction over many draws
        approaches the input (the property EF + momentum rely on)."""
        v = {"x": jax.random.normal(jax.random.PRNGKey(0), (256,))}
        comp = C.QSGDCompressor(3)
        acc = T.zeros_like(v)
        n = 64
        for i in range(n):
            q, _ = comp.compress(v, T.zeros_like(v), jax.random.PRNGKey(i))
            acc = T.add(acc, q)
        mean = T.scale(acc, 1.0 / n)
        err = float(T.global_norm(T.sub(mean, v)) / T.global_norm(v))
        assert err < 0.05, f"qsgd reconstruction biased (err={err:.3f})"
