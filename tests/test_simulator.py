"""Federated simulator integration tests (paper-scale engine, miniaturised)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.data.partition import dirichlet_partition, sort_and_partition
from repro.data.synthetic import make_image_dataset
from repro.federated.simulator import FederatedSimulator, SimConfig


@pytest.fixture(scope="module")
def data():
    x, y, xt, yt = make_image_dataset(1200, 300, 10, image_size=16, seed=0,
                                      noise=0.5)
    parts = sort_and_partition(y, 10, s=2, seed=0)
    return x, y, xt, yt, parts


def make_sim(data, strategy, rounds=12, **fed_kw):
    x, y, xt, yt, parts = data
    kw = dict(local_steps=4, clients_per_round=3, n_clients=10, eta=0.03,
              beta_global=0.6, beta_local=0.6)
    kw.update(fed_kw)
    fed = FedConfig(strategy=strategy, **kw)
    sim = SimConfig(model="cnn", n_classes=10, batch_size=16, rounds=rounds,
                    eval_every=rounds, cnn_width=8, seed=1)
    return FederatedSimulator(fed, sim, x, y, xt, yt, parts)


ALL_STRATEGIES = ["fedavg", "slowmo", "fedadc", "fedadc_double", "fedprox",
                  "scaffold", "feddyn", "moon", "fedgkd", "fedntd", "fedrs"]


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_strategy_runs_and_learns_something(data, strategy):
    s = make_sim(data, strategy, rounds=12)
    hist = s.run()
    assert np.isfinite(hist[-1]["loss"])
    assert hist[-1]["acc"] > 0.05           # not collapsed

def test_fedadc_plus_distill_runs(data):
    s = make_sim(data, "fedadc", rounds=12, distill=True, distill_lambda=0.35)
    hist = s.run()
    assert np.isfinite(hist[-1]["loss"]) and hist[-1]["acc"] > 0.05


def test_fedadc_improves_over_rounds(data):
    s = make_sim(data, "fedadc", rounds=40, eta=0.02)
    s.sim = s.sim  # eval_every = rounds → single final entry
    hist = s.run()
    assert hist[-1]["acc"] > 0.25, hist


def test_stateful_clients_persist(data):
    s = make_sim(data, "scaffold", rounds=4)
    s.run()
    assert len(s.client_states) > 0         # control variates stored


def test_coverage_selector_runs(data):
    x, y, xt, yt, parts = data
    fed = FedConfig(strategy="fedadc", local_steps=2, clients_per_round=5,
                    n_clients=10, eta=0.03)
    sim = SimConfig(model="cnn", n_classes=10, batch_size=16, rounds=4,
                    eval_every=4, cnn_width=8, selector="class_coverage")
    s = FederatedSimulator(fed, sim, x, y, xt, yt, parts)
    hist = s.run()
    assert np.isfinite(hist[-1]["loss"])


def test_resnet18_one_round(data):
    x, y, xt, yt, parts = data
    fed = FedConfig(strategy="fedadc", local_steps=2, clients_per_round=2,
                    n_clients=10, eta=0.03)
    sim = SimConfig(model="resnet18", n_classes=10, batch_size=8, rounds=1,
                    eval_every=1)
    s = FederatedSimulator(fed, sim, x, y, xt, yt, parts)
    hist = s.run()
    assert np.isfinite(hist[-1]["loss"])


# ---------------------------------------------------------------------------
# Falsy-default regressions: explicit 0 / falsy stored values are not "unset"
# ---------------------------------------------------------------------------
def test_run_rounds_zero_is_zero_rounds(data):
    """run(rounds=0) must run zero rounds, not fall back to sim.rounds."""
    s = make_sim(data, "fedadc", rounds=12)
    before = jnp.concatenate([x.ravel() for x in
                              jax.tree.leaves(s.params)])
    hist = s.run(rounds=0)
    after = jnp.concatenate([x.ravel() for x in jax.tree.leaves(s.params)])
    assert hist == [] and bool(jnp.array_equal(before, after))


def test_client_batches_explicit_zero_and_default(data):
    s = make_sim(data, "fedadc")                 # fed.local_steps == 4
    xb, yb = s._client_batches(0)
    assert xb.shape[0] == 4
    xb, yb = s._client_batches(0, local_steps=0)   # explicit 0, not unset
    assert xb.shape[0] == 0 and yb.shape == (0, s.sim.batch_size)
    xb, yb = s._client_batches(0, local_steps=2)
    assert xb.shape[0] == 2


def test_falsy_client_state_not_reinitialised(data):
    """A stored per-client state whose pytree is falsy (zero scalar) must be
    returned as-is, not silently replaced by a fresh init."""
    s = make_sim(data, "scaffold", rounds=1)
    s.client_states[3] = jnp.zeros(())           # falsy jnp scalar
    stacked = s._get_client_states([3])
    # old `or`-based code would return the dict from _client_state_init()
    assert not isinstance(stacked, dict)
    assert stacked.shape == (1,) and float(stacked[0]) == 0.0
