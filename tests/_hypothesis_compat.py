"""Optional-hypothesis shim.

The property tests use hypothesis when it is installed; offline containers
without the package must still *collect* every module and run the plain
pytest tests.  Importing ``given/settings/st`` from here yields the real
hypothesis API when available, and otherwise decorators that skip the
property tests cleanly.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed")(fn)
        return deco

    def settings(*_a, **_k):
        return lambda fn: fn

    class _AnyStrategy:
        """st.floats(...), st.integers(...), ... — inert placeholders."""
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
