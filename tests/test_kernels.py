"""Per-kernel validation: interpret=True Pallas vs the pure-jnp oracle in
ref.py, swept over shapes and dtypes, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import fedadc_update as FU
from repro.kernels import flash_attention as FA
from repro.kernels import kd_loss as KD
from repro.kernels import ops, ref
from repro.kernels import ssd_scan as SSD


def rand(key, shape, dtype):
    return jax.random.normal(key, shape).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,H,Hk,L,D", [
    (1, 2, 2, 128, 64),     # MHA
    (2, 4, 2, 256, 64),     # GQA group 2
    (1, 8, 1, 128, 128),    # MQA
    (1, 4, 4, 192, 64),     # L not multiple of block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(B, H, Hk, L, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(ks[0], (B, H, L, D), dtype)
    k = rand(ks[1], (B, Hk, L, D), dtype)
    v = rand(ks[2], (B, Hk, L, D), dtype)
    out = FA.flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                             interpret=True)
    expect = ref.flash_attention(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [32, 64, 128])
def test_flash_attention_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = rand(ks[0], (1, 2, 256, 64), jnp.float32)
    k = rand(ks[1], (1, 2, 256, 64), jnp.float32)
    v = rand(ks[2], (1, 2, 256, 64), jnp.float32)
    out = FA.flash_attention(q, k, v, causal=True, window=window,
                             block_q=64, block_k=64, interpret=True)
    expect = ref.flash_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


def test_flash_attention_model_layout_matches_sdpa():
    """ops.flash_attention (B,L,H,D layout) vs attention._sdpa."""
    from repro.models.attention import _sdpa, causal_window_mask
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    B, L, H, Hk, D = 2, 128, 4, 2, 64
    q = rand(ks[0], (B, L, H, D), jnp.float32)
    k = rand(ks[1], (B, L, Hk, D), jnp.float32)
    v = rand(ks[2], (B, L, Hk, D), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True)
    expect = _sdpa(q, k, v, causal_window_mask(L, L, 0))
    np.testing.assert_allclose(out, expect, atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,L,H,P,N,chunk", [
    (1, 64, 2, 16, 8, 16),
    (2, 128, 4, 32, 16, 32),
    (1, 256, 2, 64, 64, 64),     # zamba2-like state size
    (2, 96, 3, 16, 8, 32),       # L not multiple of 2*chunk
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_shapes(b, L, H, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = rand(ks[0], (b, L, H, P), dtype)
    dt = jax.nn.softplus(rand(ks[1], (b, L, H), jnp.float32))
    A_log = jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32))
    B = rand(ks[2], (b, L, H, N), dtype)
    C = rand(ks[3], (b, L, H, N), dtype)
    D = jnp.ones((H,))
    out = SSD.ssd_scan(x, dt, A_log, B, C, D, chunk=chunk, interpret=True)
    expect = ref.ssd_scan(x, dt, A_log, B, C, D)
    scale = float(jnp.abs(expect).max()) + 1e-6
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32) / scale,
                               np.asarray(expect, np.float32) / scale,
                               atol=tol)


def test_ssd_kernel_matches_chunked_jnp():
    """The model's jnp chunked path and the kernel agree (same math)."""
    from repro.models.mamba2 import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    b, L, H, P, N = 2, 128, 4, 32, 16
    x = rand(ks[0], (b, L, H, P), jnp.float32)
    dt = jax.nn.softplus(rand(ks[1], (b, L, H), jnp.float32))
    A_log = jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32))
    B = rand(ks[2], (b, L, H, N), jnp.float32)
    C = rand(ks[3], (b, L, H, N), jnp.float32)
    D = jnp.ones((H,))
    a = SSD.ssd_scan(x, dt, A_log, B, C, D, chunk=32, interpret=True)
    c = ssd_chunked(x, dt, A_log, B, C, D, chunk=32)
    np.testing.assert_allclose(a, c, atol=3e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# fused FedADC updates
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [128, 1000, 4097, 65536])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_local_update_sweep(n, dtype):
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    theta = rand(ks[0], (n,), dtype)
    g = rand(ks[1], (n,), dtype)
    m = rand(ks[2], (n,), dtype)
    out = ops.fedadc_local_update({"p": theta}, {"p": g}, {"p": m}, 0.05)
    expect = ref.fedadc_local_update(theta, g, m, 0.05)
    tol = 1e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(np.asarray(out["p"], np.float32),
                               np.asarray(expect, np.float32), atol=tol)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 3000), eta=st.floats(1e-4, 1.0),
       gamma=st.floats(-1.0, 1.0))
def test_property_server_update(n, eta, gamma):
    rng = np.random.RandomState(n)
    theta = jnp.asarray(rng.randn(n).astype(np.float32))
    m = jnp.asarray(rng.randn(n).astype(np.float32))
    d = jnp.asarray(rng.randn(n).astype(np.float32))
    t2, m2 = ops.fedadc_server_update({"p": theta}, {"p": m}, {"p": d},
                                      gamma, eta)
    te, me = ref.fedadc_server_update(theta, m, d, gamma, eta)
    np.testing.assert_allclose(t2["p"], te, atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(m2["p"], me, atol=1e-5, rtol=1e-4)


def test_fused_axpy_pytree_shapes():
    theta = {"a": jnp.ones((7, 13)), "b": jnp.arange(5, dtype=jnp.float32)}
    y = jax.tree.map(lambda x: x * 2.0, theta)
    out = jax.tree.map(lambda a, b: ops.fused_axpy(a, b, -0.5), theta, y)
    for leaf in jax.tree.leaves(out):
        np.testing.assert_allclose(leaf, jnp.zeros_like(leaf))


# ---------------------------------------------------------------------------
# KD loss
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,C", [(8, 10), (64, 37), (128, 100), (31, 257)])
def test_kd_loss_sweep(B, C):
    ks = jax.random.split(jax.random.PRNGKey(6), 4)
    s = rand(ks[0], (B, C), jnp.float32)
    t = rand(ks[1], (B, C), jnp.float32)
    y = jax.random.randint(ks[2], (B,), 0, C)
    rho = jax.random.uniform(ks[3], (C,))
    out = KD.kd_loss(s, t, y, rho, 0.35, 2.0, interpret=True)
    expect = ref.kd_loss(s, t, y, rho, 0.35, 2.0)
    np.testing.assert_allclose(out, expect, atol=1e-5, rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(lam=st.floats(0.0, 1.0), tau=st.floats(0.5, 4.0))
def test_property_kd_loss_hparams(lam, tau):
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    B, C = 16, 12
    s = rand(ks[0], (B, C), jnp.float32)
    t = rand(ks[1], (B, C), jnp.float32)
    y = jax.random.randint(ks[2], (B,), 0, C)
    rho = jax.random.uniform(ks[3], (C,))
    out = KD.kd_loss(s, t, y, rho, lam, tau, interpret=True)
    expect = ref.kd_loss(s, t, y, rho, lam, tau)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=1e-3)
    assert bool(jnp.all(jnp.isfinite(out)))


# ---------------------------------------------------------------------------
# delta-compression kernels (uplink quantise/sparsify round trips)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [128, 1000, 4097, 65536])
@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_qsgd_kernel_sweep(n, bits, dtype):
    ks = jax.random.split(jax.random.PRNGKey(8), 2)
    v = rand(ks[0], (n,), dtype)
    u = jax.random.uniform(ks[1], (n,), dtype=dtype)
    scale = jnp.max(jnp.abs(v))
    s = (1 << bits) - 1
    q, r = ops.qsgd_compress_leaf(v, u, scale, s)
    qe, re = ref.qsgd_quantize(v, u, scale, s)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(q, np.float32),
                               np.asarray(qe, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(r, np.float32),
                               np.asarray(re, np.float32), atol=tol, rtol=tol)
    # reconstruction error bounded by one quantisation step (plus dtype
    # rounding: bf16's 8-bit mantissa cannot represent 255 levels exactly)
    step = float(scale) / s
    eps = 2.0 ** -8 if dtype == jnp.bfloat16 else 2.0 ** -23
    bound = step * (1 + 1e-3) + 2 * float(scale) * eps + 1e-6
    np.testing.assert_array_less(np.abs(np.asarray(v - q, np.float32)), bound)


def test_qsgd_kernel_zero_leaf_and_padding():
    v = jnp.zeros((131,))                        # forces lane padding + scale 0
    u = jax.random.uniform(jax.random.PRNGKey(0), (131,))
    q, r = ops.qsgd_compress_leaf(v, u, jnp.max(jnp.abs(v)), 15)
    np.testing.assert_array_equal(np.asarray(q), 0.0)
    np.testing.assert_array_equal(np.asarray(r), 0.0)


@pytest.mark.parametrize("n,k", [(128, 13), (1000, 100), (4097, 1),
                                 (65536, 6554)])
def test_topk_threshold_kernel_sweep(n, k):
    v = rand(jax.random.PRNGKey(9), (n,), jnp.float32)
    thresh = jax.lax.top_k(jnp.abs(v), k)[0][-1]
    q, r = ops.topk_compress_leaf(v, thresh)
    qe, re = ref.topk_threshold_select(v, thresh)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qe))
    np.testing.assert_array_equal(np.asarray(r), np.asarray(re))
    # exactly k survivors for distinct magnitudes, and r is the exact
    # complement: q + r == v bitwise (select is pure masking)
    assert int(jnp.sum(q != 0)) == k
    np.testing.assert_array_equal(np.asarray(q + r), np.asarray(v))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 3000), frac=st.floats(0.01, 1.0))
def test_property_topk_select_conserves(n, frac):
    rng = np.random.RandomState(n)
    v = jnp.asarray(rng.randn(n).astype(np.float32))
    k = max(1, int(np.ceil(frac * n)))
    thresh = jax.lax.top_k(jnp.abs(v), k)[0][-1]
    q, r = ops.topk_compress_leaf(v, thresh)
    np.testing.assert_array_equal(np.asarray(q + r), np.asarray(v))
    assert int(jnp.sum(q != 0)) >= min(k, int(jnp.sum(v != 0)))


# ---------------------------------------------------------------------------
# weighted-delta-reduce: fp32 accumulation at bf16 (Pallas ↔ ref ↔ fp64
# oracle).  Summing K bf16 deltas in bf16 loses the aggregate to rounding
# once the partial sum's ulp outgrows the increments; both the ref path and
# the kernel must accumulate in fp32 and cast on write.
# ---------------------------------------------------------------------------
class TestWeightedReduceFp32Accumulation:
    K, N = 96, 4096          # K ≥ 64: bf16 running sums visibly drown here

    def _operands(self):
        rng = np.random.RandomState(7)
        # positive values ~1.0 so the partial sum grows monotonically —
        # the adversarial regime for low-precision accumulation
        d64 = 1.0 + 0.05 * rng.randn(self.K, self.N)
        d_bf16 = jnp.asarray(d64, jnp.bfloat16)
        w = jnp.asarray(rng.uniform(0.2, 1.0, self.K), jnp.float32)
        # the fp64 oracle consumes the bf16-rounded inputs (the wire dtype
        # is given; the accumulation precision is what is under test)
        return d_bf16, w, np.asarray(d_bf16, np.float64), np.asarray(
            w, np.float64)

    def test_ref_and_pallas_match_fp64_oracle(self):
        d, w, d64, w64 = self._operands()
        oracle = np.tensordot(w64, d64, axes=([0], [0]))
        got_ref = np.asarray(ref.weighted_delta_reduce(d, w), np.float64)
        got_pal = np.asarray(
            ops.weighted_delta_reduce({"x": d}, w)["x"], np.float64)
        # fp32 accumulation + one final bf16 rounding: within 1 bf16 ulp
        bound = np.abs(oracle) * 2.0 ** -8
        assert np.all(np.abs(got_ref - oracle) <= bound)
        assert np.all(np.abs(got_pal - oracle) <= bound)
        # and Pallas agrees with the ref path to the same resolution
        np.testing.assert_allclose(got_pal, got_ref, rtol=2.0 ** -8, atol=0)

    def test_bf16_accumulation_would_fail_this_bound(self):
        """The regression the fp32 fix closes: an in-dtype (bf16) running
        sum violates the 1-ulp bound the fixed paths satisfy."""
        d, w, d64, w64 = self._operands()
        oracle = np.tensordot(w64, d64, axes=([0], [0]))
        acc = jnp.zeros((self.N,), jnp.bfloat16)
        for i in range(self.K):                      # the old semantics
            acc = acc + w[i].astype(jnp.bfloat16) * d[i]
        bad = np.asarray(acc, np.float64)
        bound = np.abs(oracle) * 2.0 ** -8
        assert np.mean(np.abs(bad - oracle) > bound) > 0.5

    def test_weighted_mean_bf16_matches_fp64_oracle(self):
        """The aggregation entry point (both backends) at bf16."""
        from repro.federated import aggregation as A
        d, w, d64, w64 = self._operands()
        wn64 = w64 / w64.sum()
        oracle = np.tensordot(wn64, d64, axes=([0], [0]))
        bound = np.abs(oracle) * 2.0 ** -8 + 1e-7
        for use_pallas in (False, True):
            got = np.asarray(
                A.weighted_mean({"x": d}, w, use_pallas=use_pallas)["x"],
                np.float64)
            assert np.all(np.abs(got - oracle) <= bound), use_pallas

    def test_steady_state_transfer_guard(self, steady_state_guard):
        """Kernel parity under the transfer guard: after one warmup call
        (compile + H2D of operands) both the Pallas and the ref reduction
        run on device-resident operands with no implicit transfer, and
        still agree."""
        d, w, _, _ = self._operands()
        ops.weighted_delta_reduce({"x": d}, w)
        ref.weighted_delta_reduce(d, w)
        with steady_state_guard():
            got_pal = ops.weighted_delta_reduce({"x": d}, w)["x"]
            got_ref = ref.weighted_delta_reduce(d, w)
        np.testing.assert_allclose(np.asarray(got_pal, np.float64),
                                   np.asarray(got_ref, np.float64),
                                   rtol=2.0 ** -8, atol=0)

    def test_fp32_inputs_unchanged(self):
        """The fix must not perturb the existing fp32 path."""
        rng = np.random.RandomState(3)
        d = jnp.asarray(rng.randn(8, 513), jnp.float32)
        w = jnp.asarray(rng.uniform(size=8), jnp.float32)
        got = ops.weighted_delta_reduce({"x": d}, w)["x"]
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(ref.weighted_delta_reduce(d, w)),
            rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# sparse_weighted_delta_reduce: the scatter-accumulate server aggregate
# (kernels/sparse_reduce.py) vs the jnp segment-sum oracle and an fp64
# dense oracle — the sparse-native path's precision and collision contracts.
# ---------------------------------------------------------------------------
class TestSparseReduce:
    K, N = 96, 4096
    TOPK = 409               # ceil(0.1 · N)

    def _wire(self, dtype=jnp.bfloat16, k=None, n=None, K=None, seed=7):
        k = self.TOPK if k is None else k
        n = self.N if n is None else n
        K = self.K if K is None else K
        rng = np.random.RandomState(seed)
        # positive ~1.0 values: the adversarial regime for low-precision
        # accumulation (partial sums grow monotonically)
        vals = jnp.asarray(1.0 + 0.05 * rng.randn(K, k), dtype)
        # unique-per-client indices, as the top-k wire guarantees
        idx = jnp.asarray(
            np.stack([rng.choice(n, size=k, replace=False)
                      for _ in range(K)]), jnp.int32)
        w = jnp.asarray(rng.uniform(0.2, 1.0, K), jnp.float32)
        return vals, idx, w

    @pytest.mark.parametrize("shape,dtype,K,k", [
        ((64, 32), jnp.float32, 6, 97),
        ((4096,), jnp.bfloat16, 96, 409),
        ((17,), jnp.float32, 3, 5),        # k-pad + n-pad, tiny leaf
        ((), jnp.float32, 4, 1),           # scalar leaf
    ])
    def test_pallas_matches_ref_bitwise(self, shape, dtype, K, k):
        """Kernel and oracle apply the weighted updates in the same
        client-major order onto an fp32 zero buffer — bitwise equal."""
        n = int(np.prod(shape)) if shape else 1
        rng = np.random.RandomState(K * 1000 + k)
        vals = jnp.asarray(rng.randn(K, k), dtype)
        idx = jnp.asarray(rng.randint(0, n, (K, k)), jnp.int32)
        w = jnp.asarray(rng.uniform(0.2, 1.0, K), jnp.float32)
        got = ops.sparse_weighted_delta_reduce(vals, idx, w, shape, dtype)
        exp = ref.sparse_weighted_delta_reduce(vals, idx, w, shape, dtype)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))

    def test_bf16_values_fp32_accumulate_vs_fp64_oracle(self):
        """K=96 bf16 wires: fp32 accumulation keeps the aggregate within
        one bf16 ulp of the fp64 dense oracle (an in-dtype running sum
        would drown the late clients, as the dense reduce class pins)."""
        vals, idx, w = self._wire()
        oracle = np.zeros(self.N)
        wv = np.asarray(w, np.float64)[:, None] * np.asarray(vals, np.float64)
        np.add.at(oracle, np.asarray(idx).reshape(-1), wv.reshape(-1))
        bound = np.abs(oracle) * 2.0 ** -8 + 1e-7
        for fn in (ops.sparse_weighted_delta_reduce,
                   ref.sparse_weighted_delta_reduce):
            got = np.asarray(fn(vals, idx, w, (self.N,), jnp.float32),
                             np.float64)
            assert np.all(np.abs(got - oracle) <= bound), fn.__module__

    def test_duplicate_index_collisions_accumulate(self):
        """Duplicated indices within a client must scatter-ADD (the
        segment-sum semantics), not last-write-wins like decode's .set."""
        vals = jnp.asarray([[1.0, 2.0, 4.0], [8.0, 16.0, 32.0]], jnp.float32)
        idx = jnp.asarray([[5, 5, 5], [5, 5, 2]], jnp.int32)
        w = jnp.asarray([1.0, 1.0], jnp.float32)
        for fn in (ops.sparse_weighted_delta_reduce,
                   ref.sparse_weighted_delta_reduce):
            got = np.asarray(fn(vals, idx, w, (8,), jnp.float32))
            # weights applied as given (normalisation happens upstream)
            assert got[5] == 1 + 2 + 4 + 8 + 16, fn.__module__
            assert got[2] == 32.0, fn.__module__
            assert got[[0, 1, 3, 4, 6, 7]].sum() == 0.0

    def test_empty_k_edge(self):
        """A zero-width wire contributes exactly zeros (no Pallas call —
        a zero-size block cannot be tiled)."""
        w = jnp.ones((2,), jnp.float32)
        for fn in (ops.sparse_weighted_delta_reduce,
                   ref.sparse_weighted_delta_reduce):
            out = fn(jnp.zeros((2, 0)), jnp.zeros((2, 0), jnp.int32), w,
                     (8,), jnp.float32)
            np.testing.assert_array_equal(np.asarray(out), 0.0)
            assert out.shape == (8,) and out.dtype == jnp.float32

    def test_matches_dense_decode_fold(self):
        """The end-to-end contract: segment-summing the wire equals
        decoding each client dense and folding in client order (the
        off-support adds are exact +0.0 no-ops) — bitwise."""
        vals, idx, w = self._wire(dtype=jnp.float32, seed=11)
        acc = np.zeros(self.N, np.float32)
        for i in range(self.K):
            dense = np.asarray(ops.sparse_scatter_leaf(
                vals[i], idx[i], (self.N,), jnp.float32))
            acc = acc + np.float32(w[i]) * dense
        got = ops.sparse_weighted_delta_reduce(vals, idx, w, (self.N,),
                                               jnp.float32)
        np.testing.assert_array_equal(np.asarray(got), acc)

    def test_steady_state_transfer_guard(self, steady_state_guard):
        """After one warmup call, both backends aggregate device-resident
        wires with zero implicit host<->device transfers, and agree."""
        vals, idx, w = self._wire()
        args = (vals, idx, w, (self.N,), jnp.float32)
        ops.sparse_weighted_delta_reduce(*args)
        ref.sparse_weighted_delta_reduce(*args)
        with steady_state_guard():
            got_pal = ops.sparse_weighted_delta_reduce(*args)
            got_ref = ref.sparse_weighted_delta_reduce(*args)
        np.testing.assert_array_equal(np.asarray(got_pal),
                                      np.asarray(got_ref))
