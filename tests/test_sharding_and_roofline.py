"""Sharding rules (divisibility guards, TP/FSDP placement) and the HLO
collective parser behind the roofline analysis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCHS
from repro.launch import roofline as R
from repro.models.registry import get_model
from repro.sharding import specs as S

# JAX 0.4.37 AbstractMesh takes ((name, size), ...) pair tuples
MESH = AbstractMesh((("data", 16), ("model", 16)))
MESH_MP = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def _specs_for(arch):
    cfg = ARCHS[arch]
    model = get_model(cfg)
    shapes = jax.eval_shape(lambda r: model.init(r, cfg),
                            jax.random.PRNGKey(0))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    return [(path, leaf, S.spec_for_param(path, leaf.shape, MESH))
            for path, leaf in flat]


class TestParamSpecs:
    @pytest.mark.parametrize("arch", sorted(ARCHS))
    def test_all_specs_divisible(self, arch):
        """Guarded specs: every sharded dim divides its mesh axis."""
        for path, leaf, spec in _specs_for(arch):
            for dim, ax in enumerate(spec):
                if ax is None:
                    continue
                size = MESH.shape[ax]
                assert leaf.shape[dim] % size == 0, (path, leaf.shape, spec)

    @pytest.mark.parametrize("arch", ["mistral-large-123b", "qwen3-14b"])
    def test_dense_majority_params_sharded(self, arch):
        """≥95% of parameter bytes must be sharded over BOTH axes (FSDP×TP)
        for the big dense archs — replicated big tensors blow HBM."""
        tot, both = 0, 0
        for path, leaf, spec in _specs_for(arch):
            n = int(np.prod(leaf.shape))
            tot += n
            axes = {a for a in spec if a is not None}
            if {"data", "model"} <= axes:
                both += n
        assert both / tot > 0.95, f"only {both/tot:.1%} fully sharded"

    def test_moe_experts_expert_parallel(self):
        for path, leaf, spec in _specs_for("deepseek-v3-671b"):
            keys = "/".join(str(getattr(p, "key", p)) for p in path)
            if "experts" in keys:
                assert spec[1] == "model", (keys, spec)  # E dim (after layer stack)

    def test_row_vs_column_parallel(self):
        cfg = ARCHS["qwen3-14b"]
        model = get_model(cfg)
        shapes = jax.eval_shape(lambda r: model.init(r, cfg),
                                jax.random.PRNGKey(0))
        flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
        by_name = {}
        for path, leaf in flat:
            keys = [str(getattr(p, "key", p)) for p in path]
            if len(keys) >= 2 and keys[-1] == "w":
                by_name[keys[-2]] = S.spec_for_param(path, leaf.shape, MESH)
        # column-parallel: output dim on "model"; row-parallel: input dim
        assert by_name["wq"][-1] == "model"
        assert by_name["wo"][-2] == "model"
        assert by_name["gate"][-1] == "model"
        assert by_name["down"][-2] == "model"


class TestCacheSpecs:
    def test_kv_cache_divisibility(self):
        cfg = ARCHS["qwen3-4b"]
        model = get_model(cfg)
        cache = jax.eval_shape(lambda: model.init_cache(cfg, 128, 1024,
                                                        jnp.bfloat16))
        flat = jax.tree_util.tree_flatten_with_path(cache)[0]
        for path, leaf in flat:
            spec = S.spec_for_cache(path, leaf.shape, MESH)
            for dim, ax in enumerate(spec):
                if ax is not None:
                    assert leaf.shape[dim] % MESH.shape[ax] == 0

    def test_batch1_long_context_never_shards_batch(self):
        cfg = ARCHS["zamba2-1.2b"]
        model = get_model(cfg)
        cache = jax.eval_shape(lambda: model.init_cache(cfg, 1, 4096,
                                                        jnp.bfloat16))
        flat = jax.tree_util.tree_flatten_with_path(cache)[0]
        for path, leaf in flat:
            spec = S.spec_for_cache(path, leaf.shape, MESH)
            for dim, ax in enumerate(spec):
                if ax is not None:
                    assert leaf.shape[dim] >= MESH.shape[ax]


class TestCollectiveParser:
    HLO = """
  ENTRY %main {
    %ag = bf16[32,4096]{1,0} all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
    %ar = f32[1024]{0} all-reduce(%p1), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add
    %rs = f32[128]{0} reduce-scatter(%p2), replica_groups={{0,1}}, dimensions={0}
    %cp = bf16[64,64]{1,0} collective-permute(%p3), source_target_pairs={{0,1},{1,0}}
    %a2a = (f32[16]{0}, f32[16]{0}) all-to-all(%p4, %p5), replica_groups={{0,1}}
    %mm = f32[256,256]{1,0} dot(%a, %b)
  }
  """

    def test_counts(self):
        st = R.parse_collectives(self.HLO)
        assert st.counts["all-gather"] == 1
        assert st.counts["all-reduce"] == 1
        assert st.counts["reduce-scatter"] == 1
        assert st.counts["collective-permute"] == 1
        assert st.counts["all-to-all"] == 1

    def test_bytes(self):
        st = R.parse_collectives(self.HLO)
        assert st.result_bytes["all-gather"] == 32 * 4096 * 2
        assert st.result_bytes["all-reduce"] == 1024 * 4
        assert st.result_bytes["all-to-all"] == 2 * 16 * 4  # tuple result

    def test_wire_factors(self):
        st = R.parse_collectives(self.HLO)
        # ar: 2*(8-1)/8 × 4096B; ag: (4-1)/4 × 262144B; rs: 1/2×512B;
        # cp: 1×8192B; a2a: 1/2×128B
        expect = (2 * 7 / 8) * 4096 + (3 / 4) * 262144 + 0.5 * 512 \
            + 8192 + 0.5 * 128
        np.testing.assert_allclose(st.wire_bytes, expect)

    def test_ignores_non_collectives(self):
        st = R.parse_collectives("%x = f32[8]{0} add(%a, %b)")
        assert st.wire_bytes == 0

    def test_dominant_term(self):
        rl = R.Roofline(flops=197e12, bytes_accessed=819e9 * 3,
                        wire_bytes=50e9, chips=256,
                        collectives=R.parse_collectives(""),
                        per_device_hbm=0)
        assert rl.dominant == "memory"
        np.testing.assert_allclose(rl.compute_s, 1.0)
        np.testing.assert_allclose(rl.memory_s, 3.0)
        np.testing.assert_allclose(rl.collective_s, 1.0)


class TestModelFlops:
    def test_dense_train(self):
        from repro.configs.base import SHAPES
        cfg = ARCHS["qwen3-14b"]
        f = R.model_flops_per_round(cfg, SHAPES["train_4k"])
        expect = 6 * cfg.param_count() * 256 * 4096
        np.testing.assert_allclose(f, expect)

    def test_moe_uses_active(self):
        from repro.configs.base import SHAPES
        cfg = ARCHS["deepseek-v3-671b"]
        f = R.model_flops_per_round(cfg, SHAPES["prefill_32k"])
        expect = 2 * cfg.active_param_count() * 32 * 32768
        np.testing.assert_allclose(f, expect)
