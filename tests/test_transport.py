"""Unified round-protocol API (DESIGN.md §Transport): identity-transport
bit-exactness on all three engines, ClientStore gather/scatter round trips
(host and sharded backends), the sparse top-k wire path vs the dense
reconstruction oracle, pod-engine top-k+EF residual exactness, measured
downlink accounting, and the deprecation-shim contract (warn once, engines
and examples warning-clean).

The delta-downlink sections gate the momentum-aware reference-coded
broadcast: ``delta+identity`` bit-identical to the plain broadcast on all
three engines (the CI engine-parity matrix's second codec axis), the
per-direction knobs, the stateful reference lifecycle (incl. async
versioning under staleness and the pod train-state residency), 0-byte
derived ctx for FedADC, dispatch-not-completion downlink accounting, the
(params, None) broadcast round trip, and the wire-keyed shim cache."""
import pathlib
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, st

from repro.configs import ARCHS
from repro.configs.base import FedConfig, HeteroConfig, RunConfig
from repro.core import tree as T
from repro.core.strategies import get_strategy
from repro.data.partition import sort_and_partition
from repro.data.synthetic import make_image_dataset
from repro.federated import store as CS
from repro.federated.async_engine import AsyncFederatedSimulator
from repro.federated.protocol import RoundProtocol
from repro.federated.simulator import FederatedSimulator, SimConfig
from repro.federated import compression as C
from repro.federated.transport import (DeltaDownlinkCodec, SparseLeaf,
                                       SparseTopKCodec, Transport,
                                       make_codec, shim_transport)


@pytest.fixture(scope="module")
def data():
    x, y, xt, yt = make_image_dataset(600, 150, 10, image_size=16, seed=0,
                                      noise=0.5)
    parts = sort_and_partition(y, 10, s=2, seed=0)
    return x, y, xt, yt, parts


def _fed(strategy="fedadc", **kw):
    base = dict(local_steps=4, clients_per_round=3, n_clients=10, eta=0.03,
                beta_global=0.6, beta_local=0.6)
    base.update(kw)
    return FedConfig(strategy=strategy, **base)


def _sim(rounds=3, **kw):
    base = dict(model="cnn", n_classes=10, batch_size=16, rounds=rounds,
                eval_every=rounds, cnn_width=8, seed=1)
    base.update(kw)
    return SimConfig(**base)


def _tree(seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {"w": jax.random.normal(k1, (64, 32)),
            "b": jax.random.normal(k2, (17,))}


def _assert_trees_equal(a, b, exact=True, atol=0.0):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        if exact:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        else:
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=0, atol=atol)


# ---------------------------------------------------------------------------
# identity transport: bit-identical to the codec-bypass (pre-redesign) round
# loop, on every engine and in BOTH wire directions
# ---------------------------------------------------------------------------
class TestIdentityTransportSync:
    def test_simulator_bit_exact(self, data):
        x, y, xt, yt, parts = data
        a = FederatedSimulator(_fed(), _sim(), x, y, xt, yt, parts)
        b = FederatedSimulator(
            _fed(compressor="identity", downlink_compressor="identity"),
            _sim(), x, y, xt, yt, parts)
        a.run(), b.run()
        _assert_trees_equal(a.params, b.params, exact=True)
        assert b.uplink_bytes == b.uplink_bytes_raw > 0
        assert b.downlink_bytes == b.downlink_bytes_raw > 0

    def test_steady_state_transfer_guard(self, data, steady_state_guard):
        """After a warmup run (compile + initial H2D), further rounds must
        perform no implicit host<->device transfer (DESIGN.md §Static
        analysis): batches/eval go through the explicit jnp.asarray /
        device_get wire points only."""
        x, y, xt, yt, parts = data
        s = FederatedSimulator(_fed(), _sim(2), x, y, xt, yt, parts)
        s.run()
        with steady_state_guard():
            s.run(2)

    def test_downlink_accounting_includes_ctx(self, data):
        """FedADC's broadcast carries θ_t AND m̄_t — the measured downlink
        must be 2× the uplink's raw parameter bytes (the paper's naive
        accounting, now measured from the actual wire tree)."""
        x, y, xt, yt, parts = data
        s = FederatedSimulator(_fed("fedadc"), _sim(1), x, y, xt, yt, parts)
        s.run()
        assert s.downlink_bytes_raw == 2 * s.uplink_bytes_raw
        f = FederatedSimulator(_fed("fedavg"), _sim(1), x, y, xt, yt, parts)
        f.run()
        assert f.downlink_bytes_raw == f.uplink_bytes_raw  # empty ctx


class TestIdentityTransportAsync:
    def test_async_bit_exact(self, data):
        x, y, xt, yt, parts = data
        het = HeteroConfig()
        a = AsyncFederatedSimulator(_fed(), _sim(), het, x, y, xt, yt, parts)
        b = AsyncFederatedSimulator(
            _fed(compressor="identity", downlink_compressor="identity"),
            _sim(), het, x, y, xt, yt, parts)
        a.run(), b.run()
        _assert_trees_equal(a.params, b.params, exact=True)
        assert b.downlink_bytes == b.downlink_bytes_raw > 0

    def test_steady_state_transfer_guard(self, data, steady_state_guard):
        x, y, xt, yt, parts = data
        het = HeteroConfig()
        s = AsyncFederatedSimulator(_fed(), _sim(2), het, x, y, xt, yt,
                                    parts)
        s.run()
        with steady_state_guard():
            s.run(2)

    def test_async_downlink_paid_at_dispatch(self, data):
        """Every dispatch (including redispatches) pays one broadcast, so
        downlink clients ≥ uplink clients (drops lose the upload only)."""
        x, y, xt, yt, parts = data
        het = HeteroConfig(enabled=True, drop_prob=0.5, seed=3)
        s = AsyncFederatedSimulator(_fed(), _sim(), het, x, y, xt, yt, parts)
        s.run()
        per_up = s.transport._up_raw
        per_down = s.transport._down_raw
        assert s.downlink_bytes_raw // per_down \
            > s.uplink_bytes_raw // per_up


class TestIdentityTransportPod:
    def test_pod_bit_exact(self):
        from repro.launch.mesh import make_host_mesh
        from repro.launch.train import init_state, make_train_step
        mcfg = ARCHS["qwen3-4b"].reduced()
        run = RunConfig(remat="none", param_dtype="float32",
                        compute_dtype="float32")
        rng = np.random.RandomState(0)
        toks = rng.randint(0, mcfg.vocab_size, size=(1, 2, 2, 2, 32))
        batch = {"tokens": jnp.asarray(toks, jnp.int32),
                 "labels": jnp.asarray(toks, jnp.int32)}
        kw = dict(strategy="fedadc", clients_per_round=2, local_steps=2,
                  eta=0.05)
        with make_host_mesh():
            state = init_state(jax.random.PRNGKey(0), mcfg,
                               FedConfig(**kw), run)
            sa, _ = make_train_step(mcfg, FedConfig(**kw), run)(state, batch)
            sb, _ = make_train_step(
                mcfg, FedConfig(compressor="identity",
                                downlink_compressor="identity", **kw),
                run)(state, batch)
            _assert_trees_equal(sa["params"], sb["params"], exact=True)

    def test_steady_state_transfer_guard(self, steady_state_guard):
        """One warmup step compiles the round; the next step runs entirely
        on device-resident state + batch with zero implicit transfers."""
        from repro.launch.mesh import make_host_mesh
        from repro.launch.train import init_state, make_train_step
        mcfg = ARCHS["qwen3-4b"].reduced()
        run = RunConfig(remat="none", param_dtype="float32",
                        compute_dtype="float32")
        rng = np.random.RandomState(0)
        toks = rng.randint(0, mcfg.vocab_size, size=(1, 2, 2, 2, 32))
        batch = {"tokens": jnp.asarray(toks, jnp.int32),
                 "labels": jnp.asarray(toks, jnp.int32)}
        fed = FedConfig(strategy="fedadc", clients_per_round=2,
                        local_steps=2, eta=0.05)
        with make_host_mesh():
            state = init_state(jax.random.PRNGKey(0), mcfg, fed, run)
            step = jax.jit(make_train_step(mcfg, fed, run))
            state, _ = step(state, batch)
            with steady_state_guard():
                state, m = step(state, batch)
            assert np.isfinite(float(jax.device_get(m["loss"])))


# ---------------------------------------------------------------------------
# delta (reference-coded) downlink: the lossless configuration is
# bit-identical to the plain broadcast on every engine — the CI
# engine-parity matrix's downlink_compressor ∈ {none, delta+identity} axis
# ---------------------------------------------------------------------------
class TestDeltaTransportSync:
    def test_simulator_bit_exact(self, data):
        x, y, xt, yt, parts = data
        a = FederatedSimulator(_fed(), _sim(), x, y, xt, yt, parts)
        b = FederatedSimulator(_fed(downlink_compressor="delta"),
                               _sim(), x, y, xt, yt, parts)
        a.run(), b.run()
        _assert_trees_equal(a.params, b.params, exact=True)

    def test_steady_state_transfer_guard(self, data, steady_state_guard):
        """The reference-coded downlink keeps its state (ref tree) on
        device: steady-state rounds stay implicit-transfer-free."""
        x, y, xt, yt, parts = data
        s = FederatedSimulator(_fed(downlink_compressor="delta"), _sim(2),
                               x, y, xt, yt, parts)
        s.run()
        with steady_state_guard():
            s.run(2)

    def test_downlink_bytes_steady_state_1x_theta(self, data):
        """FedADC under the Δm̄ codec: round 0 pays the full (θ, m̄) initial
        sync, every later round pays θ-delta bytes only (the derived ctx is
        0 bytes) — so measured downlink tends to 1× raw θ while the raw
        baseline stays at 2×."""
        x, y, xt, yt, parts = data
        R = 4
        s = FederatedSimulator(_fed(downlink_compressor="delta"), _sim(R),
                               x, y, xt, yt, parts)
        s.run()
        per_up = s.transport._up_raw            # raw θ bytes per client
        clients = s.fed.clients_per_round
        assert s.downlink_bytes_raw == R * clients * 2 * per_up
        assert s.downlink_bytes == clients * (2 + (R - 1)) * per_up
        # steady state: one more round costs exactly 1× θ per client
        assert s.transport._down_nbytes == per_up

    def test_lossy_delta_converges_like_plain(self, data):
        """delta+qsgd8 trains (the reference self-corrects coding error);
        the run completes with finite loss and nonzero accuracy."""
        x, y, xt, yt, parts = data
        s = FederatedSimulator(
            _fed(downlink_compressor="delta+qsgd", downlink_qsgd_bits=8),
            _sim(), x, y, xt, yt, parts)
        hist = s.run()
        assert np.isfinite(hist[-1]["loss"])
        assert s.downlink_bytes < s.downlink_bytes_raw


class TestDeltaTransportAsync:
    def test_async_bit_exact(self, data):
        x, y, xt, yt, parts = data
        het = HeteroConfig()
        a = AsyncFederatedSimulator(_fed(), _sim(), het, x, y, xt, yt, parts)
        b = AsyncFederatedSimulator(_fed(downlink_compressor="delta"),
                                    _sim(), het, x, y, xt, yt, parts)
        a.run(), b.run()
        _assert_trees_equal(a.params, b.params, exact=True)
        assert b.downlink_bytes < b.downlink_bytes_raw

    def test_steady_state_transfer_guard(self, data, steady_state_guard):
        x, y, xt, yt, parts = data
        het = HeteroConfig()
        s = AsyncFederatedSimulator(_fed(downlink_compressor="delta"),
                                    _sim(2), het, x, y, xt, yt, parts)
        s.run()
        with steady_state_guard():
            s.run(2)

    def test_downlink_counts_dispatches_not_completions(self, data):
        """Clients whose uploads are dropped still received the broadcast:
        measured downlink bytes count dispatch events (version-0 dispatches
        at the full-resync rate, later ones at the delta rate), uplink
        bytes count arrivals only."""
        x, y, xt, yt, parts = data
        het = HeteroConfig(enabled=True, drop_prob=0.4, seed=5)
        s = AsyncFederatedSimulator(_fed(downlink_compressor="delta"),
                                    _sim(4), het, x, y, xt, yt, parts)
        s.run()
        disp = [e for e in s.event_log if e[0] == "dispatch"]
        arr = [e for e in s.event_log if e[0] == "arrive"]
        drops = [e for e in s.event_log if e[0] == "drop"]
        assert drops, "drop_prob=0.4 must actually drop uploads"
        assert len(disp) > len(arr)
        t = s.transport
        assert s.uplink_bytes_raw == len(arr) * t._up_raw
        assert s.downlink_bytes_raw == len(disp) * t._down_raw
        n0 = sum(1 for e in disp if e[3] == 0)     # version-0 dispatches
        assert s.downlink_bytes == \
            n0 * t._down_raw + (len(disp) - n0) * t._down_nbytes

    def test_reconstruction_matches_dispatch_version_under_staleness(
            self, data):
        """Δm̄-codec reconstruction under staleness > 0: one broadcast per
        server version (the reference advances exactly once per version),
        every dispatch at version v hands out that same reconstruction, and
        a stale delta was therefore computed against the reference version
        it was dispatched with, not the one current at arrival."""
        x, y, xt, yt, parts = data
        het = HeteroConfig(enabled=True, speed_dist="bimodal",
                           straggler_frac=0.4, straggler_slowdown=4.0,
                           seed=0)
        eng = AsyncFederatedSimulator(
            _fed(downlink_compressor="delta+qsgd", downlink_qsgd_bits=8,
                 buffer_k=1), _sim(6), het, x, y, xt, yt, parts)
        rec = {}
        orig = eng._broadcast

        def spy():
            params_now = jax.tree.map(np.asarray, eng.params)
            pw, cx = orig()
            v = eng.version
            got = jax.tree.map(np.asarray, pw)
            if v in rec:
                # memoised: every dispatch at version v gets the same wire
                _assert_trees_equal(rec[v]["pw"], got, exact=True)
            else:
                rec[v] = {"pw": got, "params": params_now,
                          "ref": jax.tree.map(np.asarray,
                                              eng.refs.reference()[0])}
            return pw, cx

        eng._broadcast = spy
        eng.run()
        assert eng.staleness_hist.max > 0, "fleet must actually go stale"
        disp_versions = {e[3] for e in eng.event_log if e[0] == "dispatch"}
        assert set(rec) == disp_versions
        for v, r in rec.items():
            # the reference advanced to this version's reconstruction ...
            _assert_trees_equal(r["ref"], r["pw"], exact=True)
            if v > 0:
                # ... which is genuinely the lossy wire, not the raw θ_v
                diff = max(float(np.max(np.abs(a - b))) for a, b in zip(
                    jax.tree.leaves(r["pw"]), jax.tree.leaves(r["params"])))
                assert diff > 0


class TestDeltaTransportPod:
    def _setup(self, **fed_kw):
        from repro.launch.mesh import make_host_mesh
        mcfg = ARCHS["qwen3-4b"].reduced()
        run = RunConfig(remat="none", param_dtype="float32",
                        compute_dtype="float32")
        rng = np.random.RandomState(0)
        toks = rng.randint(0, mcfg.vocab_size, size=(1, 2, 2, 2, 32))
        batch = {"tokens": jnp.asarray(toks, jnp.int32),
                 "labels": jnp.asarray(toks, jnp.int32)}
        kw = dict(strategy="fedadc", clients_per_round=2, local_steps=2,
                  eta=0.05)
        kw.update(fed_kw)
        return make_host_mesh(), mcfg, run, batch, FedConfig(**kw)

    def test_pod_bit_exact(self):
        from repro.launch.train import init_state, make_train_step
        mesh, mcfg, run, batch, fed_plain = self._setup()
        _, _, _, _, fed_delta = self._setup(downlink_compressor="delta")
        with mesh:
            sa = init_state(jax.random.PRNGKey(0), mcfg, fed_plain, run)
            sd = init_state(jax.random.PRNGKey(0), mcfg, fed_delta, run)
            # the lossless delta downlink is stateless: NEITHER train state
            # carries a broadcast reference (the codec derives it from θ_t)
            for key in ("refs", "downlink_ref"):
                assert key not in sd and key not in sa
            step_a = make_train_step(mcfg, fed_plain, run)
            step_d = make_train_step(mcfg, fed_delta, run)
            # two rounds: the reference must thread through the train state
            for _ in range(2):
                sa, _ = step_a(sa, batch)
                sd, _ = step_d(sd, batch)
            _assert_trees_equal(sa["params"], sd["params"], exact=True)

    def test_steady_state_transfer_guard(self, steady_state_guard):
        from repro.launch.train import init_state, make_train_step
        mesh, mcfg, run, batch, fed = self._setup(
            downlink_compressor="delta")
        with mesh:
            state = init_state(jax.random.PRNGKey(0), mcfg, fed, run)
            step = jax.jit(make_train_step(mcfg, fed, run))
            state, _ = step(state, batch)
            with steady_state_guard():
                state, m = step(state, batch)
            assert np.isfinite(float(jax.device_get(m["loss"])))

    def test_pod_ref_tracks_broadcast(self):
        """Lossy delta: after round t, state["refs"]["downlink"] is the
        round-t broadcast *reconstruction* — the tree the clients now hold.
        Round 1's delta against the initial-sync reference is exactly zero,
        so its reconstruction is θ_0 bitwise; round 2's is genuinely lossy
        and matches an eager replication of the codec."""
        from repro.launch.train import (init_state, make_train_step,
                                        _broadcast_inputs)
        from repro.core.strategies import get_strategy
        mesh, mcfg, run, batch, fed = self._setup(
            downlink_compressor="delta+topk", downlink_topk_frac=0.1)
        with mesh:
            s0 = init_state(jax.random.PRNGKey(0), mcfg, fed, run)
            assert "refs" in s0 and "downlink_ref" not in s0
            step = make_train_step(mcfg, fed, run)
            s1, _ = step(s0, batch)
            # round-0 delta is exact: the reference IS θ_0
            _assert_trees_equal(s1["refs"]["downlink"][0], s0["params"],
                                exact=True)
            s2, _ = step(s1, batch)
            # eager replication of round 2's broadcast against R_1
            strategy = get_strategy(fed.strategy)
            theta_t, _, ctx, _ = _broadcast_inputs(
                strategy, s1["params"], s1["server"], fed, run)
            dkey = jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(run.seed),
                                   s1["round"]), 0xD0)
            _, _, ref2 = step.transport.broadcast(
                theta_t, ctx, dkey, s1["refs"]["downlink"])
            _assert_trees_equal(s2["refs"]["downlink"][0], ref2[0],
                                exact=False, atol=1e-6)
            # ... and the reconstruction is genuinely lossy, not θ_1
            diff = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
                       for a, b in zip(
                           jax.tree.leaves(s2["refs"]["downlink"][0]),
                           jax.tree.leaves(s1["params"])))
            assert diff > 0

    def test_pod_delta_ref_lowers_through_dryrun_inputs(self):
        """state_inputs grows the sharded reference and the jit'd round
        still lowers on the (1×1 host) mesh."""
        from repro.launch import inputs as I
        from repro.launch.mesh import make_host_mesh
        from repro.launch.train import make_train_step
        from repro.configs.base import ShapeConfig
        mcfg = ARCHS["qwen3-4b"].reduced()
        fed = FedConfig(strategy="fedadc", clients_per_round=2,
                        local_steps=2, eta=0.05,
                        downlink_compressor="delta+topk",
                        downlink_topk_frac=0.1)
        run = RunConfig(remat="none")
        shape = ShapeConfig("train_small", seq_len=64, global_batch=16,
                            kind="train")
        mesh = make_host_mesh()
        with mesh:
            state_sds = I.state_inputs(mcfg, fed, run, mesh)
            assert "refs" in state_sds and "downlink_ref" not in state_sds
            batch_sds = I.train_inputs(mcfg, shape, fed, mesh, False)
            step = make_train_step(mcfg, fed, run)
            compiled = jax.jit(step).lower(state_sds, batch_sds).compile()
            assert compiled.cost_analysis() is not None

    def test_pod_lossless_delta_state_carries_no_ref(self):
        """The lossless delta config drops the reference from the pod train
        state entirely — dryrun shape pin for the one-mechanism invariant."""
        from repro.launch import inputs as I
        from repro.launch.mesh import make_host_mesh
        mcfg = ARCHS["qwen3-4b"].reduced()
        fed = FedConfig(strategy="fedadc", clients_per_round=2,
                        local_steps=2, eta=0.05,
                        downlink_compressor="delta")
        run = RunConfig(remat="none")
        with make_host_mesh() as mesh:
            state_sds = I.state_inputs(mcfg, fed, run, mesh)
            assert "refs" not in state_sds
            assert "downlink_ref" not in state_sds


# ---------------------------------------------------------------------------
# unicast downlink (per-client catch-up resync): under full participation the
# per-client classification degenerates to the multicast schedule — bytes AND
# trajectory must match bit-for-bit on every engine (the CI engine-parity
# matrix's Unicast axis); under partial participation the ReferenceStore's
# catch-up/resync split is the new accounting
# ---------------------------------------------------------------------------
class TestUnicastTransportSync:
    @pytest.mark.parametrize("codec", ["delta", "delta+identity"])
    def test_full_participation_matches_multicast(self, data, codec):
        x, y, xt, yt, parts = data
        kw = dict(downlink_compressor=codec, clients_per_round=10,
                  n_clients=10)
        a = FederatedSimulator(_fed(**kw), _sim(), x, y, xt, yt, parts)
        b = FederatedSimulator(_fed(downlink_unicast=True, **kw), _sim(),
                               x, y, xt, yt, parts)
        a.run(), b.run()
        _assert_trees_equal(a.params, b.params, exact=True)
        # round 0: every client is never-seen → full resync ≡ the multicast
        # initial sync; later rounds: staleness 1 ≤ horizon → catch-up at
        # exactly the multicast delta rate
        assert b.downlink_bytes == a.downlink_bytes > 0
        assert b.downlink_bytes_raw == a.downlink_bytes_raw
        assert int(b.refs.resyncs) == 10
        assert int(b.refs.catchups) == 10 * (b.sim.rounds - 1)

    def test_partial_participation_catchup_accounting(self, data):
        x, y, xt, yt, parts = data
        s = FederatedSimulator(
            _fed(downlink_compressor="delta", downlink_unicast=True,
                 resync_horizon=100), _sim(6), x, y, xt, yt, parts)
        s.run()
        t = s.transport
        # measured bytes are exactly the per-client ledger's sum, and every
        # dispatch landed in exactly one class
        assert s.downlink_bytes == sum(s.refs.client_bytes.values())
        n_disp = 6 * s.fed.clients_per_round
        n_resync, n_catchup = int(s.refs.resyncs), int(s.refs.catchups)
        assert n_resync + n_catchup == n_disp   # sync never re-hits fresh
        assert s.downlink_bytes == \
            n_resync * t._down_raw + n_catchup * t._down_nbytes
        assert s.downlink_bytes_raw == n_disp * t._down_raw
        # horizon 0 forces a full resync on every revisit — strictly more
        # bytes than the catch-up schedule for the same trajectory
        h0 = FederatedSimulator(
            _fed(downlink_compressor="delta", downlink_unicast=True,
                 resync_horizon=0), _sim(6), x, y, xt, yt, parts)
        h0.run()
        _assert_trees_equal(s.params, h0.params, exact=True)
        assert h0.downlink_bytes > s.downlink_bytes

    def test_reference_pages_roundtrip(self, data):
        """Each dispatched client's page in the store's "downlink_ref"
        namespace holds the wire it was last handed — the engine can
        re-serve a client's exact downlink without the global state."""
        x, y, xt, yt, parts = data
        s = FederatedSimulator(
            _fed(downlink_compressor="delta", downlink_unicast=True),
            _sim(), x, y, xt, yt, parts)
        s.run()
        last_v = s._rounds_done - 1
        served = [c for c, v in s.refs._client_version.items() if v == last_v]
        assert served, "someone was dispatched in the last round"
        for c in served:
            page = s.refs.client_reference(c)
            _assert_trees_equal(page, s.refs._wire, exact=True)
        # a client never dispatched has no page
        never = set(range(s.n_clients)) - set(s.refs._client_version)
        for c in never:
            assert s.refs.client_reference(c) is None

    def test_unicast_validation(self):
        with pytest.raises(ValueError, match="lossless delta"):
            Transport(_fed(downlink_compressor="identity",
                           downlink_unicast=True))
        with pytest.raises(ValueError, match="lossless delta"):
            Transport(_fed(downlink_compressor="delta+qsgd",
                           downlink_qsgd_bits=8, downlink_unicast=True))
        with pytest.raises(ValueError, match="resync_horizon"):
            Transport(_fed(downlink_compressor="delta",
                           downlink_unicast=True, resync_horizon=-1))


class TestUnicastTransportAsync:
    def test_full_participation_matches_multicast(self, data):
        """Unicast is an accounting layer: the trained trajectory is the
        multicast one bit-for-bit, and with every client re-dispatched at
        most once per version the measured bytes agree too."""
        x, y, xt, yt, parts = data
        het = HeteroConfig()
        kw = dict(downlink_compressor="delta", clients_per_round=10,
                  n_clients=10, buffer_k=10)
        a = AsyncFederatedSimulator(_fed(**kw), _sim(), het, x, y, xt, yt,
                                    parts)
        b = AsyncFederatedSimulator(_fed(downlink_unicast=True, **kw),
                                    _sim(), het, x, y, xt, yt, parts)
        a.run(), b.run()
        _assert_trees_equal(a.params, b.params, exact=True)
        assert b.downlink_bytes == a.downlink_bytes > 0
        assert b.downlink_bytes_raw == a.downlink_bytes_raw

    def test_staleness_splits_catchup_resync(self, data):
        """A straggling fleet under a tight horizon: fast clients ride the
        cheap catch-up path, clients stale past the horizon pay the full
        resync — both classes must actually occur and the measured bytes
        must reproduce the split exactly."""
        x, y, xt, yt, parts = data
        het = HeteroConfig(enabled=True, speed_dist="bimodal",
                           straggler_frac=0.4, straggler_slowdown=8.0,
                           seed=0)
        s = AsyncFederatedSimulator(
            _fed(downlink_compressor="delta", downlink_unicast=True,
                 resync_horizon=1, buffer_k=1), _sim(8), het, x, y, xt, yt,
            parts)
        s.run()
        t = s.transport
        n_resync, n_catchup = int(s.refs.resyncs), int(s.refs.catchups)
        assert n_resync > 0 and n_catchup > 0
        n_disp = sum(1 for e in s.event_log if e[0] == "dispatch")
        n_fresh = n_disp - n_resync - n_catchup
        assert n_fresh >= 0
        assert s.downlink_bytes == \
            n_resync * t._down_raw + n_catchup * t._down_nbytes
        assert s.downlink_bytes == sum(s.refs.client_bytes.values())

    def test_bookkeeping_stays_bounded_over_long_runs(self, data):
        """Dynamic counterpart of the unbounded-host-accumulator lint: the
        unicast ledger is keyed per client, so arbitrarily many rounds hold
        its size at O(n_clients) — no per-dispatch growth."""
        x, y, xt, yt, parts = data
        het = HeteroConfig(enabled=True, speed_dist="bimodal",
                           straggler_frac=0.3, straggler_slowdown=4.0,
                           seed=1)
        s = AsyncFederatedSimulator(
            _fed(downlink_compressor="delta", downlink_unicast=True,
                 resync_horizon=2, buffer_k=1), _sim(4), het, x, y, xt, yt,
            parts)
        n = s.n_clients
        sizes = []
        for _ in range(3):          # repeated runs must not re-grow state
            s.run(4)
            for d in (s.refs._client_version, s.refs.client_bytes,
                      s.refs.client_catchups, s.refs.client_resyncs):
                assert len(d) <= n
            sizes.append(len(s.refs._client_version))
        n_disp = sum(1 for e in s.event_log if e[0] == "dispatch")
        assert n_disp > n, "the bound must actually be exercised"
        # the ledger only ever tracks the visited-client set — it grows
        # toward the population, never with the dispatch count
        assert sizes == sorted(sizes) and sizes[-1] <= n < n_disp


class TestUnicastTransportPod:
    def test_full_participation_matches_multicast(self):
        from repro.launch.mesh import make_host_mesh
        from repro.launch.train import init_state, make_train_step
        mcfg = ARCHS["qwen3-4b"].reduced()
        run = RunConfig(remat="none", param_dtype="float32",
                        compute_dtype="float32")
        rng = np.random.RandomState(0)
        toks = rng.randint(0, mcfg.vocab_size, size=(1, 2, 2, 2, 32))
        batch = {"tokens": jnp.asarray(toks, jnp.int32),
                 "labels": jnp.asarray(toks, jnp.int32)}
        kw = dict(strategy="fedadc", clients_per_round=2, local_steps=2,
                  eta=0.05, downlink_compressor="delta", n_clients=2)
        fed_m = FedConfig(**kw)
        fed_u = FedConfig(downlink_unicast=True, **kw)
        with make_host_mesh():
            sm = init_state(jax.random.PRNGKey(0), mcfg, fed_m, run)
            su = init_state(jax.random.PRNGKey(0), mcfg, fed_u, run)
            step_m = make_train_step(mcfg, fed_m, run)
            step_u = make_train_step(mcfg, fed_u, run)
            ids = np.arange(2, dtype=np.int32)
            for r in range(3):
                sm, _ = step_m(sm, batch)
                step_m.account_round(2, resync=(r == 0))
                su, _ = step_u(su, batch)
                step_u.account_round(client_ids=ids)
            _assert_trees_equal(sm["params"], su["params"], exact=True)
            tm, tu = step_m.transport, step_u.transport
            assert tu.downlink_bytes == tm.downlink_bytes > 0
            assert tu.downlink_bytes_raw == tm.downlink_bytes_raw
            assert tu.uplink_bytes == tm.uplink_bytes
            assert int(step_u.refs.resyncs) == 2
            assert int(step_u.refs.catchups) == 4



class TestDeltaDownlinkCodec:
    def test_per_direction_knobs_fall_back_to_uplink(self):
        t = _tree()
        tpl = (t, {})
        shared = Transport(_fed(downlink_compressor="topk", topk_frac=0.2))
        split = Transport(_fed(downlink_compressor="topk", topk_frac=0.2,
                               downlink_topk_frac=0.05))
        assert shared.downlink_wire_nbytes(tpl) == \
            Transport(_fed(compressor="topk", topk_frac=0.2)
                      ).uplink_wire_nbytes(t)
        assert split.downlink_wire_nbytes(tpl) < \
            shared.downlink_wire_nbytes(tpl)
        # uplink side unaffected by the downlink override
        assert split.uplink_wire_nbytes(t) == shared.uplink_wire_nbytes(t)

    def test_downlink_qsgd_bits_override(self):
        tpl = (_tree(), {})
        wide = Transport(_fed(downlink_compressor="qsgd", qsgd_bits=8))
        narrow = Transport(_fed(downlink_compressor="qsgd", qsgd_bits=8,
                                downlink_qsgd_bits=2))
        assert narrow.downlink_wire_nbytes(tpl) < \
            wide.downlink_wire_nbytes(tpl)

    def test_delta_rejected_on_uplink(self):
        with pytest.raises(ValueError, match="downlink"):
            Transport(_fed(compressor="delta"))

    @pytest.mark.parametrize("name", ["delta+none", "delta+", "delta+delta",
                                      "delta+topk9"])
    def test_unknown_delta_inner_rejected(self, name):
        with pytest.raises(ValueError, match="unknown"):
            Transport(_fed(downlink_compressor=name))

    def test_ctx_costs_zero_bytes_for_fedadc(self):
        """Momentum-aware accounting: FedADC's m̄ is an exact scalar image
        of the θ-delta, so the delta-coded ctx ships 0 bytes; a strategy
        without the hook (FedProx broadcasts θ_t itself) pays full freight."""
        p = _tree()
        adc = Transport(_fed(downlink_compressor="delta"))
        assert adc.downlink_wire_nbytes((p, {"m_bar": p})) == C.raw_nbytes(p)
        prox = Transport(_fed("fedprox", downlink_compressor="delta"))
        assert prox.downlink_wire_nbytes((p, {"theta_t": p})) == \
            2 * C.raw_nbytes(p)
        # lossy inner composes on the θ-delta only
        lossy = Transport(_fed(downlink_compressor="delta+topk",
                               downlink_topk_frac=0.1))
        assert lossy.downlink_wire_nbytes((p, {"m_bar": p})) == \
            C.TopKCompressor(0.1).wire_nbytes(p)

    def test_lossy_reference_lifecycle(self):
        """ref_t = reconstruction_t; clients accumulate ref + decoded delta;
        the derived ctx is the exact scalar image of the decoded θ-delta."""
        fed = _fed(downlink_compressor="delta+qsgd", downlink_qsgd_bits=8)
        t = Transport(fed)
        assert t.needs_downlink_ref and t.down.lossy
        p0 = _tree(0)
        ctx0 = {"m_bar": T.zeros_like(p0)}
        ref0 = t.init_downlink_ref(p0, ctx0)
        p1 = T.add(p0, T.scale(_tree(1), 0.01))
        ctx1 = {"m_bar": T.scale(_tree(2), 0.1)}
        pw, cw, ref1 = t.broadcast(p1, ctx1, jax.random.PRNGKey(3), ref0)
        # reconstruction is close to—but not bitwise—the true tree
        err = float(T.global_norm(T.sub(pw, p1)))
        assert 0 < err < 0.05 * float(T.global_norm(p1))
        # new reference IS the reconstruction the clients now hold
        _assert_trees_equal(ref1[0], pw, exact=True)
        _assert_trees_equal(ref1[1], cw, exact=True)
        # momentum-aware ctx: m̄ = −β_l/(H·α·η) · (decoded θ-delta)
        k = -fed.beta_local / (fed.local_steps * fed.alpha * fed.eta)
        expect = T.scale(T.sub(pw, p0), k)
        _assert_trees_equal(cw, {"m_bar": expect}, exact=False, atol=1e-5)

    def test_round0_delta_is_exact(self):
        """The round-0 reference is the initial sync, so the first lossy
        wire delta is exactly zero and clients start from the true θ_0."""
        fed = _fed(downlink_compressor="delta+topk", downlink_topk_frac=0.1)
        t = Transport(fed)
        p0, ctx0 = _tree(0), {"m_bar": T.zeros_like(_tree(0))}
        ref0 = t.init_downlink_ref(p0, ctx0)
        pw, cw, _ = t.broadcast(p0, ctx0, jax.random.PRNGKey(0), ref0)
        _assert_trees_equal(pw, p0, exact=True)
        _assert_trees_equal(cw, ctx0, exact=True)

    def test_stateless_codecs_need_no_ref(self):
        t = Transport(_fed(downlink_compressor="identity"))
        assert not t.needs_downlink_ref
        assert t.init_downlink_ref(_tree(), {}) is None
        p = _tree()
        pw, cw, ref = t.broadcast(p, {})
        assert pw is p and ref is None

    def test_delta_requires_ref(self):
        # only the *lossy* delta codec is stateful — its reconstruction
        # drifts from θ_t, so the reference must be threaded in
        t = Transport(_fed(downlink_compressor="delta+qsgd",
                           downlink_qsgd_bits=8))
        assert t.stateful_downlink
        with pytest.raises(ValueError, match="stateful"):
            t.broadcast(_tree(), {}, jax.random.PRNGKey(0))
        # the lossless delta derives its reference from θ_t itself:
        # ref=None is the stateless form every engine now uses
        t2 = Transport(_fed(downlink_compressor="delta"))
        assert t2.needs_downlink_ref and not t2.stateful_downlink
        p = _tree()
        pw, cw, _ = t2.broadcast(p, {})
        _assert_trees_equal(pw, p, exact=True)


# ---------------------------------------------------------------------------
# broadcast with ctx=None (FedAvg's empty context): no phantom leaves, 0
# downlink bytes for the ctx side
# ---------------------------------------------------------------------------
class TestBroadcastCtxNone:
    @pytest.mark.parametrize("codec", ["topk", "qsgd"])
    def test_lossy_roundtrip_preserves_none(self, codec):
        t = Transport(_fed("fedavg", downlink_compressor=codec))
        p = _tree(4)
        pw, cw, _ = t.broadcast(p, None, jax.random.PRNGKey(0))
        assert cw is None
        assert jax.tree.structure((pw, cw)) == jax.tree.structure((p, None))
        assert len(jax.tree.leaves((pw, cw))) == len(jax.tree.leaves(p))
        # the codec actually engaged on the params side
        assert float(T.global_norm(T.sub(pw, p))) > 0

    def test_zeros_like_keeps_none_empty(self):
        z = T.zeros_like((_tree(), None))
        assert z[1] is None
        assert len(jax.tree.leaves(z)) == len(jax.tree.leaves(_tree()))

    def test_templates_count_none_ctx_zero(self):
        p = _tree()
        for fed in (_fed("fedavg"),
                    _fed("fedavg", downlink_compressor="topk"),
                    _fed("fedavg", downlink_compressor="qsgd"),
                    _fed("fedavg", downlink_compressor="delta")):
            t = Transport(fed)
            with_none = t.downlink_wire_nbytes((p, None))
            params_only = t.downlink_wire_nbytes((p, {}))
            assert with_none == params_only > 0, fed.downlink_compressor
        t = Transport(_fed("fedavg", downlink_compressor="identity"))
        t.set_wire_templates(p, (p, None))
        assert t._down_raw == C.raw_nbytes(p)
        t.account_downlink(3)
        assert t.downlink_bytes == 3 * C.raw_nbytes(p)

    def test_delta_codec_threads_none_ctx(self):
        t = Transport(_fed("fedavg", downlink_compressor="delta+qsgd"))
        p0 = _tree(0)
        ref = t.init_downlink_ref(p0, None)
        p1 = T.add(p0, T.scale(_tree(1), 0.01))
        pw, cw, ref1 = t.broadcast(p1, None, jax.random.PRNGKey(0), ref)
        assert cw is None and ref1[1] is None
        assert float(T.global_norm(T.sub(pw, p0))) > 0


# ---------------------------------------------------------------------------
# shim cache: keyed on the wire-relevant fields, not the whole config
# ---------------------------------------------------------------------------
class TestShimTransportCache:
    def test_non_wire_fields_share_one_instance(self):
        a = _fed(compressor="topk", topk_frac=0.1, eta=0.01)
        b = _fed(compressor="topk", topk_frac=0.1, eta=0.9)
        assert shim_transport(a) is shim_transport(b)

    def test_flipping_compressor_changes_served_codec(self):
        a = _fed(compressor="topk", topk_frac=0.1)
        b = _fed(compressor="qsgd", qsgd_bits=4)
        ta, tb = shim_transport(a), shim_transport(b)
        assert ta is not tb
        assert ta.up.name == "topk" and tb.up.name == "qsgd"
        # and the served codec reflects the knob, not a stale entry
        assert shim_transport(_fed(compressor="topk", topk_frac=0.1)) is ta

    def test_wire_knob_variants_get_distinct_codecs(self):
        a = shim_transport(_fed(compressor="topk", topk_frac=0.1))
        b = shim_transport(_fed(compressor="topk", topk_frac=0.2))
        assert a is not b and a.up._comp.frac != b.up._comp.frac

    def test_mutable_config_rejected(self):
        import dataclasses

        @dataclasses.dataclass
        class MutableFed:
            compressor: str = "topk"
            topk_frac: float = 0.1
            qsgd_bits: int = 8
            error_feedback: bool = True
            sparse_uplink: bool = False
            use_pallas: bool = False

        with pytest.raises(TypeError, match="frozen"):
            shim_transport(MutableFed())



class TestClientStore:
    def test_host_gather_initialises_then_round_trips(self):
        store = CS.ClientStore()
        store.register("ef", lambda: {"w": jnp.zeros((3,))})
        stacked = store.gather("ef", [4, 7])
        assert stacked["w"].shape == (2, 3)
        new = {"w": jnp.asarray([[1., 2., 3.], [4., 5., 6.]])}
        store.scatter("ef", [4, 7], new)
        again = store.gather("ef", [7, 4])
        np.testing.assert_array_equal(again["w"],
                                      np.asarray([[4, 5, 6], [1, 2, 3]]))
        assert set(store.states("ef")) == {4, 7}

    def test_host_falsy_state_survives(self):
        store = CS.ClientStore()
        store.register("state", lambda: {"x": jnp.ones(())})
        store.states("state")[3] = jnp.zeros(())   # falsy but present
        got = store.gather("state", [3])
        assert not isinstance(got, dict) and float(got[0]) == 0.0

    def test_sharded_round_trip(self):
        template = {"w": jnp.zeros((4, 2)), "b": jnp.zeros(())}
        store = CS.sharded_init(template, 6)
        assert jax.tree.leaves(store)[0].shape[0] == 6
        ids = jnp.asarray([5, 0, 3], jnp.int32)
        vals = {"w": jnp.arange(24, dtype=jnp.float32).reshape(3, 4, 2),
                "b": jnp.asarray([1., 2., 3.])}
        store = CS.sharded_scatter(store, ids, vals)
        got = CS.sharded_gather(store, ids)
        _assert_trees_equal(got, vals, exact=True)
        untouched = CS.sharded_gather(store, jnp.asarray([1, 2, 4]))
        assert all(float(jnp.max(jnp.abs(l))) == 0
                   for l in jax.tree.leaves(untouched))

    def test_sharded_round_trip_inside_jit(self):
        """The pod-engine usage: gather/scatter under jit with traced ids."""
        template = {"w": jnp.zeros((8,))}
        store = CS.sharded_init(template, 5)

        @jax.jit
        def roundtrip(store, ids, vals):
            s2 = CS.sharded_scatter(store, ids, vals)
            return CS.sharded_gather(s2, ids), s2
        ids = jnp.asarray([2, 4], jnp.int32)
        vals = {"w": jnp.ones((2, 8)) * jnp.asarray([[1.], [2.]])}
        got, s2 = roundtrip(store, ids, vals)
        _assert_trees_equal(got, vals, exact=True)

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.integers(min_value=0, max_value=9), min_size=1,
                    max_size=6, unique=True),
           st.integers(min_value=0, max_value=2 ** 31 - 1))
    def test_sharded_gather_scatter_property(self, ids, seed):
        """Property: for any unique id set, scatter-then-gather is the
        identity and non-addressed rows are untouched."""
        template = {"w": jnp.zeros((3,))}
        store0 = CS.sharded_init(template, 10)
        k = jax.random.PRNGKey(seed)
        vals = {"w": jax.random.normal(k, (len(ids), 3))}
        idx = jnp.asarray(ids, jnp.int32)
        store1 = CS.sharded_scatter(store0, idx, vals)
        _assert_trees_equal(CS.sharded_gather(store1, idx), vals, exact=True)
        others = [c for c in range(10) if c not in ids]
        if others:
            rest = CS.sharded_gather(store1, jnp.asarray(others, jnp.int32))
            assert float(jnp.max(jnp.abs(rest["w"]))) == 0.0


# ---------------------------------------------------------------------------
# sparse top-k wire path == dense-reconstruction oracle
# ---------------------------------------------------------------------------
class TestSparseTopK:
    def test_codec_matches_dense_oracle_bitwise(self):
        delta, ef = _tree(1), T.zeros_like(_tree(1))
        key = jax.random.PRNGKey(0)
        dense = make_codec("topk", _fed(compressor="topk", topk_frac=0.1))
        sparse = SparseTopKCodec(0.1)
        qd, ed = dense.roundtrip(delta, ef, key)
        qs, es = sparse.roundtrip(delta, ef, key)
        _assert_trees_equal(qd, qs, exact=True)
        _assert_trees_equal(ed, es, exact=True)

    def test_wire_is_value_index_pairs(self):
        sparse = SparseTopKCodec(0.1)
        delta = _tree(2)
        wire, _ = sparse.encode(delta, T.zeros_like(delta),
                                jax.random.PRNGKey(0))
        leaves = jax.tree.leaves(wire, is_leaf=lambda x: isinstance(
            x, SparseLeaf))
        assert all(isinstance(l, SparseLeaf) for l in leaves)
        # k = ceil(0.1 · n) entries survive per leaf
        assert leaves[0].values.shape == leaves[0].indices.shape
        decoded = sparse.decode(wire, delta)
        for w, d in zip(leaves, jax.tree.leaves(delta)):
            assert w.values.size == int(np.ceil(0.1 * d.size))

    def test_wire_bytes_equal_dense_accounting(self):
        t = _tree()
        fed = _fed(compressor="topk", topk_frac=0.1)
        assert Transport(fed).uplink_wire_nbytes(t) == \
            Transport(_fed(compressor="topk", topk_frac=0.1,
                           sparse_uplink=True)).uplink_wire_nbytes(t)

    def test_simulator_sparse_trajectory_matches_dense(self, data):
        """End-to-end: the sparse wire representation reproduces the dense
        round trajectory (exact away from magnitude ties at the k-th entry,
        where dense-threshold keeps all tied entries and top-k exactly k)."""
        x, y, xt, yt, parts = data
        kw = dict(compressor="topk", topk_frac=0.1)
        a = FederatedSimulator(_fed(**kw), _sim(), x, y, xt, yt, parts)
        b = FederatedSimulator(_fed(sparse_uplink=True, **kw), _sim(),
                               x, y, xt, yt, parts)
        a.run(), b.run()
        _assert_trees_equal(a.params, b.params, exact=False, atol=1e-6)
        assert b.uplink_bytes == a.uplink_bytes < a.uplink_bytes_raw

    def test_sparse_requires_topk(self):
        with pytest.raises(ValueError, match="sparse"):
            Transport(_fed(compressor="qsgd", sparse_uplink=True))

    @pytest.mark.parametrize("sparse", [False, True])
    def test_topk_handles_tuple_pytree_nodes(self, sparse):
        """Regression: a delta pytree with tuple INTERNAL nodes must not be
        mistaken for (wire, residual) pairs by the codec's unzip step."""
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
        delta = {"pair": (jax.random.normal(k1, (40,)),
                          jax.random.normal(k2, (24,))),
                 "plain": jax.random.normal(k3, (16,))}
        ef = T.zeros_like(delta)
        fed = _fed(compressor="topk", topk_frac=0.25, sparse_uplink=sparse)
        q, new_ef = Transport(fed).uplink(delta, ef, jax.random.PRNGKey(0))
        assert jax.tree.structure(q) == jax.tree.structure(delta)
        assert jax.tree.structure(new_ef) == jax.tree.structure(delta)
        # reconstruction + residual == input, leaf by leaf
        _assert_trees_equal(T.add(q, new_ef), delta, exact=True)

    def test_lossy_downlink_requires_key(self):
        t = Transport(_fed(downlink_compressor="qsgd"))
        params, ctx = _tree(8), {}
        with pytest.raises(ValueError, match="key"):
            t.broadcast(params, ctx)


# ---------------------------------------------------------------------------
# sparse-native aggregation: segment-summing the wire == decoding every
# client dense and folding — the CI engine-parity matrix's third codec
# axis, sparse_aggregate ∈ {dense-decode, sparse-native}, per engine
# ---------------------------------------------------------------------------
def _sparse_fed(**kw):
    base = dict(compressor="topk", topk_frac=0.1, sparse_uplink=True)
    base.update(kw)
    return _fed(**base)


class TestSparseAggTransportSync:
    def test_unit_aggregate_matches_dense_fold(self):
        """sparse_weighted_mean (both backends) is bitwise the sequential
        dense fold: decode each client, accumulate wn_i·Δ_i client-major
        into fp32 zeros, cast on the final write."""
        from repro.federated import aggregation as A
        from repro.kernels import ops
        like = _tree(0)
        codec = SparseTopKCodec(0.1)
        wires = [codec.encode(_tree(s), T.zeros_like(like),
                              jax.random.PRNGKey(s))[0]
                 for s in (1, 2, 3)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *wires)
        w = jnp.asarray([0.5, 0.2, 0.3], jnp.float32)
        wn = np.asarray(w / jnp.maximum(jnp.sum(w), 1e-12), np.float32)
        oracle = {}
        for key, leaf in like.items():
            acc = np.zeros(leaf.shape, np.float32)
            for i, wire in enumerate(wires):
                dense = np.asarray(ops.sparse_scatter_leaf(
                    wire[key].values, wire[key].indices,
                    leaf.shape, leaf.dtype))
                acc = acc + wn[i] * dense
            oracle[key] = acc.astype(leaf.dtype)
        for use_pallas in (False, True):
            got = A.sparse_weighted_mean(stacked, w, like,
                                         use_pallas=use_pallas)
            _assert_trees_equal(got, oracle, exact=True)

    def test_simulator_trajectory_matches_dense_decode(self, data):
        """End-to-end engine parity: sparse-native aggregation reproduces
        the dense-decode trajectory (1e-6: same fp32 sums, different add
        order) at identical measured wire bytes."""
        x, y, xt, yt, parts = data
        a = FederatedSimulator(_sparse_fed(sparse_aggregate=False), _sim(),
                               x, y, xt, yt, parts)
        b = FederatedSimulator(_sparse_fed(sparse_aggregate=True), _sim(),
                               x, y, xt, yt, parts)
        a.run(), b.run()
        assert b.transport.sparse_native and not a.transport.sparse_native
        _assert_trees_equal(a.params, b.params, exact=False, atol=1e-6)
        assert b.uplink_bytes == a.uplink_bytes < a.uplink_bytes_raw

    def test_drag_weights_from_wire(self, data):
        """The DRAG aggregator runs off the wire too (sparse divergence
        against the broadcast reference) and stays close to dense-decode."""
        x, y, xt, yt, parts = data
        kw = dict(aggregator="drag")
        a = FederatedSimulator(_sparse_fed(sparse_aggregate=False, **kw),
                               _sim(), x, y, xt, yt, parts)
        b = FederatedSimulator(_sparse_fed(sparse_aggregate=True, **kw),
                               _sim(), x, y, xt, yt, parts)
        a.run(), b.run()
        _assert_trees_equal(a.params, b.params, exact=False, atol=1e-5)

    def test_steady_state_transfer_guard(self, data, steady_state_guard):
        x, y, xt, yt, parts = data
        s = FederatedSimulator(_sparse_fed(), _sim(2), x, y, xt, yt, parts)
        s.run()
        with steady_state_guard():
            s.run(2)


class TestSparseAggTransportAsync:
    def test_async_trajectory_matches_dense_decode(self, data):
        x, y, xt, yt, parts = data
        het = HeteroConfig()
        a = AsyncFederatedSimulator(_sparse_fed(sparse_aggregate=False),
                                    _sim(), het, x, y, xt, yt, parts)
        b = AsyncFederatedSimulator(_sparse_fed(sparse_aggregate=True),
                                    _sim(), het, x, y, xt, yt, parts)
        a.run(), b.run()
        _assert_trees_equal(a.params, b.params, exact=False, atol=1e-6)
        assert b.uplink_bytes == a.uplink_bytes < a.uplink_bytes_raw

    def test_drop_path_decodes_wire_for_ef(self, data):
        """Dropped clients fold their lost update back into EF; on the
        sparse-native path the in-flight record holds the WIRE, so the
        fold-back decodes it first.  The host-RNG drop schedule is seeded
        identically in both configs, so parity must survive drops."""
        x, y, xt, yt, parts = data
        het = HeteroConfig(enabled=True, drop_prob=0.3, seed=5)
        a = AsyncFederatedSimulator(_sparse_fed(sparse_aggregate=False),
                                    _sim(), het, x, y, xt, yt, parts)
        b = AsyncFederatedSimulator(_sparse_fed(sparse_aggregate=True),
                                    _sim(), het, x, y, xt, yt, parts)
        a.run(), b.run()
        _assert_trees_equal(a.params, b.params, exact=False, atol=1e-6)

    def test_steady_state_transfer_guard(self, data, steady_state_guard):
        x, y, xt, yt, parts = data
        het = HeteroConfig()
        s = AsyncFederatedSimulator(_sparse_fed(), _sim(2), het, x, y, xt,
                                    yt, parts)
        s.run()
        with steady_state_guard():
            s.run(2)


class TestSparseAggTransportPod:
    def _setup(self):
        from repro.launch.mesh import make_host_mesh
        from repro.launch.train import init_state, make_train_step
        mcfg = ARCHS["qwen3-4b"].reduced()
        run = RunConfig(remat="none", param_dtype="float32",
                        compute_dtype="float32")
        rng = np.random.RandomState(0)
        toks = rng.randint(0, mcfg.vocab_size, size=(1, 2, 2, 2, 32))
        batch = {"tokens": jnp.asarray(toks, jnp.int32),
                 "labels": jnp.asarray(toks, jnp.int32)}
        return make_host_mesh(), mcfg, run, batch, init_state, make_train_step

    def test_pod_bit_exact(self):
        """The pod scan folds clients sequentially either way, and the
        sparse scatter-adds touch exactly the wire support (off-support
        adds are +0.0 no-ops) — so sparse-native is BITWISE equal to
        dense-decode here, not merely close."""
        kw = dict(strategy="fedadc", clients_per_round=2, local_steps=2,
                  eta=0.05, compressor="topk", topk_frac=0.1,
                  error_feedback=True, sparse_uplink=True)
        mesh, mcfg, run, batch, init_state, make_train_step = self._setup()
        with mesh:
            fed_a = FedConfig(sparse_aggregate=False, **kw)
            fed_b = FedConfig(sparse_aggregate=True, **kw)
            state = init_state(jax.random.PRNGKey(0), mcfg, fed_a, run)
            sa, ma = make_train_step(mcfg, fed_a, run)(state, batch)
            sb, mb = make_train_step(mcfg, fed_b, run)(state, batch)
            _assert_trees_equal(sa["params"], sb["params"], exact=True)
            _assert_trees_equal(sa["clients"]["ef"], sb["clients"]["ef"],
                                exact=True)
            assert np.isfinite(float(mb["loss"]))

    def test_pod_sparse_uplink_accounts_wire_bytes(self):
        """Regression (measured-byte audit): the pod engine's uplink
        counter must report the (values, indices) WIRE bytes at the wire
        dtype — not the decoded dense reconstruction, and not the fp32
        master-param bytes."""
        from repro.launch.mesh import make_host_mesh
        from repro.launch.train import make_train_step, state_shapes
        mcfg = ARCHS["qwen3-4b"].reduced()
        run = RunConfig(remat="none", param_dtype="float32",
                        compute_dtype="bfloat16")
        fed = FedConfig(strategy="fedadc", clients_per_round=2,
                        local_steps=2, eta=0.05, compressor="topk",
                        topk_frac=0.1, error_feedback=True,
                        sparse_uplink=True)
        with make_host_mesh():
            step = make_train_step(mcfg, fed, run)
            tr = step.transport
            step.account_round(4)
        params_t = state_shapes(mcfg, fed, run)["params"]
        wire_t = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.bfloat16), params_t)
        assert tr.uplink_bytes == 4 * tr.uplink_wire_nbytes(wire_t)
        assert tr.uplink_bytes_raw == 4 * C.raw_nbytes(wire_t)
        # wire < dense bf16 < dense fp32 master: neither inflation bug
        assert tr.uplink_bytes < tr.uplink_bytes_raw \
            < 4 * C.raw_nbytes(params_t)
        # the round also paid its broadcast; identity downlink ⇒ wire == raw
        assert tr.downlink_bytes == tr.downlink_bytes_raw > 0

    def test_steady_state_transfer_guard(self, steady_state_guard):
        """The sparse-native pod round — encode on the wire, streaming
        scatter-add aggregate — runs steady-state with zero implicit
        host<->device transfers."""
        fed = FedConfig(strategy="fedadc", clients_per_round=2,
                        local_steps=2, eta=0.05, compressor="topk",
                        topk_frac=0.1, error_feedback=True,
                        sparse_uplink=True)
        mesh, mcfg, run, batch, init_state, make_train_step = self._setup()
        with mesh:
            state = init_state(jax.random.PRNGKey(0), mcfg, fed, run)
            step = jax.jit(make_train_step(mcfg, fed, run))
            state, _ = step(state, batch)
            with steady_state_guard():
                state, m = step(state, batch)
            assert np.isfinite(float(jax.device_get(m["loss"])))


# ---------------------------------------------------------------------------
# two-tier hierarchical aggregation (DESIGN.md §Fleet): with ONE region the
# regional reduce IS the flat reduce, and the global combine is a weighted
# mean over a single partial whose normalised weight is exactly 1.0
# (IEEE W/W), so the two-tier path is BITWISE the flat path on every
# engine — the CI engine-parity matrix's fourth codec axis.  With R > 1
# the fp32 sums reassociate, so parity is tolerance-bounded.
# ---------------------------------------------------------------------------
class TestHierarchicalTransportSync:
    def test_one_region_bit_exact(self, data):
        x, y, xt, yt, parts = data
        a = FederatedSimulator(_fed(), _sim(), x, y, xt, yt, parts)
        b = FederatedSimulator(_fed(fleet_regions=1), _sim(), x, y, xt, yt,
                               parts)
        a.run(), b.run()
        _assert_trees_equal(a.params, b.params, exact=True)

    def test_one_region_sparse_wire_bit_exact(self, data):
        """The regional stage reuses the sparse-native segment-sum, so the
        sparse wire + EF trajectory is also bitwise under one region (EF is
        client-side and must come out identical too)."""
        x, y, xt, yt, parts = data
        a = FederatedSimulator(_sparse_fed(sparse_aggregate=True), _sim(),
                               x, y, xt, yt, parts)
        b = FederatedSimulator(_sparse_fed(sparse_aggregate=True,
                                           fleet_regions=1), _sim(),
                               x, y, xt, yt, parts)
        a.run(), b.run()
        _assert_trees_equal(a.params, b.params, exact=True)
        efa, efb = a.protocol.store.states("ef"), b.protocol.store.states("ef")
        assert sorted(efa) == sorted(efb)
        for cid in efa:
            _assert_trees_equal(efa[cid], efb[cid], exact=True)

    def test_multi_region_matches_flat_within_tol(self, data):
        x, y, xt, yt, parts = data
        a = FederatedSimulator(_fed(), _sim(), x, y, xt, yt, parts)
        b = FederatedSimulator(_fed(fleet_regions=3), _sim(), x, y, xt, yt,
                               parts)
        a.run(), b.run()
        _assert_trees_equal(a.params, b.params, exact=False, atol=1e-5)

    def test_steady_state_transfer_guard(self, data, steady_state_guard):
        x, y, xt, yt, parts = data
        s = FederatedSimulator(_fed(fleet_regions=2), _sim(2), x, y, xt, yt,
                               parts)
        s.run()
        with steady_state_guard():
            s.run(2)


class TestHierarchicalTransportAsync:
    def test_one_region_bit_exact(self, data):
        x, y, xt, yt, parts = data
        het = HeteroConfig()
        a = AsyncFederatedSimulator(_fed(), _sim(), het, x, y, xt, yt, parts)
        b = AsyncFederatedSimulator(_fed(fleet_regions=1), _sim(), het,
                                    x, y, xt, yt, parts)
        a.run(), b.run()
        _assert_trees_equal(a.params, b.params, exact=True)

    def test_buffered_k_one_region_bit_exact(self, data):
        """The buffered-K flush aggregates whatever cohort the buffer
        holds; with one region the hierarchical flush is still bitwise."""
        x, y, xt, yt, parts = data
        het = HeteroConfig(enabled=True, speed_dist="lognormal", seed=2)
        a = AsyncFederatedSimulator(_fed(buffer_k=2), _sim(), het, x, y,
                                    xt, yt, parts)
        b = AsyncFederatedSimulator(_fed(buffer_k=2, fleet_regions=1),
                                    _sim(), het, x, y, xt, yt, parts)
        a.run(), b.run()
        _assert_trees_equal(a.params, b.params, exact=True)

    def test_steady_state_transfer_guard(self, data, steady_state_guard):
        x, y, xt, yt, parts = data
        het = HeteroConfig()
        s = AsyncFederatedSimulator(_fed(fleet_regions=2), _sim(2), het,
                                    x, y, xt, yt, parts)
        s.run()
        with steady_state_guard():
            s.run(2)


class TestHierarchicalTransportPod:
    def _setup(self):
        from repro.launch.mesh import make_host_mesh
        from repro.launch.train import init_state, make_train_step
        mcfg = ARCHS["qwen3-4b"].reduced()
        run = RunConfig(remat="none", param_dtype="float32",
                        compute_dtype="float32")
        rng = np.random.RandomState(0)
        toks = rng.randint(0, mcfg.vocab_size, size=(1, 2, 2, 2, 32))
        batch = {"tokens": jnp.asarray(toks, jnp.int32),
                 "labels": jnp.asarray(toks, jnp.int32)}
        return make_host_mesh(), mcfg, run, batch, init_state, make_train_step

    def test_pod_one_region_bit_exact(self):
        """The pod engine's per-pod means are the regional partials; with
        fleet_regions=1 the global combine reduces to the flat
        server_aggregate over the CP axis, bitwise."""
        kw = dict(strategy="fedadc", clients_per_round=2, local_steps=2,
                  eta=0.05)
        mesh, mcfg, run, batch, init_state, make_train_step = self._setup()
        with mesh:
            fed_a = FedConfig(**kw)
            fed_b = FedConfig(fleet_regions=1, **kw)
            state = init_state(jax.random.PRNGKey(0), mcfg, fed_a, run)
            sa, _ = make_train_step(mcfg, fed_a, run)(state, batch)
            sb, mb = make_train_step(mcfg, fed_b, run)(state, batch)
            _assert_trees_equal(sa["params"], sb["params"], exact=True)
            assert np.isfinite(float(mb["loss"]))

    def test_steady_state_transfer_guard(self, steady_state_guard):
        # the host-mesh batch carries ONE pod on the CP axis, so one region
        # is the only valid split — region_sizes rejects R > pods
        fed = FedConfig(strategy="fedadc", clients_per_round=2,
                        local_steps=2, eta=0.05, fleet_regions=1)
        mesh, mcfg, run, batch, init_state, make_train_step = self._setup()
        with mesh:
            state = init_state(jax.random.PRNGKey(0), mcfg, fed, run)
            step = jax.jit(make_train_step(mcfg, fed, run))
            state, _ = step(state, batch)
            with steady_state_guard():
                state, m = step(state, batch)
            assert np.isfinite(float(jax.device_get(m["loss"])))


# ---------------------------------------------------------------------------
# pod engine: top-k + EF through the sharded store
# ---------------------------------------------------------------------------
class TestPodErrorFeedback:
    def test_pod_topk_ef_state_is_round_residual(self):
        """The pod engine completes a top-k+EF round and the stored EF state
        equals the exact round residual — the same invariant the simulator
        pins (test_compression.TestErrorFeedback): for FedAvg with one
        client, θ'_cmp − θ'_raw = Δ − q = e."""
        from repro.launch.mesh import make_host_mesh
        from repro.launch.train import init_state, make_train_step
        mcfg = ARCHS["qwen3-4b"].reduced()
        run = RunConfig(remat="none", param_dtype="float32",
                        compute_dtype="float32")
        rng = np.random.RandomState(0)
        toks = rng.randint(0, mcfg.vocab_size, size=(1, 1, 2, 2, 32))
        batch = {"tokens": jnp.asarray(toks, jnp.int32),
                 "labels": jnp.asarray(toks, jnp.int32),
                 "client_ids": jnp.asarray([[3]], jnp.int32)}
        kw = dict(strategy="fedavg", clients_per_round=1, local_steps=2,
                  eta=0.05, n_clients=5)
        with make_host_mesh():
            fed_raw = FedConfig(**kw)
            fed_cmp = FedConfig(compressor="topk", topk_frac=0.1,
                                error_feedback=True, **kw)
            state = init_state(jax.random.PRNGKey(0), mcfg, fed_raw, run)
            state_c = init_state(jax.random.PRNGKey(0), mcfg, fed_cmp, run)
            assert "clients" in state_c and "clients" not in state
            sa, _ = make_train_step(mcfg, fed_raw, run)(state, batch)
            sb, _ = make_train_step(mcfg, fed_cmp, run)(state_c, batch)
            ef = jax.tree.map(lambda x: x[3], sb["clients"]["ef"])
            expect = T.sub(sb["params"], sa["params"])
            _assert_trees_equal(ef, expect, exact=False, atol=1e-5)
            assert float(T.global_norm(ef)) > 0      # genuinely lossy
            # only the round's client slot was written
            others = jax.tree.map(lambda x: x[jnp.asarray([0, 1, 2, 4])],
                                  sb["clients"]["ef"])
            assert all(float(jnp.max(jnp.abs(l))) == 0
                       for l in jax.tree.leaves(others))

    def test_pod_ef_store_lowers_through_dryrun_inputs(self):
        """state_inputs/train_inputs grow the sharded store + client_ids and
        the jit'd round still lowers on the (1×1 host) mesh."""
        from repro.configs.base import ShapeConfig
        from repro.launch import inputs as I
        from repro.launch.mesh import make_host_mesh
        from repro.launch.train import make_train_step
        mcfg = ARCHS["qwen3-4b"].reduced()
        fed = FedConfig(strategy="fedadc", clients_per_round=2,
                        local_steps=2, eta=0.05, n_clients=8,
                        compressor="topk", topk_frac=0.1,
                        error_feedback=True)
        run = RunConfig(remat="none")
        shape = ShapeConfig("train_small", seq_len=64, global_batch=16,
                            kind="train")
        mesh = make_host_mesh()
        with mesh:
            state_sds = I.state_inputs(mcfg, fed, run, mesh)
            assert "clients" in state_sds
            batch_sds = I.train_inputs(mcfg, shape, fed, mesh, False)
            assert "client_ids" in batch_sds
            step = make_train_step(mcfg, fed, run)
            compiled = jax.jit(step).lower(state_sds, batch_sds).compile()
            assert compiled.cost_analysis() is not None

    def test_pod_ef_accumulates_across_rounds(self):
        """Default client ids: slot i ↔ client i; a second round compresses
        v = Δ + e₁ so the store keeps evolving (no longer rejected)."""
        from repro.launch.mesh import make_host_mesh
        from repro.launch.train import init_state, make_train_step
        mcfg = ARCHS["qwen3-4b"].reduced()
        run = RunConfig(remat="none", param_dtype="float32",
                        compute_dtype="float32")
        rng = np.random.RandomState(1)
        toks = rng.randint(0, mcfg.vocab_size, size=(1, 2, 2, 2, 32))
        batch = {"tokens": jnp.asarray(toks, jnp.int32),
                 "labels": jnp.asarray(toks, jnp.int32)}
        fed = FedConfig(strategy="fedadc", clients_per_round=2,
                        local_steps=2, eta=0.05, n_clients=4,
                        compressor="topk", topk_frac=0.1,
                        error_feedback=True)
        with make_host_mesh():
            state = init_state(jax.random.PRNGKey(0), mcfg, fed, run)
            step = make_train_step(mcfg, fed, run)
            s1, m1 = step(state, batch)
            s2, m2 = step(s1, batch)
            assert np.isfinite(float(m2["loss"]))
            e1 = jax.tree.map(lambda x: x[:2], s1["clients"]["ef"])
            e2 = jax.tree.map(lambda x: x[:2], s2["clients"]["ef"])
            diff = float(T.global_norm(T.sub(e1, e2)))
            assert diff > 0                      # residual actually updated


# ---------------------------------------------------------------------------
# protocol validation + deprecation shims
# ---------------------------------------------------------------------------
class TestProtocolValidation:
    def test_lossy_rejected_for_stateful_server_corrections(self):
        for strat in ("scaffold", "feddyn"):
            with pytest.raises(ValueError, match="compressor"):
                RoundProtocol(_fed(strat, compressor="topk"))
            with pytest.raises(ValueError, match="downlink"):
                RoundProtocol(_fed(strat, downlink_compressor="qsgd"))
            with pytest.raises(ValueError, match="aggregator"):
                RoundProtocol(_fed(strat, aggregator="drag"))
        RoundProtocol(_fed("scaffold", compressor="identity"))  # lossless ok

    def test_unknown_codec_names(self):
        with pytest.raises(ValueError, match="unknown"):
            Transport(_fed(compressor="bogus"))
        with pytest.raises(ValueError, match="unknown"):
            Transport(_fed(downlink_compressor="bogus"))


class TestDeprecationShims:
    def test_compress_delta_warns_once_and_delegates(self):
        from repro.core import strategies as S
        fed = _fed(compressor="topk", topk_frac=0.1)
        s = get_strategy("fedadc")
        delta, ef = _tree(5), T.zeros_like(_tree(5))
        key = jax.random.PRNGKey(0)
        S._DEPRECATION_WARNED.discard("strategy.compress_delta")
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            q1, e1 = s.compress_delta(delta, ef, key, fed)
            q2, e2 = s.compress_delta(delta, ef, key, fed)
        deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
        assert len(deps) == 1, "shim must warn once per hook, not per call"
        q_ref, e_ref = Transport(fed).uplink(delta, ef, key)
        _assert_trees_equal(q1, q_ref, exact=True)
        _assert_trees_equal(e1, e_ref, exact=True)
        _assert_trees_equal(q2, q1, exact=True)

    def test_engines_run_warning_clean(self, data):
        """The refactored engines must not route through their own shims."""
        x, y, xt, yt, parts = data
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            FederatedSimulator(_fed(compressor="topk", topk_frac=0.1),
                               _sim(1), x, y, xt, yt, parts).run()
            AsyncFederatedSimulator(_fed(compressor="qsgd", qsgd_bits=6),
                                    _sim(1), HeteroConfig(),
                                    x, y, xt, yt, parts).run()
        deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
        assert not deps, [str(d.message) for d in deps]

    def test_examples_use_no_deprecated_hooks(self):
        """All five examples must be clean of the old hook surface, so they
        run warning-free on the new API."""
        root = pathlib.Path(__file__).resolve().parents[1] / "examples"
        deprecated = ("compress_delta", "_gather_states", "_scatter_states")
        offenders = []
        files = sorted(root.glob("*.py"))
        assert len(files) == 5
        for f in files:
            src = f.read_text()
            offenders += [f"{f.name}:{name}" for name in deprecated
                          if name in src]
        assert not offenders, offenders
