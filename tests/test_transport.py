"""Unified round-protocol API (DESIGN.md §Transport): identity-transport
bit-exactness on all three engines, ClientStore gather/scatter round trips
(host and sharded backends), the sparse top-k wire path vs the dense
reconstruction oracle, pod-engine top-k+EF residual exactness, measured
downlink accounting, and the deprecation-shim contract (warn once, engines
and examples warning-clean)."""
import pathlib
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, st

from repro.configs import ARCHS
from repro.configs.base import FedConfig, HeteroConfig, RunConfig
from repro.core import tree as T
from repro.core.strategies import get_strategy
from repro.data.partition import sort_and_partition
from repro.data.synthetic import make_image_dataset
from repro.federated import store as CS
from repro.federated.async_engine import AsyncFederatedSimulator
from repro.federated.protocol import RoundProtocol
from repro.federated.simulator import FederatedSimulator, SimConfig
from repro.federated.transport import (SparseLeaf, SparseTopKCodec,
                                       Transport, make_codec)


@pytest.fixture(scope="module")
def data():
    x, y, xt, yt = make_image_dataset(600, 150, 10, image_size=16, seed=0,
                                      noise=0.5)
    parts = sort_and_partition(y, 10, s=2, seed=0)
    return x, y, xt, yt, parts


def _fed(strategy="fedadc", **kw):
    base = dict(local_steps=4, clients_per_round=3, n_clients=10, eta=0.03,
                beta_global=0.6, beta_local=0.6)
    base.update(kw)
    return FedConfig(strategy=strategy, **base)


def _sim(rounds=3, **kw):
    base = dict(model="cnn", n_classes=10, batch_size=16, rounds=rounds,
                eval_every=rounds, cnn_width=8, seed=1)
    base.update(kw)
    return SimConfig(**base)


def _tree(seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {"w": jax.random.normal(k1, (64, 32)),
            "b": jax.random.normal(k2, (17,))}


def _assert_trees_equal(a, b, exact=True, atol=0.0):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        if exact:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        else:
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=0, atol=atol)


# ---------------------------------------------------------------------------
# identity transport: bit-identical to the codec-bypass (pre-redesign) round
# loop, on every engine and in BOTH wire directions
# ---------------------------------------------------------------------------
class TestIdentityTransportSync:
    def test_simulator_bit_exact(self, data):
        x, y, xt, yt, parts = data
        a = FederatedSimulator(_fed(), _sim(), x, y, xt, yt, parts)
        b = FederatedSimulator(
            _fed(compressor="identity", downlink_compressor="identity"),
            _sim(), x, y, xt, yt, parts)
        a.run(), b.run()
        _assert_trees_equal(a.params, b.params, exact=True)
        assert b.uplink_bytes == b.uplink_bytes_raw > 0
        assert b.downlink_bytes == b.downlink_bytes_raw > 0

    def test_downlink_accounting_includes_ctx(self, data):
        """FedADC's broadcast carries θ_t AND m̄_t — the measured downlink
        must be 2× the uplink's raw parameter bytes (the paper's naive
        accounting, now measured from the actual wire tree)."""
        x, y, xt, yt, parts = data
        s = FederatedSimulator(_fed("fedadc"), _sim(1), x, y, xt, yt, parts)
        s.run()
        assert s.downlink_bytes_raw == 2 * s.uplink_bytes_raw
        f = FederatedSimulator(_fed("fedavg"), _sim(1), x, y, xt, yt, parts)
        f.run()
        assert f.downlink_bytes_raw == f.uplink_bytes_raw  # empty ctx


class TestIdentityTransportAsync:
    def test_async_bit_exact(self, data):
        x, y, xt, yt, parts = data
        het = HeteroConfig()
        a = AsyncFederatedSimulator(_fed(), _sim(), het, x, y, xt, yt, parts)
        b = AsyncFederatedSimulator(
            _fed(compressor="identity", downlink_compressor="identity"),
            _sim(), het, x, y, xt, yt, parts)
        a.run(), b.run()
        _assert_trees_equal(a.params, b.params, exact=True)
        assert b.downlink_bytes == b.downlink_bytes_raw > 0

    def test_async_downlink_paid_at_dispatch(self, data):
        """Every dispatch (including redispatches) pays one broadcast, so
        downlink clients ≥ uplink clients (drops lose the upload only)."""
        x, y, xt, yt, parts = data
        het = HeteroConfig(enabled=True, drop_prob=0.5, seed=3)
        s = AsyncFederatedSimulator(_fed(), _sim(), het, x, y, xt, yt, parts)
        s.run()
        per_up = s.transport._up_raw
        per_down = s.transport._down_raw
        assert s.downlink_bytes_raw // per_down \
            > s.uplink_bytes_raw // per_up


class TestIdentityTransportPod:
    def test_pod_bit_exact(self):
        from repro.launch.mesh import make_host_mesh
        from repro.launch.train import init_state, make_train_step
        mcfg = ARCHS["qwen3-4b"].reduced()
        run = RunConfig(remat="none", param_dtype="float32",
                        compute_dtype="float32")
        rng = np.random.RandomState(0)
        toks = rng.randint(0, mcfg.vocab_size, size=(1, 2, 2, 2, 32))
        batch = {"tokens": jnp.asarray(toks, jnp.int32),
                 "labels": jnp.asarray(toks, jnp.int32)}
        kw = dict(strategy="fedadc", clients_per_round=2, local_steps=2,
                  eta=0.05)
        with make_host_mesh():
            state = init_state(jax.random.PRNGKey(0), mcfg,
                               FedConfig(**kw), run)
            sa, _ = make_train_step(mcfg, FedConfig(**kw), run)(state, batch)
            sb, _ = make_train_step(
                mcfg, FedConfig(compressor="identity",
                                downlink_compressor="identity", **kw),
                run)(state, batch)
            _assert_trees_equal(sa["params"], sb["params"], exact=True)


# ---------------------------------------------------------------------------
# ClientStore: gather/scatter round trips on both backends
# ---------------------------------------------------------------------------
class TestClientStore:
    def test_host_gather_initialises_then_round_trips(self):
        store = CS.ClientStore()
        store.register("ef", lambda: {"w": jnp.zeros((3,))})
        stacked = store.gather("ef", [4, 7])
        assert stacked["w"].shape == (2, 3)
        new = {"w": jnp.asarray([[1., 2., 3.], [4., 5., 6.]])}
        store.scatter("ef", [4, 7], new)
        again = store.gather("ef", [7, 4])
        np.testing.assert_array_equal(again["w"],
                                      np.asarray([[4, 5, 6], [1, 2, 3]]))
        assert set(store.states("ef")) == {4, 7}

    def test_host_falsy_state_survives(self):
        store = CS.ClientStore()
        store.register("state", lambda: {"x": jnp.ones(())})
        store.states("state")[3] = jnp.zeros(())   # falsy but present
        got = store.gather("state", [3])
        assert not isinstance(got, dict) and float(got[0]) == 0.0

    def test_sharded_round_trip(self):
        template = {"w": jnp.zeros((4, 2)), "b": jnp.zeros(())}
        store = CS.sharded_init(template, 6)
        assert jax.tree.leaves(store)[0].shape[0] == 6
        ids = jnp.asarray([5, 0, 3], jnp.int32)
        vals = {"w": jnp.arange(24, dtype=jnp.float32).reshape(3, 4, 2),
                "b": jnp.asarray([1., 2., 3.])}
        store = CS.sharded_scatter(store, ids, vals)
        got = CS.sharded_gather(store, ids)
        _assert_trees_equal(got, vals, exact=True)
        untouched = CS.sharded_gather(store, jnp.asarray([1, 2, 4]))
        assert all(float(jnp.max(jnp.abs(l))) == 0
                   for l in jax.tree.leaves(untouched))

    def test_sharded_round_trip_inside_jit(self):
        """The pod-engine usage: gather/scatter under jit with traced ids."""
        template = {"w": jnp.zeros((8,))}
        store = CS.sharded_init(template, 5)

        @jax.jit
        def roundtrip(store, ids, vals):
            s2 = CS.sharded_scatter(store, ids, vals)
            return CS.sharded_gather(s2, ids), s2
        ids = jnp.asarray([2, 4], jnp.int32)
        vals = {"w": jnp.ones((2, 8)) * jnp.asarray([[1.], [2.]])}
        got, s2 = roundtrip(store, ids, vals)
        _assert_trees_equal(got, vals, exact=True)

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.integers(min_value=0, max_value=9), min_size=1,
                    max_size=6, unique=True),
           st.integers(min_value=0, max_value=2 ** 31 - 1))
    def test_sharded_gather_scatter_property(self, ids, seed):
        """Property: for any unique id set, scatter-then-gather is the
        identity and non-addressed rows are untouched."""
        template = {"w": jnp.zeros((3,))}
        store0 = CS.sharded_init(template, 10)
        k = jax.random.PRNGKey(seed)
        vals = {"w": jax.random.normal(k, (len(ids), 3))}
        idx = jnp.asarray(ids, jnp.int32)
        store1 = CS.sharded_scatter(store0, idx, vals)
        _assert_trees_equal(CS.sharded_gather(store1, idx), vals, exact=True)
        others = [c for c in range(10) if c not in ids]
        if others:
            rest = CS.sharded_gather(store1, jnp.asarray(others, jnp.int32))
            assert float(jnp.max(jnp.abs(rest["w"]))) == 0.0


# ---------------------------------------------------------------------------
# sparse top-k wire path == dense-reconstruction oracle
# ---------------------------------------------------------------------------
class TestSparseTopK:
    def test_codec_matches_dense_oracle_bitwise(self):
        delta, ef = _tree(1), T.zeros_like(_tree(1))
        key = jax.random.PRNGKey(0)
        dense = make_codec("topk", _fed(compressor="topk", topk_frac=0.1))
        sparse = SparseTopKCodec(0.1)
        qd, ed = dense.roundtrip(delta, ef, key)
        qs, es = sparse.roundtrip(delta, ef, key)
        _assert_trees_equal(qd, qs, exact=True)
        _assert_trees_equal(ed, es, exact=True)

    def test_wire_is_value_index_pairs(self):
        sparse = SparseTopKCodec(0.1)
        delta = _tree(2)
        wire, _ = sparse.encode(delta, T.zeros_like(delta),
                                jax.random.PRNGKey(0))
        leaves = jax.tree.leaves(wire, is_leaf=lambda x: isinstance(
            x, SparseLeaf))
        assert all(isinstance(l, SparseLeaf) for l in leaves)
        # k = ceil(0.1 · n) entries survive per leaf
        assert leaves[0].values.shape == leaves[0].indices.shape
        decoded = sparse.decode(wire, delta)
        for w, d in zip(leaves, jax.tree.leaves(delta)):
            assert w.values.size == int(np.ceil(0.1 * d.size))

    def test_wire_bytes_equal_dense_accounting(self):
        t = _tree()
        fed = _fed(compressor="topk", topk_frac=0.1)
        assert Transport(fed).uplink_wire_nbytes(t) == \
            Transport(_fed(compressor="topk", topk_frac=0.1,
                           sparse_uplink=True)).uplink_wire_nbytes(t)

    def test_simulator_sparse_trajectory_matches_dense(self, data):
        """End-to-end: the sparse wire representation reproduces the dense
        round trajectory (exact away from magnitude ties at the k-th entry,
        where dense-threshold keeps all tied entries and top-k exactly k)."""
        x, y, xt, yt, parts = data
        kw = dict(compressor="topk", topk_frac=0.1)
        a = FederatedSimulator(_fed(**kw), _sim(), x, y, xt, yt, parts)
        b = FederatedSimulator(_fed(sparse_uplink=True, **kw), _sim(),
                               x, y, xt, yt, parts)
        a.run(), b.run()
        _assert_trees_equal(a.params, b.params, exact=False, atol=1e-6)
        assert b.uplink_bytes == a.uplink_bytes < a.uplink_bytes_raw

    def test_sparse_requires_topk(self):
        with pytest.raises(ValueError, match="sparse"):
            Transport(_fed(compressor="qsgd", sparse_uplink=True))

    @pytest.mark.parametrize("sparse", [False, True])
    def test_topk_handles_tuple_pytree_nodes(self, sparse):
        """Regression: a delta pytree with tuple INTERNAL nodes must not be
        mistaken for (wire, residual) pairs by the codec's unzip step."""
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
        delta = {"pair": (jax.random.normal(k1, (40,)),
                          jax.random.normal(k2, (24,))),
                 "plain": jax.random.normal(k3, (16,))}
        ef = T.zeros_like(delta)
        fed = _fed(compressor="topk", topk_frac=0.25, sparse_uplink=sparse)
        q, new_ef = Transport(fed).uplink(delta, ef, jax.random.PRNGKey(0))
        assert jax.tree.structure(q) == jax.tree.structure(delta)
        assert jax.tree.structure(new_ef) == jax.tree.structure(delta)
        # reconstruction + residual == input, leaf by leaf
        _assert_trees_equal(T.add(q, new_ef), delta, exact=True)

    def test_lossy_downlink_requires_key(self):
        t = Transport(_fed(downlink_compressor="qsgd"))
        params, ctx = _tree(8), {}
        with pytest.raises(ValueError, match="key"):
            t.broadcast(params, ctx)


# ---------------------------------------------------------------------------
# pod engine: top-k + EF through the sharded store
# ---------------------------------------------------------------------------
class TestPodErrorFeedback:
    def test_pod_topk_ef_state_is_round_residual(self):
        """The pod engine completes a top-k+EF round and the stored EF state
        equals the exact round residual — the same invariant the simulator
        pins (test_compression.TestErrorFeedback): for FedAvg with one
        client, θ'_cmp − θ'_raw = Δ − q = e."""
        from repro.launch.mesh import make_host_mesh
        from repro.launch.train import init_state, make_train_step
        mcfg = ARCHS["qwen3-4b"].reduced()
        run = RunConfig(remat="none", param_dtype="float32",
                        compute_dtype="float32")
        rng = np.random.RandomState(0)
        toks = rng.randint(0, mcfg.vocab_size, size=(1, 1, 2, 2, 32))
        batch = {"tokens": jnp.asarray(toks, jnp.int32),
                 "labels": jnp.asarray(toks, jnp.int32),
                 "client_ids": jnp.asarray([[3]], jnp.int32)}
        kw = dict(strategy="fedavg", clients_per_round=1, local_steps=2,
                  eta=0.05, n_clients=5)
        with make_host_mesh():
            fed_raw = FedConfig(**kw)
            fed_cmp = FedConfig(compressor="topk", topk_frac=0.1,
                                error_feedback=True, **kw)
            state = init_state(jax.random.PRNGKey(0), mcfg, fed_raw, run)
            state_c = init_state(jax.random.PRNGKey(0), mcfg, fed_cmp, run)
            assert "clients" in state_c and "clients" not in state
            sa, _ = make_train_step(mcfg, fed_raw, run)(state, batch)
            sb, _ = make_train_step(mcfg, fed_cmp, run)(state_c, batch)
            ef = jax.tree.map(lambda x: x[3], sb["clients"]["ef"])
            expect = T.sub(sb["params"], sa["params"])
            _assert_trees_equal(ef, expect, exact=False, atol=1e-5)
            assert float(T.global_norm(ef)) > 0      # genuinely lossy
            # only the round's client slot was written
            others = jax.tree.map(lambda x: x[jnp.asarray([0, 1, 2, 4])],
                                  sb["clients"]["ef"])
            assert all(float(jnp.max(jnp.abs(l))) == 0
                       for l in jax.tree.leaves(others))

    def test_pod_ef_store_lowers_through_dryrun_inputs(self):
        """state_inputs/train_inputs grow the sharded store + client_ids and
        the jit'd round still lowers on the (1×1 host) mesh."""
        from repro.configs.base import ShapeConfig
        from repro.launch import inputs as I
        from repro.launch.mesh import make_host_mesh
        from repro.launch.train import make_train_step
        mcfg = ARCHS["qwen3-4b"].reduced()
        fed = FedConfig(strategy="fedadc", clients_per_round=2,
                        local_steps=2, eta=0.05, n_clients=8,
                        compressor="topk", topk_frac=0.1,
                        error_feedback=True)
        run = RunConfig(remat="none")
        shape = ShapeConfig("train_small", seq_len=64, global_batch=16,
                            kind="train")
        mesh = make_host_mesh()
        with mesh:
            state_sds = I.state_inputs(mcfg, fed, run, mesh)
            assert "clients" in state_sds
            batch_sds = I.train_inputs(mcfg, shape, fed, mesh, False)
            assert "client_ids" in batch_sds
            step = make_train_step(mcfg, fed, run)
            compiled = jax.jit(step).lower(state_sds, batch_sds).compile()
            assert compiled.cost_analysis() is not None

    def test_pod_ef_accumulates_across_rounds(self):
        """Default client ids: slot i ↔ client i; a second round compresses
        v = Δ + e₁ so the store keeps evolving (no longer rejected)."""
        from repro.launch.mesh import make_host_mesh
        from repro.launch.train import init_state, make_train_step
        mcfg = ARCHS["qwen3-4b"].reduced()
        run = RunConfig(remat="none", param_dtype="float32",
                        compute_dtype="float32")
        rng = np.random.RandomState(1)
        toks = rng.randint(0, mcfg.vocab_size, size=(1, 2, 2, 2, 32))
        batch = {"tokens": jnp.asarray(toks, jnp.int32),
                 "labels": jnp.asarray(toks, jnp.int32)}
        fed = FedConfig(strategy="fedadc", clients_per_round=2,
                        local_steps=2, eta=0.05, n_clients=4,
                        compressor="topk", topk_frac=0.1,
                        error_feedback=True)
        with make_host_mesh():
            state = init_state(jax.random.PRNGKey(0), mcfg, fed, run)
            step = make_train_step(mcfg, fed, run)
            s1, m1 = step(state, batch)
            s2, m2 = step(s1, batch)
            assert np.isfinite(float(m2["loss"]))
            e1 = jax.tree.map(lambda x: x[:2], s1["clients"]["ef"])
            e2 = jax.tree.map(lambda x: x[:2], s2["clients"]["ef"])
            diff = float(T.global_norm(T.sub(e1, e2)))
            assert diff > 0                      # residual actually updated


# ---------------------------------------------------------------------------
# protocol validation + deprecation shims
# ---------------------------------------------------------------------------
class TestProtocolValidation:
    def test_lossy_rejected_for_stateful_server_corrections(self):
        for strat in ("scaffold", "feddyn"):
            with pytest.raises(ValueError, match="compressor"):
                RoundProtocol(_fed(strat, compressor="topk"))
            with pytest.raises(ValueError, match="downlink"):
                RoundProtocol(_fed(strat, downlink_compressor="qsgd"))
            with pytest.raises(ValueError, match="aggregator"):
                RoundProtocol(_fed(strat, aggregator="drag"))
        RoundProtocol(_fed("scaffold", compressor="identity"))  # lossless ok

    def test_unknown_codec_names(self):
        with pytest.raises(ValueError, match="unknown"):
            Transport(_fed(compressor="bogus"))
        with pytest.raises(ValueError, match="unknown"):
            Transport(_fed(downlink_compressor="bogus"))


class TestDeprecationShims:
    def test_compress_delta_warns_once_and_delegates(self):
        from repro.core import strategies as S
        fed = _fed(compressor="topk", topk_frac=0.1)
        s = get_strategy("fedadc")
        delta, ef = _tree(5), T.zeros_like(_tree(5))
        key = jax.random.PRNGKey(0)
        S._DEPRECATION_WARNED.discard("strategy.compress_delta")
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            q1, e1 = s.compress_delta(delta, ef, key, fed)
            q2, e2 = s.compress_delta(delta, ef, key, fed)
        deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
        assert len(deps) == 1, "shim must warn once per hook, not per call"
        q_ref, e_ref = Transport(fed).uplink(delta, ef, key)
        _assert_trees_equal(q1, q_ref, exact=True)
        _assert_trees_equal(e1, e_ref, exact=True)
        _assert_trees_equal(q2, q1, exact=True)

    def test_engines_run_warning_clean(self, data):
        """The refactored engines must not route through their own shims."""
        x, y, xt, yt, parts = data
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            FederatedSimulator(_fed(compressor="topk", topk_frac=0.1),
                               _sim(1), x, y, xt, yt, parts).run()
            AsyncFederatedSimulator(_fed(compressor="qsgd", qsgd_bits=6),
                                    _sim(1), HeteroConfig(),
                                    x, y, xt, yt, parts).run()
        deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
        assert not deps, [str(d.message) for d in deps]

    def test_examples_use_no_deprecated_hooks(self):
        """All five examples must be clean of the old hook surface, so they
        run warning-free on the new API."""
        root = pathlib.Path(__file__).resolve().parents[1] / "examples"
        deprecated = ("compress_delta", "_gather_states", "_scatter_states")
        offenders = []
        files = sorted(root.glob("*.py"))
        assert len(files) == 5
        for f in files:
            src = f.read_text()
            offenders += [f"{f.name}:{name}" for name in deprecated
                          if name in src]
        assert not offenders, offenders
