"""Self-confidence KD (Sec. III) invariants + baseline loss sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import distillation as D


def logits_pair(seed, B=16, C=10):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(k1, (B, C)), jax.random.normal(k2, (B, C)),
            jax.random.randint(k3, (B,), 0, C))


class TestSelfConfidenceTargets:
    def test_targets_are_distribution(self):
        s, t, y = logits_pair(0)
        rho = jnp.linspace(0.1, 1.0, 10)
        tgt = D.self_confidence_targets(t, y, rho, tau=1.0)
        np.testing.assert_allclose(tgt.sum(-1), 1.0, rtol=1e-5)
        assert bool(jnp.all(tgt >= -1e-6))

    def test_iid_reduces_to_onehot(self):
        """Paper claim: iid data ⇒ ρ_i ≈ 1 ∀i ⇒ target ≈ one-hot ⇒ loss ≈ CE."""
        s, t, y = logits_pair(1)
        rho = jnp.ones(10)
        tgt = D.self_confidence_targets(t, y, rho, tau=1.0)
        onehot = jax.nn.one_hot(y, 10)
        np.testing.assert_allclose(tgt, onehot, atol=1e-6)

    def test_iid_loss_is_ce_scaled(self):
        s, t, y = logits_pair(2)
        counts = jnp.full((10,), 100.0)       # perfectly balanced client
        loss, aux = D.self_confidence_kd_loss(s, t, y, counts, lam=0.35,
                                              tau=1.0)
        # KD term against one-hot at tau=1 IS the CE, so loss == CE
        np.testing.assert_allclose(loss, aux["ce"], rtol=1e-4)

    def test_missing_class_gets_full_teacher_mass(self):
        """ρ_i = 0 for a class absent locally ⇒ the teacher's opinion on it
        is fully preserved (no unintended forgetting)."""
        s, t, y = logits_pair(3)
        rho = jnp.ones(10).at[7].set(0.0)
        tgt = D.self_confidence_targets(t, y, rho, tau=1.0)
        pt = D.softmax_T(t, 1.0)
        nontrue = (y != 7)
        np.testing.assert_allclose(tgt[nontrue, 7], pt[nontrue, 7], rtol=1e-5)

    def test_class_confidence_normalisation(self):
        counts = jnp.array([10.0, 40.0, 0.0, 20.0])
        rho = D.class_confidence(counts)
        np.testing.assert_allclose(rho, [0.25, 1.0, 0.0, 0.5])


class TestBaselineLosses:
    def test_kl_nonnegative(self):
        s, t, y = logits_pair(4)
        kl = D.kl_loss(s, D.softmax_T(t, 1.0), 1.0)
        assert float(kl) >= 0

    def test_kl_zero_iff_equal(self):
        s, _, _ = logits_pair(5)
        kl = D.kl_loss(s, D.softmax_T(s, 1.0), 1.0)
        np.testing.assert_allclose(kl, 0.0, atol=1e-5)

    def test_fedntd_ignores_true_class_logit(self):
        """FedNTD's KD term must be invariant to the teacher's true-class
        logit (distillation on non-true classes only)."""
        s, t, y = logits_pair(6)
        l1, _ = D.fedntd_loss(s, t, y, beta=1.0, tau=1.0)
        t_shift = t + 5.0 * jax.nn.one_hot(y, 10)
        l2, _ = D.fedntd_loss(s, t_shift, y, beta=1.0, tau=1.0)
        np.testing.assert_allclose(l1, l2, rtol=1e-4)

    def test_fedrs_scales_absent_classes(self):
        logits = jnp.ones((4, 10))
        present = jnp.zeros(10).at[jnp.array([0, 1])].set(1.0)
        out = D.fedrs_logits(logits, present, alpha=0.5)
        np.testing.assert_allclose(out[:, :2], 1.0)
        np.testing.assert_allclose(out[:, 2:], 0.5)

    def test_moon_prefers_global_features(self):
        k = jax.random.PRNGKey(7)
        z_g = jax.random.normal(k, (8, 32))
        z_p = -z_g
        loss_aligned = D.moon_loss(z_g, z_g, z_p, mu=1.0, temperature=0.5)
        loss_opposed = D.moon_loss(z_p, z_g, z_p, mu=1.0, temperature=0.5)
        assert float(loss_aligned) < float(loss_opposed)


@settings(max_examples=20, deadline=None)
@given(tau=st.floats(0.25, 4.0), lam=st.floats(0.0, 1.0), seed=st.integers(0, 50))
def test_property_targets_always_distribution(tau, lam, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    t = jax.random.normal(k1, (8, 6)) * 3
    y = jax.random.randint(k2, (8,), 0, 6)
    rho = jax.random.uniform(k3, (6,))
    tgt = D.self_confidence_targets(t, y, rho, tau)
    np.testing.assert_allclose(tgt.sum(-1), 1.0, rtol=1e-4)
    # true-class mass ≥ teacher's damped leftover (sanity: finite + in [0,1+eps])
    assert bool(jnp.all(jnp.isfinite(tgt)))


class TestMaskedSelfConfidenceKD:
    def test_masked_equals_unmasked_on_all_valid(self):
        s, t, y = logits_pair(11)
        counts = jnp.arange(1.0, 11.0)
        full, _ = D.self_confidence_kd_loss(s, t, y, counts, 0.4, 1.5)
        masked, _ = D.masked_self_confidence_kd_loss(
            s, t, y, counts, 0.4, 1.5, jnp.ones(s.shape[0], bool))
        np.testing.assert_allclose(masked, full, rtol=1e-5)

    def test_masked_drops_padded_positions(self):
        """Loss over [valid | junk-with-mask-0] equals loss over valid only."""
        s, t, y = logits_pair(12, B=16)
        counts = jnp.ones(10)
        mask = jnp.arange(16) < 10
        junk_s = s.at[10:].set(100.0)   # wild logits at padded positions
        want, _ = D.self_confidence_kd_loss(s[:10], t[:10], y[:10], counts,
                                            0.3, 1.0)
        got, _ = D.masked_self_confidence_kd_loss(junk_s, t, y, counts, 0.3,
                                                  1.0, mask)
        np.testing.assert_allclose(got, want, rtol=1e-5)

    def test_all_masked_is_finite(self):
        s, t, y = logits_pair(13)
        loss, _ = D.masked_self_confidence_kd_loss(
            s, t, y, jnp.ones(10), 0.5, 1.0, jnp.zeros(s.shape[0], bool))
        assert bool(jnp.isfinite(loss)) and float(loss) == 0.0
