"""Heterogeneity subsystem: weighted-reduce kernel vs oracle, staleness
math vs a hand-rolled numpy recursion, virtual-clock determinism, and the
sync/semi-async parity guarantee."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig, HeteroConfig
from repro.core import tree as T
from repro.core.strategies import get_strategy
from repro.data.partition import sort_and_partition
from repro.data.synthetic import make_image_dataset
from repro.federated import aggregation as A
from repro.federated.async_engine import AsyncFederatedSimulator
from repro.federated.hetero import (ClientSystemModel, fednova_scale,
                                    sample_speeds, staleness_discount)
from repro.federated.simulator import FederatedSimulator, SimConfig
from repro.kernels import ops, ref


@pytest.fixture(scope="module")
def data():
    x, y, xt, yt = make_image_dataset(600, 150, 10, image_size=16, seed=0,
                                      noise=0.5)
    parts = sort_and_partition(y, 10, s=2, seed=0)
    return x, y, xt, yt, parts


def _fed(strategy="fedadc", **kw):
    base = dict(local_steps=4, clients_per_round=3, n_clients=10, eta=0.03,
                beta_global=0.6, beta_local=0.6)
    base.update(kw)
    return FedConfig(strategy=strategy, **base)


def _sim(rounds=4, **kw):
    base = dict(model="cnn", n_classes=10, batch_size=16, rounds=rounds,
                eval_every=rounds, cnn_width=8, seed=1)
    base.update(kw)
    return SimConfig(**base)


# ---------------------------------------------------------------------------
# weighted-delta-reduce kernel vs the pure-jnp oracle (interpret mode on CPU)
# ---------------------------------------------------------------------------
class TestWeightedReduceKernel:
    @pytest.mark.parametrize("k,n", [(2, 128), (4, 1000), (7, 131),
                                     (3, 8192), (16, 64)])
    def test_matches_ref(self, k, n):
        kx, kw = jax.random.split(jax.random.PRNGKey(k * 1000 + n))
        d = jax.random.normal(kx, (k, n))
        w = jax.random.uniform(kw, (k,))
        got = ops.weighted_delta_reduce({"leaf": d}, w)["leaf"]
        np.testing.assert_allclose(got, ref.weighted_delta_reduce(d, w),
                                   rtol=1e-5, atol=1e-6)

    def test_pytree_and_shapes(self):
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        tree = {"w": jax.random.normal(k1, (5, 4, 3)),
                "b": jax.random.normal(k2, (5, 7))}
        w = jnp.asarray([0.1, 0.2, 0.3, 0.25, 0.15])
        out = ops.weighted_delta_reduce(tree, w)
        assert out["w"].shape == (4, 3) and out["b"].shape == (7,)
        np.testing.assert_allclose(
            out["w"], jnp.tensordot(w, tree["w"], axes=([0], [0])),
            rtol=1e-5, atol=1e-6)

    def test_weighted_mean_normalises(self):
        d = jnp.stack([jnp.full((8,), 2.0), jnp.full((8,), 6.0)])
        out = A.weighted_mean({"x": d}, jnp.asarray([1.0, 3.0]))["x"]
        np.testing.assert_allclose(out, 5.0, rtol=1e-6)   # (2+3·6)/4

    def test_pallas_hook_matches_plain(self):
        d = {"x": jax.random.normal(jax.random.PRNGKey(3), (4, 33))}
        w = jnp.asarray([0.4, 0.1, 0.3, 0.2])
        s = get_strategy("fedadc")
        plain = s.server_aggregate(d, w, _fed(use_pallas=False))
        fused = s.server_aggregate(d, w, _fed(use_pallas=True))
        np.testing.assert_allclose(plain["x"], fused["x"], rtol=1e-5,
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# aggregation weights
# ---------------------------------------------------------------------------
class TestAggregators:
    def test_uniform_and_examples(self):
        d = {"x": jnp.ones((3, 4))}
        np.testing.assert_allclose(A.compute_weights("uniform", d),
                                   np.ones(3))
        np.testing.assert_allclose(
            A.compute_weights("examples", d, n_examples=jnp.asarray(
                [10.0, 30.0, 60.0])), [10, 30, 60])

    def test_drag_downweights_divergent_delta(self):
        aligned = jnp.ones((8,))
        outlier = -jnp.ones((8,))
        d = {"x": jnp.stack([aligned, aligned * 1.1, outlier])}
        w = A.compute_weights("drag", d, ref={"x": aligned}, lam=4.0)
        assert float(w[0]) > 0.9 * float(w[1])
        assert float(w[2]) < 0.05 * float(w[0])

    def test_drag_scale_invariant(self):
        d = {"x": jnp.stack([jnp.ones(4), -jnp.ones(4)])}
        w1 = A.compute_weights("drag", d, ref={"x": jnp.ones(4)})
        w2 = A.compute_weights("drag", d, ref={"x": 100.0 * jnp.ones(4)})
        np.testing.assert_allclose(w1, w2, rtol=1e-5)

    def test_streaming_rejects_unknown_and_refless_drag(self):
        d = {"x": jnp.ones(4)}
        with pytest.raises(ValueError):
            A.streaming_weight(d, None, "bogus", 1.0)
        with pytest.raises(ValueError):
            A.streaming_weight(d, None, "drag", 1.0)

    def test_weighted_aggregation_rejected_for_stateful_strategies(self, data):
        x, y, xt, yt, parts = data
        with pytest.raises(ValueError):
            FederatedSimulator(_fed("scaffold", aggregator="drag"), _sim(),
                               x, y, xt, yt, parts)

    def test_pod_engine_rejects_refless_drag(self):
        from repro.configs import ARCHS
        from repro.configs.base import RunConfig
        from repro.launch.train import make_train_step
        with pytest.raises(ValueError):
            make_train_step(ARCHS["qwen3-4b"].reduced(),
                            _fed("fedavg", aggregator="drag"), RunConfig())

    def test_streaming_matches_stacked(self):
        k = jax.random.PRNGKey(5)
        deltas = jax.random.normal(k, (4, 16))
        ref_dir = {"x": jnp.ones(16)}
        stacked = A.compute_weights("drag", {"x": deltas}, ref=ref_dir,
                                    lam=2.0)
        streamed = [A.streaming_weight({"x": deltas[i]}, ref_dir, "drag", 2.0)
                    for i in range(4)]
        np.testing.assert_allclose(stacked, np.asarray(streamed), rtol=1e-5)


# ---------------------------------------------------------------------------
# hetero system model + staleness algebra
# ---------------------------------------------------------------------------
class TestHeteroModel:
    def test_speed_distributions(self):
        rng = np.random.RandomState(0)
        h = HeteroConfig(enabled=True, speed_dist="bimodal",
                         straggler_frac=0.5, straggler_slowdown=4.0)
        s = sample_speeds(h, 1000, rng)
        assert set(np.unique(s)) == {0.25, 1.0}
        h2 = HeteroConfig(enabled=True, speed_dist="lognormal")
        s2 = sample_speeds(h2, 100, rng)
        assert s2.max() == 1.0 and s2.min() > 0

    def test_round_time_scales_with_slowdown(self):
        h = HeteroConfig(enabled=True, speed_dist="bimodal",
                         straggler_frac=0.5, straggler_slowdown=4.0, seed=0)
        m = ClientSystemModel(h, 100, base_local_steps=8)
        fast = [m.round_time(c) for c in range(100) if m.speeds[c] == 1.0]
        slow = [m.round_time(c) for c in range(100) if m.speeds[c] == 0.25]
        np.testing.assert_allclose(np.mean(slow) / np.mean(fast), 4.0)

    def test_fednova_scale(self):
        assert fednova_scale(2, 8) == 4.0
        assert fednova_scale(8, 8) == 1.0

    def test_staleness_discount_vs_numpy(self):
        s = np.arange(5)
        np.testing.assert_allclose(staleness_discount(s, "none", 0.5),
                                   np.ones(5))
        np.testing.assert_allclose(staleness_discount(s, "poly", 0.5),
                                   (1.0 + s) ** -0.5)
        np.testing.assert_allclose(staleness_discount(s, "exp", 0.7),
                                   0.7 ** s)

    def test_staleness_corrected_momentum_recursion(self):
        """Server-side FedADC recursion with per-delta staleness discounts
        equals a hand-rolled numpy recursion:
          m ← (β_g−β_l)·m + (Σ wn_i·c(s_i)·Δ_i)/η ;  θ ← θ − αη·m."""
        fed = _fed("fedadc", staleness_mode="poly", staleness_factor=0.5)
        s = get_strategy("fedadc")
        rng = np.random.RandomState(0)
        theta = {"w": jnp.asarray(rng.randn(6), jnp.float32)}
        state = {"m": {"w": jnp.asarray(rng.randn(6), jnp.float32)}}
        theta_np = np.asarray(theta["w"], np.float64)
        m_np = np.asarray(state["m"]["w"], np.float64)
        for step in range(3):
            deltas = rng.randn(4, 6).astype(np.float32) * 0.01
            stale = np.asarray([0, 1, 2, 0])
            disc = staleness_discount(stale, "poly", 0.5)
            scaled = {"w": jnp.asarray(deltas * disc[:, None])}
            w = A.compute_weights("uniform", scaled)
            mean_delta = s.server_aggregate(scaled, w, fed)
            theta, state = s.server_update(state, theta, mean_delta, fed)
            # numpy oracle
            dbar = (deltas.astype(np.float64) * disc[:, None]).mean(0)
            m_np = (fed.beta_global - fed.beta_local) * m_np + dbar / fed.eta
            theta_np = theta_np - fed.alpha * fed.eta * m_np
            np.testing.assert_allclose(state["m"]["w"], m_np, rtol=1e-4)
            np.testing.assert_allclose(theta["w"], theta_np, rtol=1e-4)


# ---------------------------------------------------------------------------
# virtual-clock engine
# ---------------------------------------------------------------------------
HETERO = HeteroConfig(enabled=True, speed_dist="bimodal", straggler_frac=0.3,
                      straggler_slowdown=4.0, local_steps_choices=(2, 4, 8),
                      drop_prob=0.05, seed=3)


class TestAsyncEngine:
    def test_scheduler_deterministic_under_fixed_seed(self, data):
        x, y, xt, yt, parts = data
        fed = _fed("fedadc", clients_per_round=4, buffer_k=2)
        runs = []
        for _ in range(2):
            e = AsyncFederatedSimulator(fed, _sim(rounds=5), HETERO,
                                        x, y, xt, yt, parts)
            h = e.run()
            runs.append((e.event_log, e.staleness_hist.to_dict(), h))
        assert runs[0][0] == runs[1][0]      # identical event sequences
        assert runs[0][1] == runs[1][1]
        assert runs[0][2] == runs[1][2]

    def test_semi_async_sees_staleness(self, data):
        x, y, xt, yt, parts = data
        fed = _fed("fedadc", clients_per_round=4, buffer_k=2)
        e = AsyncFederatedSimulator(fed, _sim(rounds=5), HETERO,
                                    x, y, xt, yt, parts)
        h = e.run()
        assert e.staleness_hist.max >= 1     # stale deltas actually occurred
        assert np.isfinite(h[-1]["loss"])

    def test_sync_barrier_mode_has_zero_staleness(self, data):
        x, y, xt, yt, parts = data
        fed = _fed("fedadc", clients_per_round=3)     # buffer_k == K
        e = AsyncFederatedSimulator(fed, _sim(rounds=3), HETERO,
                                    x, y, xt, yt, parts)
        e.run()
        assert e.staleness_hist.max == 0 and e.staleness_hist.count > 0

    def test_stateful_strategies_rejected(self, data):
        x, y, xt, yt, parts = data
        with pytest.raises(ValueError):
            AsyncFederatedSimulator(_fed("scaffold"), _sim(), HeteroConfig(),
                                    x, y, xt, yt, parts)

    @pytest.mark.parametrize("strategy", ["fedavg", "fedadc"])
    def test_parity_with_synchronous_simulator(self, data, strategy):
        """Acceptance: hetero off ⇒ the async engine reproduces the
        synchronous round trajectory to numerical tolerance."""
        x, y, xt, yt, parts = data
        fed = _fed(strategy)
        sync = FederatedSimulator(fed, _sim(rounds=4), x, y, xt, yt, parts)
        h_sync = sync.run()
        asyn = AsyncFederatedSimulator(fed, _sim(rounds=4), HeteroConfig(),
                                       x, y, xt, yt, parts)
        h_async = asyn.run()
        assert asyn.staleness_hist.max == 0
        for hs, ha in zip(h_sync, h_async):
            assert hs["round"] == ha["round"]
            np.testing.assert_allclose(hs["loss"], ha["loss"], rtol=2e-4)
            np.testing.assert_allclose(hs["acc"], ha["acc"], atol=1e-8)
        for a, b in zip(jax.tree.leaves(sync.params),
                        jax.tree.leaves(asyn.params)):
            np.testing.assert_allclose(a, b, rtol=2e-4, atol=1e-6)

    def test_variable_local_work_fednova_runs(self, data):
        x, y, xt, yt, parts = data
        hetero = HeteroConfig(enabled=True, local_steps_choices=(2, 8),
                              fednova=True, seed=1)
        fed = _fed("fedadc", clients_per_round=3)
        e = AsyncFederatedSimulator(fed, _sim(rounds=3), hetero,
                                    x, y, xt, yt, parts)
        h = e.run()
        assert np.isfinite(h[-1]["loss"])
        scales = {e.system.delta_scale(c) for c in range(e.n_clients)}
        assert len(scales) > 1               # normalisation actually varies
