"""Telemetry subsystem: drift-metric math vs numpy oracles, the
tracer/counters/histogram primitives, JSONL schema + sinks, latency
summaries, and the engine contracts — disabled path bit-identical on all
three engines, enabling adds no jit retrace, and the async staleness
histogram stays bounded and resets per run()."""
import io
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig, HeteroConfig
from repro.data.partition import sort_and_partition
from repro.data.synthetic import make_image_dataset
from repro.federated.async_engine import AsyncFederatedSimulator
from repro.federated.simulator import FederatedSimulator, SimConfig
from repro.serving.request import RequestOutput
from repro.telemetry import (Counters, Histogram, JsonlSink, Telemetry,
                             Tracer, delta_dispersion, ef_residual_norm,
                             latency_summary, momentum_alignment,
                             prometheus_text, request_itl, round_metrics,
                             streaming_dispersion, streaming_sq_norm,
                             update_norm, validate_event, validate_jsonl)


# ---------------------------------------------------------------------------
# drift metric math
# ---------------------------------------------------------------------------
class TestDriftMetrics:
    def _stacked(self, k=5, n=64, seed=0):
        rng = np.random.RandomState(seed)
        d = rng.randn(k, n).astype(np.float32)
        tree = {"w": jnp.asarray(d)}
        mean = {"w": jnp.asarray(d.mean(0))}
        return d, tree, mean

    def test_dispersion_zero_for_identical_deltas(self):
        d = jnp.ones((4, 16))
        out = delta_dispersion({"w": d}, {"w": d[0]})
        assert float(out) == pytest.approx(0.0, abs=1e-6)

    def test_dispersion_matches_numpy(self):
        d, tree, mean = self._stacked()
        dbar = d.mean(0)
        want = np.mean(((d - dbar) ** 2).sum(-1)) / (dbar ** 2).sum()
        got = float(delta_dispersion(tree, mean))
        np.testing.assert_allclose(got, want, rtol=1e-5)

    def test_streaming_matches_stacked_uniform_weights(self):
        d, tree, mean = self._stacked(k=6)
        sq = sum(float(streaming_sq_norm({"w": jnp.asarray(row)},
                                         jnp.float32(1.0))) for row in d)
        got = float(streaming_dispersion(jnp.float32(sq), jnp.float32(6.0),
                                         mean))
        want = float(delta_dispersion(tree, mean))
        np.testing.assert_allclose(got, want, rtol=1e-4)

    def test_alignment_signs(self):
        v = {"w": jnp.asarray([1.0, 2.0, 3.0])}
        neg = {"w": jnp.asarray([-1.0, -2.0, -3.0])}
        assert float(momentum_alignment(v, v)) == pytest.approx(1.0, abs=1e-5)
        assert float(momentum_alignment(v, neg)) == pytest.approx(-1.0,
                                                                  abs=1e-5)

    def test_ef_residual_and_update_norm(self):
        efs = {"w": jnp.asarray([[3.0, 4.0], [0.0, 0.0]])}  # norms 5, 0
        assert float(ef_residual_norm(efs)) == pytest.approx(2.5, abs=1e-5)
        assert float(update_norm({"w": jnp.asarray([3.0, 4.0])})) == \
            pytest.approx(5.0, abs=1e-5)

    def test_round_metrics_keys_are_static(self):
        d, tree, mean = self._stacked(k=3)
        base = round_metrics(tree, mean)
        assert set(base) == {"delta_dispersion", "update_norm"}
        full = round_metrics(tree, mean, momentum=mean,
                             efs={"w": jnp.ones((3, 64))})
        assert set(full) == {"delta_dispersion", "update_norm",
                             "momentum_alignment", "ef_residual_norm"}


# ---------------------------------------------------------------------------
# tracer / counters / histogram primitives
# ---------------------------------------------------------------------------
class TestTracer:
    def test_nested_span_names(self):
        tr = Tracer(enabled=True)
        with tr.span("round"):
            with tr.span("local_train"):
                pass
        s = tr.summary()
        assert set(s) == {"round", "round/local_train"}
        assert s["round"]["count"] == 1 and s["round"]["total_s"] >= 0.0
        assert {"p50_s", "p95_s"} <= set(s["round"])
        assert len(tr.timings("round/local_train")) == 1

    def test_disabled_tracer_records_nothing(self):
        tr = Tracer(enabled=False)
        with tr.span("round"):
            pass
        assert tr.timings("round") == [] and tr.summary() == {}

    def test_bounded_reservoir_exact_count(self):
        tr = Tracer(enabled=True, maxlen=8)
        for _ in range(50):
            with tr.span("x"):
                pass
        assert len(tr.timings("x")) == 8      # reservoir bounded
        assert tr.summary()["x"]["count"] == 50   # count stays exact


class TestCounters:
    def test_int_arithmetic_stays_int(self):
        c = Counters()
        c.inc("bytes", 3)
        c.inc("bytes", 4)
        assert c.get("bytes") == 7 and isinstance(c.get("bytes"), int)
        assert c.get("missing") == 0
        c.set("gauge", 2.5)
        assert c.snapshot() == {"bytes": 7, "gauge": 2.5}
        assert "bytes" in c and "nope" not in c


class TestHistogram:
    def test_bounded_with_overflow_and_exact_moments(self):
        h = Histogram(n_bins=4)
        h.observe_many([0, 1, 2, 3, 9])     # 9 lands in overflow
        assert h.count == 5 and h.overflow == 1
        assert h.max == 9 and h.total == 15
        assert h.mean() == pytest.approx(3.0)
        d = h.to_dict()
        assert d["count"] == 5 and d["overflow"] == 1

    def test_reset_and_negative_rejection(self):
        h = Histogram()
        h.observe(2)
        h.reset()
        assert h.count == 0 and h.max == 0 and h.mean() == 0.0
        with pytest.raises(ValueError):
            h.observe(-1)


# ---------------------------------------------------------------------------
# schema + sinks + exporters
# ---------------------------------------------------------------------------
class TestSchema:
    def _round(self):
        return {"ts": 1.0, "kind": "round", "engine": "sim",
                "round": 3, "metrics": {"loss": 0.5}}

    def test_valid_events(self):
        validate_event(self._round())
        validate_event({"ts": 1.0, "kind": "request", "engine": "serving",
                        "rid": 0, "n_tokens": 1, "ttft_s": 0.1,
                        "itl_s": None, "e2e_s": 0.1})  # itl_s nullable

    def test_unknown_kind_rejected(self):
        ev = self._round()
        ev["kind"] = "mystery"
        with pytest.raises(ValueError, match="kind"):
            validate_event(ev)

    def test_missing_field_rejected(self):
        ev = self._round()
        del ev["metrics"]
        with pytest.raises(ValueError):
            validate_event(ev)

    def test_bool_is_not_a_number(self):
        ev = {"ts": 1.0, "kind": "eval", "engine": "sim", "round": 1,
              "acc": True, "loss": 0.1}
        with pytest.raises(ValueError):
            validate_event(ev)

    def test_validate_jsonl(self, tmp_path):
        p = tmp_path / "t.jsonl"
        p.write_text(json.dumps(self._round()) + "\n")
        assert validate_jsonl(str(p)) == 1
        (tmp_path / "e.jsonl").write_text("")
        with pytest.raises(ValueError):
            validate_jsonl(str(tmp_path / "e.jsonl"))


class TestJsonlSink:
    def test_owned_path_roundtrip(self, tmp_path):
        p = tmp_path / "s.jsonl"
        with JsonlSink(str(p)) as sink:
            sink.emit({"ts": 0.0, "kind": "summary", "engine": "sim",
                        "counters": {"rounds": 1}})
        assert sink.n_events == 1 and validate_jsonl(str(p)) == 1

    def test_borrowed_object_not_closed(self):
        buf = io.StringIO()
        sink = JsonlSink(buf)
        sink.emit({"ts": 0.0, "kind": "summary", "engine": "x",
                    "counters": {}})
        sink.close()
        assert not buf.closed and buf.getvalue().count("\n") == 1

    def test_invalid_event_raises(self):
        with pytest.raises(ValueError):
            JsonlSink(io.StringIO()).emit({"kind": "round"})


class TestPrometheus:
    def test_counters_and_histogram_text(self):
        c = Counters()
        c.inc("transport.uplink_bytes", 128)
        h = Histogram(n_bins=2)
        h.observe_many([0, 1, 1])
        text = prometheus_text(c, {"staleness": h})
        assert "repro_transport_uplink_bytes 128" in text
        assert 'repro_staleness_bucket{le="+Inf"} 3' in text
        assert "repro_staleness_count 3" in text
        # buckets are cumulative
        lines = [l for l in text.splitlines() if "_bucket" in l]
        counts = [int(l.rsplit(" ", 1)[1]) for l in lines]
        assert counts == sorted(counts)


# ---------------------------------------------------------------------------
# latency summaries (satellite a)
# ---------------------------------------------------------------------------
def _out(rid, arrival, first, finish, n_tokens):
    return RequestOutput(rid, [1], list(range(n_tokens)), arrival, first,
                         finish)


class TestLatency:
    def test_summary_on_synthetic_timestamps(self):
        # TTFTs 0.1..1.0 and e2e 0.2..2.0 over 10 requests: nearest-rank
        # p50 takes sorted index int(0.5*10) = 5, p95 the last value.
        outs = [_out(i, 0.0, 0.1 * (i + 1), 0.2 * (i + 1), 5)
                for i in range(10)]
        s = latency_summary(outs)
        assert s["n_requests"] == 10 and s["n_tokens"] == 50
        assert s["ttft_s"]["p50"] == pytest.approx(0.6)
        assert s["ttft_s"]["p95"] == pytest.approx(1.0)
        assert s["ttft_s"]["mean"] == pytest.approx(0.55)
        assert s["e2e_s"]["p50"] == pytest.approx(1.2)
        assert s["e2e_s"]["p95"] == pytest.approx(2.0)
        # ITL = (finish - first)/(n-1) per request
        want_itl = sorted((0.1 * (i + 1)) / 4 for i in range(10))
        assert s["itl_s"]["p50"] == pytest.approx(want_itl[5])
        assert s["n_itl_requests"] == 10

    def test_itl_none_for_single_token(self):
        single = _out(0, 0.0, 0.1, 0.1, 1)
        assert request_itl(single) is None and single.itl is None
        multi = _out(1, 0.0, 0.1, 0.5, 5)
        assert multi.itl == pytest.approx(0.1)
        s = latency_summary([single, multi])
        assert s["n_itl_requests"] == 1 and s["itl_s"] is not None

    def test_all_single_token_gives_null_itl(self):
        s = latency_summary([_out(0, 0.0, 0.1, 0.1, 1)])
        assert s["itl_s"] is None

    def test_empty_raises(self):
        with pytest.raises(ValueError):
            latency_summary([])


# ---------------------------------------------------------------------------
# Telemetry facade
# ---------------------------------------------------------------------------
class TestTelemetryFacade:
    def test_disabled_is_inert_but_history_lives(self):
        tel = Telemetry.disabled("sim")
        tel.record_round(0, {"loss": 1.0})
        tel.record_eval({"round": 1, "acc": 0.5, "loss": 1.0})
        assert len(tel.drift_curve) == 0 and tel.counters.snapshot() == {}
        assert tel.history == [{"round": 1, "acc": 0.5, "loss": 1.0}]

    def test_enabled_records_rounds(self):
        tel = Telemetry(engine="sim")
        tel.record_round(0, {"loss": 1.0, "delta_dispersion": 0.2})
        assert tel.counters.get("rounds") == 1
        assert tel.drift_curve[0]["delta_dispersion"] == pytest.approx(0.2)
        d = tel.drift_summary()
        assert d["delta_dispersion"] == {"first": 0.2, "last": 0.2}

    def test_jsonl_requires_enabled(self):
        with pytest.raises(ValueError):
            Telemetry(enabled=False, jsonl=io.StringIO())

    def test_emit_stream_is_schema_valid(self, tmp_path):
        p = tmp_path / "t.jsonl"
        tel = Telemetry(jsonl=str(p), engine="sim")
        tel.record_round(0, {"loss": 0.3})
        tel.record_eval({"round": 1, "acc": 0.1, "loss": 0.3})
        tel.emit_summary()
        tel.close()
        assert validate_jsonl(str(p)) == 3


# ---------------------------------------------------------------------------
# engine contracts (satellites b + c)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def data():
    x, y, xt, yt = make_image_dataset(600, 150, 10, image_size=16, seed=0,
                                      noise=0.5)
    parts = sort_and_partition(y, 10, s=2, seed=0)
    return x, y, xt, yt, parts


def _fed(**kw):
    base = dict(strategy="fedadc", local_steps=2, clients_per_round=3,
                n_clients=10, eta=0.03, beta_global=0.6, beta_local=0.6)
    base.update(kw)
    return FedConfig(**base)


def _simcfg(rounds=3):
    return SimConfig(model="cnn", n_classes=10, batch_size=16, rounds=rounds,
                     eval_every=rounds, cnn_width=8, seed=1)


def _leaves_equal(a, b):
    return all(bool(jnp.all(x == y)) for x, y in
               zip(jax.tree.leaves(a), jax.tree.leaves(b)))


class TestEngineContracts:
    def test_sync_disabled_bit_identical_and_no_retrace(self, data):
        x, y, xt, yt, parts = data
        off = FederatedSimulator(_fed(), _simcfg(), x, y, xt, yt, parts)
        h_off = off.run()
        tel = Telemetry(engine="sim")
        on = FederatedSimulator(_fed(), _simcfg(), x, y, xt, yt, parts,
                                telemetry=tel)
        h_on = on.run()
        assert _leaves_equal(off.params, on.params)
        assert [e["acc"] for e in h_off] == [e["acc"] for e in h_on]
        # enabling telemetry costs exactly one trace of the round function
        assert on._round_fn._cache_size() == 1
        assert off._round_fn._cache_size() == 1
        # drift diagnostics recorded every round, momentum metric present
        assert len(tel.drift_curve) == 3
        assert {"delta_dispersion", "momentum_alignment", "update_norm",
                "loss"} <= set(tel.drift_curve[0])

    def test_sync_ef_metrics_present(self, data):
        x, y, xt, yt, parts = data
        tel = Telemetry(engine="sim")
        FederatedSimulator(_fed(compressor="topk", topk_frac=0.1,
                                error_feedback=True),
                           _simcfg(), x, y, xt, yt, parts,
                           telemetry=tel).run()
        assert "ef_residual_norm" in tel.drift_curve[0]

    def test_async_disabled_bit_identical(self, data):
        x, y, xt, yt, parts = data
        hetero = HeteroConfig(enabled=True, speed_dist="bimodal",
                              straggler_frac=0.3, straggler_slowdown=3.0)
        fed = _fed(clients_per_round=4, buffer_k=2)
        off = AsyncFederatedSimulator(fed, _simcfg(), hetero, x, y, xt, yt,
                                      parts)
        off.run()
        tel = Telemetry(engine="async")
        on = AsyncFederatedSimulator(fed, _simcfg(), hetero, x, y, xt, yt,
                                     parts, telemetry=tel)
        on.run()
        assert _leaves_equal(off.params, on.params)
        assert len(tel.drift_curve) > 0
        assert {"delta_dispersion", "staleness_mean",
                "staleness_max"} <= set(tel.drift_curve[0])

    def test_async_staleness_hist_resets_per_run(self, data):
        """Regression: the old unbounded ``staleness_seen`` list kept
        growing across consecutive run() calls, double-counting every
        earlier round's staleness in the second run's summary."""
        x, y, xt, yt, parts = data
        e = AsyncFederatedSimulator(_fed(clients_per_round=4, buffer_k=2),
                                    _simcfg(), HeteroConfig(), x, y, xt, yt,
                                    parts)
        e.run()
        first = e.staleness_hist.to_dict()
        assert first["count"] > 0
        # run() counts cumulative server versions: ask for 3 more updates.
        # Each run applies 3 updates of K=2 flushes, so both observe the
        # same number of staleness values — without the per-run reset the
        # histogram would report double.
        e.run(rounds=6)
        assert e.version == 6
        assert e.staleness_hist.to_dict()["count"] == first["count"]

    def test_pod_disabled_aux_and_bit_identity(self):
        from repro.configs import ARCHS
        from repro.configs.base import RunConfig
        from repro.launch.mesh import make_host_mesh
        from repro.launch.train import init_state, make_train_step
        mcfg = ARCHS["qwen3-4b"].reduced()
        fed = FedConfig(strategy="fedadc", clients_per_round=2,
                        local_steps=2, eta=0.05)
        run = RunConfig(remat="none", param_dtype="float32",
                        compute_dtype="float32")
        mesh = make_host_mesh()
        with mesh:
            state = init_state(jax.random.PRNGKey(0), mcfg, fed, run)
            rng = np.random.RandomState(0)
            toks = rng.randint(0, mcfg.vocab_size, size=(1, 2, 2, 2, 16))
            batch = {"tokens": jnp.asarray(toks, jnp.int32),
                     "labels": jnp.asarray(toks, jnp.int32)}
            s_off, aux_off = make_train_step(mcfg, fed, run)(state, batch)
            assert set(aux_off) == {"loss"}    # disabled: no extra outputs
            tel = Telemetry(engine="pod")
            s_on, aux_on = make_train_step(mcfg, fed, run,
                                           telemetry=tel)(state, batch)
            assert _leaves_equal(s_off["params"], s_on["params"])
            assert _leaves_equal(s_off["server"], s_on["server"])
            m = aux_on["telemetry"]
            assert {"delta_dispersion", "update_norm",
                    "momentum_alignment"} <= set(m)
            assert all(bool(jnp.isfinite(v)) for v in m.values())
