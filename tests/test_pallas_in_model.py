"""Integration: the use_pallas code paths inside the models produce the same
numerics as the default jnp paths (interpret mode on CPU), and the pod
engine runs the paper's variants end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import FedConfig, RunConfig
from repro.launch.train import init_state, make_train_step
from repro.models.registry import get_model


def _mk_batch(cfg, B=2, L=128):
    tokens = (jax.random.randint(jax.random.PRNGKey(1), (B, L), 0,
                                 cfg.vocab_size)).astype(jnp.int32)
    return {"tokens": tokens, "labels": tokens}


def test_mamba2_pallas_path_matches_jnp():
    cfg = ARCHS["zamba2-1.2b"].reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    batch = _mk_batch(cfg)
    a, _ = model.forward(params, batch, cfg, use_pallas=False)
    b, _ = model.forward(params, batch, cfg, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=5e-3,
                               rtol=5e-3)


def test_attention_pallas_path_matches_jnp():
    cfg = ARCHS["mistral-large-123b"].reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    batch = _mk_batch(cfg)
    a, _ = model.forward(params, batch, cfg, use_pallas=False)
    b, _ = model.forward(params, batch, cfg, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=5e-3,
                               rtol=5e-3)


@pytest.mark.parametrize("strategy", ["fedadc_double", "fedprox", "slowmo"])
def test_pod_engine_strategy_variants(strategy):
    cfg = ARCHS["qwen3-4b"].reduced()
    fed = FedConfig(strategy=strategy, clients_per_round=2, local_steps=2,
                    eta=0.01)
    run = RunConfig(remat="none")
    state = init_state(jax.random.PRNGKey(0), cfg, fed, run)
    step = jax.jit(make_train_step(cfg, fed, run))
    batch1 = _mk_batch(cfg, 2, 32)
    batch = jax.tree.map(lambda x: jnp.broadcast_to(x, (1, 2, 2) + x.shape),
                         batch1)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))


def test_pod_engine_fedadc_plus_distill():
    """FedADC+ on the pod engine: self-confidence KD with token-frequency ρ."""
    cfg = ARCHS["qwen3-4b"].reduced()
    fed = FedConfig(strategy="fedadc", clients_per_round=2, local_steps=2,
                    eta=0.01, distill=True, distill_lambda=0.35)
    run = RunConfig(remat="none")
    state = init_state(jax.random.PRNGKey(0), cfg, fed, run)
    step = jax.jit(make_train_step(cfg, fed, run))
    batch1 = _mk_batch(cfg, 2, 32)
    batch = jax.tree.map(lambda x: jnp.broadcast_to(x, (1, 2, 2) + x.shape),
                         batch1)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))


def test_pod_engine_rejects_stateful_strategies():
    cfg = ARCHS["qwen3-4b"].reduced()
    fed = FedConfig(strategy="scaffold")
    with pytest.raises(ValueError):
        make_train_step(cfg, fed, RunConfig())


def test_mixed_precision_round_preserves_master_dtype():
    cfg = ARCHS["qwen3-4b"].reduced()
    fed = FedConfig(strategy="fedadc", clients_per_round=2, local_steps=2,
                    eta=0.01)
    run = RunConfig(param_dtype="float32", compute_dtype="bfloat16")
    state = init_state(jax.random.PRNGKey(0), cfg, fed, run)
    step = jax.jit(make_train_step(cfg, fed, run))
    batch1 = _mk_batch(cfg, 2, 32)
    batch = jax.tree.map(lambda x: jnp.broadcast_to(x, (1, 2, 2) + x.shape),
                         batch1)
    new_state, _ = step(state, batch)
    for leaf in jax.tree.leaves(new_state["params"]):
        assert leaf.dtype == jnp.float32      # f32 master survives
    for leaf in jax.tree.leaves(new_state["server"]["m"]):
        assert leaf.dtype == jnp.float32
