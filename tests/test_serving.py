"""Continuous-batching serving engine tests.

The load-bearing property: the scheduler's output for any request is
identical (greedy) to running that request alone — per-slot cache rows are
isolated, masked writes keep a mid-prefill slot untouched by interleaved
decode steps, and the sampling PRNG is keyed per (request, position).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.registry import get_model
from repro.serving import (CacheManager, SamplingParams, Scheduler,
                           SchedulerConfig, ServingEngine, sample_tokens)
from repro.serving.request import Request, RequestQueue

MAX_LEN = 96


def _prompts(cfg, n, seed=0, lo=3, hi=24):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, rng.randint(lo, hi)).tolist()
            for _ in range(n)]


def _sched(n_slots, chunk=8):
    return SchedulerConfig(n_slots=n_slots, max_len=MAX_LEN,
                           prefill_chunk=chunk, page_size=16)


def _engine_outputs(cfg, params, prompts, n_slots, gen=8, chunk=8):
    eng = ServingEngine(cfg, params=params, sched=_sched(n_slots, chunk))
    for p in prompts:
        eng.add_request(p, max_new_tokens=gen)
    return [o.tokens for o in eng.run()]


# ---------------------------------------------------------------------------
# Greedy identity: batched == alone, across cache families
# ---------------------------------------------------------------------------
SERVE_ARCHS = ["qwen3-4b",        # dense GQA ring cache
               "zamba2-1.2b",     # hybrid: mamba2 state + shared-attn KV
               "xlstm-350m",      # pure SSM state slots (m/sLSTM)
               "deepseek-v3-671b"]  # MLA latent cache + MoE


@pytest.mark.parametrize("arch", SERVE_ARCHS)
def test_batched_greedy_identical_to_alone(arch):
    from dataclasses import replace
    cfg = ARCHS[arch].reduced()
    if cfg.moe is not None:
        # dropless capacity: finite-capacity routing competes across the
        # batch, an inherent MoE serve skew (DESIGN.md §MoE)
        cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=8.0))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(cfg, 5)
    batched = _engine_outputs(cfg, params, prompts, n_slots=4)
    serial = _engine_outputs(cfg, params, prompts, n_slots=1)
    assert batched == serial


def test_prefill_chunk_size_invariant():
    """Chunked prefill is exact: chunk=4 and chunk=64 (prompt in one go)
    produce identical continuations, including the partial last chunk."""
    cfg = ARCHS["qwen3-4b"].reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(cfg, 3, seed=1, lo=5, hi=30)
    a = _engine_outputs(cfg, params, prompts, n_slots=2, chunk=4)
    b = _engine_outputs(cfg, params, prompts, n_slots=2, chunk=64)
    assert a == b


def test_more_requests_than_slots_all_complete_fifo():
    cfg = ARCHS["qwen3-4b"].reduced()
    eng = ServingEngine(cfg, sched=_sched(n_slots=2))
    prompts = _prompts(cfg, 7)
    rids = [eng.add_request(p, max_new_tokens=5) for p in prompts]
    outs = eng.run()
    assert [o.rid for o in outs] == rids
    assert all(len(o.tokens) == 5 for o in outs)
    assert not eng.has_work()
    assert eng.cachemgr.free_pages == eng.cachemgr.total_pages


def test_mid_flight_admission():
    """A request submitted while others are decoding is admitted, prefills
    interleaved, and does not perturb in-flight greedy outputs."""
    cfg = ARCHS["qwen3-4b"].reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(cfg, 3, seed=2)
    alone = _engine_outputs(cfg, params, prompts, n_slots=1, gen=12)

    eng = ServingEngine(cfg, params=params, sched=_sched(n_slots=4))
    eng.add_request(prompts[0], max_new_tokens=12)
    eng.add_request(prompts[1], max_new_tokens=12)
    outs = []
    for _ in range(6):
        outs.extend(eng.step())
    eng.add_request(prompts[2], max_new_tokens=12)   # mid-flight
    while eng.has_work():
        outs.extend(eng.step())
    got = {o.rid: o.tokens for o in outs}
    assert [got[i] for i in range(3)] == alone


# ---------------------------------------------------------------------------
# Engine API edges
# ---------------------------------------------------------------------------
def test_engine_rejects_encdec_and_overlong():
    with pytest.raises(ValueError, match="decoder-only"):
        ServingEngine(ARCHS["whisper-small"].reduced())
    cfg = ARCHS["qwen3-4b"].reduced()
    eng = ServingEngine(cfg, sched=_sched(n_slots=1))
    with pytest.raises(ValueError, match="max_len"):
        eng.add_request(list(range(MAX_LEN)), max_new_tokens=8)
    with pytest.raises(ValueError, match="non-empty"):
        eng.add_request([], max_new_tokens=8)


def test_ssm_arch_admits_any_length():
    """Pure-SSM caches are fixed-size state slots: no KV pages, so length
    is not capacity-bounded (the recurrent state carries the context)."""
    cfg = ARCHS["xlstm-350m"].reduced()
    mgr = CacheManager(cfg, n_slots=2, max_len=32, page_size=16)
    assert not mgr.has_kv and mgr.has_state
    assert mgr.pages_for(10_000) == 1            # one state page
    eng = ServingEngine(cfg, sched=SchedulerConfig(
        n_slots=1, max_len=32, prefill_chunk=16, page_size=16))
    eng.add_request(list(np.arange(120) % cfg.vocab_size),
                    max_new_tokens=3)
    (out,) = eng.run()
    assert len(out.tokens) == 3


# ---------------------------------------------------------------------------
# CacheManager page accounting
# ---------------------------------------------------------------------------
def test_cache_manager_page_accounting():
    cfg = ARCHS["qwen3-4b"].reduced()
    mgr = CacheManager(cfg, n_slots=2, max_len=64, page_size=16)
    assert mgr.total_pages == 2 * 4
    assert mgr.pages_for(1) == 1 and mgr.pages_for(16) == 1
    assert mgr.pages_for(17) == 2
    s0 = mgr.admit(40)                           # 3 pages
    assert mgr.free_pages == 8 - 3
    s1 = mgr.admit(64)                           # 4 pages
    assert mgr.free_pages == 1
    assert not mgr.can_admit(32)                 # no free slot
    mgr.free(s0)
    assert mgr.free_pages == 4
    assert mgr.can_admit(64) and mgr.can_admit(80)   # capped at ring size
    mgr.free(s1)
    assert mgr.free_pages == mgr.total_pages
    mgr.admit(40), mgr.admit(40)                 # both slots taken again
    with pytest.raises(RuntimeError):
        mgr.admit(40)


def test_scheduler_blocks_on_pages_not_just_slots():
    """FIFO head that doesn't fit in the page pool waits even when a slot
    is free; it is admitted once pages are released."""
    cfg = ARCHS["qwen3-4b"].reduced()
    mgr = CacheManager(cfg, n_slots=3, max_len=64, page_size=16,
                       total_pages=6)
    sched = Scheduler(SchedulerConfig(3, 64, 8, 16), mgr)
    a = Request(0, [1] * 10, 54)                 # 64 tokens -> 4 pages
    b = Request(1, [1] * 10, 54)
    sched.submit(a), sched.submit(b)
    assert [r.rid for r in sched.admit_ready()] == [0]
    assert b.state == "queued"                   # 2 pages left < 4
    sched.release(a)
    assert [r.rid for r in sched.admit_ready()] == [1]


def test_request_queue_fifo():
    q = RequestQueue()
    for i in range(3):
        q.add(Request(i, [1], 1))
    assert q.peek().rid == 0 and len(q) == 3
    assert [q.pop().rid for _ in range(3)] == [0, 1, 2] and not q


# ---------------------------------------------------------------------------
# Sampling
# ---------------------------------------------------------------------------
def test_sampling_greedy_and_topk1():
    logits = jnp.asarray(np.random.RandomState(0).randn(4, 50), jnp.float32)
    greedy = np.argmax(np.asarray(logits), -1)
    z = jnp.zeros((4,), jnp.int32)
    out = sample_tokens(logits, jnp.zeros((4,)), z, z, z)
    np.testing.assert_array_equal(np.asarray(out), greedy)
    # top_k=1 at any temperature is greedy
    out = sample_tokens(logits, jnp.full((4,), 2.0),
                        jnp.full((4,), 1, jnp.int32), z, z)
    np.testing.assert_array_equal(np.asarray(out), greedy)


def test_sampling_topk_respected_and_seeded():
    logits = jnp.asarray(np.random.RandomState(1).randn(1, 100), jnp.float32)
    top5 = set(np.argsort(-np.asarray(logits[0]))[:5].tolist())
    draws = set()
    for c in range(50):
        t = sample_tokens(logits, jnp.asarray([1.5]),
                          jnp.asarray([5], jnp.int32),
                          jnp.asarray([9], jnp.int32),
                          jnp.asarray([c], jnp.int32))
        draws.add(int(t[0]))
    assert draws <= top5 and len(draws) > 1
    # same (seed, counter) reproduces; different seed diverges somewhere
    a = [int(sample_tokens(logits, jnp.asarray([1.5]),
                           jnp.asarray([0], jnp.int32),
                           jnp.asarray([9], jnp.int32),
                           jnp.asarray([c], jnp.int32))[0])
         for c in range(20)]
    b = [int(sample_tokens(logits, jnp.asarray([1.5]),
                           jnp.asarray([0], jnp.int32),
                           jnp.asarray([9], jnp.int32),
                           jnp.asarray([c], jnp.int32))[0])
         for c in range(20)]
    c = [int(sample_tokens(logits, jnp.asarray([1.5]),
                           jnp.asarray([0], jnp.int32),
                           jnp.asarray([123], jnp.int32),
                           jnp.asarray([ci], jnp.int32))[0])
         for ci in range(20)]
    assert a == b and a != c


# ---------------------------------------------------------------------------
# Per-slot positions in the decode step (the batched-decode substrate)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["qwen3-4b", "deepseek-v3-671b"])
def test_vector_cur_pos_matches_scalar(arch):
    from dataclasses import replace
    cfg = ARCHS[arch].reduced()
    if cfg.moe is not None:
        cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=8.0))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    B, L = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0,
                              cfg.vocab_size).astype(jnp.int32)
    c_s = model.init_cache(cfg, B, L + 1, jnp.float32)
    c_v = model.init_cache(cfg, B, L + 1, jnp.float32)
    for t in range(L):
        lo_s, c_s = model.decode_step(params, c_s, toks[:, t:t + 1],
                                      jnp.asarray(t, jnp.int32), cfg)
        lo_v, c_v = model.decode_step(params, c_v, toks[:, t:t + 1],
                                      jnp.full((B,), t, jnp.int32), cfg)
        np.testing.assert_allclose(np.asarray(lo_s), np.asarray(lo_v),
                                   rtol=1e-6, atol=1e-6)


def test_inactive_slot_cache_untouched():
    cfg = ARCHS["qwen3-4b"].reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    B = 3
    cache = model.init_cache(cfg, B, 16, jnp.float32)
    tok = jnp.ones((B, 1), jnp.int32)
    _, c1 = model.decode_step(params, cache, tok,
                              jnp.zeros((B,), jnp.int32), cfg,
                              active=jnp.asarray([True, False, True]))
    for new, old in zip(jax.tree.leaves(c1), jax.tree.leaves(cache)):
        np.testing.assert_array_equal(np.asarray(new[:, 1]),
                                      np.asarray(old[:, 1]))
    # active rows did change
    assert any(not np.array_equal(np.asarray(new[:, 0]), np.asarray(old[:, 0]))
               for new, old in zip(jax.tree.leaves(c1),
                                   jax.tree.leaves(cache)))


def test_encdec_rejects_active_mask():
    """enc-dec kpos is batch-shared: a per-slot active mask cannot be
    honoured consistently and must be rejected, not half-applied."""
    cfg = ARCHS["whisper-small"].reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    cache = model.init_cache(cfg, 2, 16, jnp.float32)
    tok = jnp.ones((2, 1), jnp.int32)
    with pytest.raises(NotImplementedError, match="batch-shared"):
        model.decode_step(params, cache, tok, jnp.asarray(0, jnp.int32),
                          cfg, active=jnp.asarray([True, False]))
