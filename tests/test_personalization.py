"""Classifier-calibration personalization (Sec. IV-D)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.personalization import calibrate_head
from repro.data.synthetic import make_image_dataset
from repro.models.vision import cnn_apply, cnn_init


def test_calibration_improves_skewed_local_accuracy():
    """A client holding only classes {0,1}: calibrating the head on its data
    must raise its local accuracy and touch ONLY the head."""
    x, y, xt, yt = make_image_dataset(1500, 400, 10, image_size=16, seed=0,
                                      noise=0.5)
    params = cnn_init(jax.random.PRNGKey(0), 10, width=8, image_size=16)
    # quick global pretrain (few steps, all classes)
    from repro.core.distillation import cross_entropy

    @jax.jit
    def step(p, xb, yb):
        g = jax.grad(lambda p: cross_entropy(cnn_apply(p, xb), yb))(p)
        return jax.tree.map(lambda a, b: a - 0.05 * b, p, g)
    rng = np.random.RandomState(0)
    for _ in range(150):
        sel = rng.randint(0, len(x), 64)
        params = step(params, jnp.asarray(x[sel]), jnp.asarray(y[sel]))

    mask_tr = (y <= 1)
    mask_te = (yt <= 1)
    xtr, ytr = x[mask_tr], y[mask_tr]
    xte, yte = xt[mask_te], yt[mask_te]
    counts = jnp.zeros(10).at[0].set((ytr == 0).sum()).at[1].set(
        (ytr == 1).sum())

    def local_acc(p):
        logits = cnn_apply(p, jnp.asarray(xte))
        return float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(yte)))

    base = local_acc(params)
    pers = calibrate_head(params, cnn_apply, "head", xtr, ytr, counts,
                          steps=40, batch_size=64, eta=0.05)
    assert local_acc(pers) >= base
    # only the head moved
    for k in params:
        leaves_a = jax.tree.leaves(params[k])
        leaves_b = jax.tree.leaves(pers[k])
        same = all(bool(jnp.all(a == b)) for a, b in zip(leaves_a, leaves_b))
        assert same == (k != "head"), k


def test_calibration_regularizers_run():
    x, y, _, _ = make_image_dataset(200, 10, 10, image_size=16, seed=1)
    params = cnn_init(jax.random.PRNGKey(0), 10, width=8, image_size=16)
    counts = jnp.ones(10) * 20
    for reg in ("none", "prox", "kd"):
        p = calibrate_head(params, cnn_apply, "head", x, y, counts,
                           steps=3, batch_size=32, eta=0.05, reg=reg)
        assert all(bool(jnp.all(jnp.isfinite(l)))
                   for l in jax.tree.leaves(p["head"]))
