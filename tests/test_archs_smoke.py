"""Per-architecture smoke tests: a REDUCED same-family variant (2 layers,
d_model ≤ 512, ≤ 4 experts) runs one forward/train step on CPU; output
shapes asserted, no NaNs.  The FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import FedConfig, RunConfig
from repro.models.registry import get_model
from repro.models.transformer import VIS_EMBED_DIM

ALL_ARCHS = sorted(ARCHS)


def make_batch(cfg, B=2, L=64):
    batch = {"tokens": jnp.arange(B * L).reshape(B, L) % cfg.vocab_size,
             "labels": (jnp.arange(B * L).reshape(B, L) + 1) % cfg.vocab_size}
    batch["tokens"] = batch["tokens"].astype(jnp.int32)
    batch["labels"] = batch["labels"].astype(jnp.int32)
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.ones((B, L, cfg.d_model), jnp.float32) * 0.1
    if cfg.n_patch_tokens > 0:
        batch["patch_embeds"] = jnp.ones((B, cfg.n_patch_tokens,
                                          VIS_EMBED_DIM), jnp.float32) * 0.1
    return batch


@pytest.fixture(scope="module")
def reduced(request):
    return None


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_constraints(arch):
    cfg = ARCHS[arch].reduced()
    assert cfg.n_layers <= 2 or len(cfg.blocks()) <= 4
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = ARCHS[arch].reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    logits, aux = model.forward(params, batch, cfg)
    L_exp = batch["tokens"].shape[1]
    if cfg.n_patch_tokens > 0:
        L_exp += cfg.n_patch_tokens
    assert logits.shape == (2, L_exp, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_fedadc_train_step(arch):
    """One full FedADC round (2 clients × 2 local steps) on the reduced
    config — loss finite, params changed, momentum non-zero."""
    from repro.launch.train import init_state, make_train_step
    cfg = ARCHS[arch].reduced()
    fed = FedConfig(strategy="fedadc", clients_per_round=2, local_steps=2,
                    eta=0.01, beta_global=0.8, beta_local=0.8)
    run = RunConfig(remat="none")
    state = init_state(jax.random.PRNGKey(0), cfg, fed, run)
    step = make_train_step(cfg, fed, run)
    B, L = 2, 32
    CP, CS, H = 1, 2, 2

    def stack(leaf_fn):
        return leaf_fn()
    batch1 = make_batch(cfg, B, L)
    batch = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (CP, CS, H) + x.shape), batch1)
    new_state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    diff = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree.leaves(state["params"]), jax.tree.leaves(new_state["params"])))
    assert diff > 0, "params did not move"
    mnorm = sum(float(jnp.abs(x).sum()) for x in
                jax.tree.leaves(new_state["server"]["m"]))
    assert mnorm > 0, "server momentum not updated"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_step_runs(arch):
    cfg = ARCHS[arch].reduced()
    if cfg.is_encoder_decoder:
        pytest.skip("encdec decode covered in test_encdec_decode")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 64
    cache = model.init_cache(cfg, B, S, jnp.float32)
    tokens = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = model.decode_step(params, cache, tokens,
                                       jnp.zeros((), jnp.int32), cfg)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


DECODE_CONSISTENCY = ["qwen3-4b", "qwen1.5-32b", "mistral-large-123b",
                      "llama4-scout-17b-a16e", "xlstm-350m", "zamba2-1.2b",
                      "deepseek-v3-671b"]


@pytest.mark.parametrize("arch", DECODE_CONSISTENCY)
def test_decode_matches_forward(arch):
    """Incremental decode (KV cache / recurrent state) reproduces the full
    forward pass logits — the strongest cache-correctness check, covering
    ring buffers, MLA absorbed decode, SSD state recurrence and xLSTM.

    MoE archs use a dropless capacity factor here: with finite capacity the
    router drops different tokens at batch-prefill vs single-token decode
    (an inherent, documented train/serve skew of capacity-based MoE —
    DESIGN.md §MoE)."""
    from dataclasses import replace
    cfg = ARCHS[arch].reduced()
    if cfg.moe is not None:
        cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=8.0))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    B, L = 1, 24
    tokens = (jax.random.randint(jax.random.PRNGKey(1), (B, L), 0,
                                 cfg.vocab_size)).astype(jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    full_logits, _ = model.forward(params, batch, cfg)   # (B, L, V)

    cache = model.init_cache(cfg, B, max_len=L, dtype=jnp.float32)
    outs = []
    for t in range(L):
        lt, cache = model.decode_step(params, cache, tokens[:, t:t + 1],
                                      jnp.asarray(t, jnp.int32), cfg)
        outs.append(lt)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits, np.float32), atol=2e-2, rtol=2e-2)


def test_encdec_decode():
    from repro.models import encdec
    cfg = ARCHS["whisper-small"].reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    B, L, F = 1, 12, 16
    frames = jax.random.normal(jax.random.PRNGKey(2), (B, F, cfg.d_model))
    tokens = (jax.random.randint(jax.random.PRNGKey(3), (B, L), 0,
                                 cfg.vocab_size)).astype(jnp.int32)
    batch = {"tokens": tokens, "labels": tokens, "frames": frames}
    full_logits, _ = model.forward(params, batch, cfg)

    enc_out = encdec.encode(params, frames, cfg)
    cache = model.init_cache(cfg, B, max_len=F, dtype=jnp.float32)
    cache = encdec.prefill_cross(params, enc_out, cfg, cache)
    outs = []
    for t in range(L):
        lt, cache = model.decode_step(params, cache, tokens[:, t:t + 1],
                                      jnp.asarray(t, jnp.int32), cfg)
        outs.append(lt)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_sliding_window_decode_matches_forward():
    """Windowed attention with ring-buffer cache == windowed full forward."""
    from dataclasses import replace
    cfg = replace(ARCHS["qwen3-4b"].reduced(), sliding_window=8)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    B, L = 1, 20
    tokens = (jax.random.randint(jax.random.PRNGKey(4), (B, L), 0,
                                 cfg.vocab_size)).astype(jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    full_logits, _ = model.forward(params, batch, cfg)
    cache = model.init_cache(cfg, B, max_len=L, dtype=jnp.float32)
    outs = []
    for t in range(L):
        lt, cache = model.decode_step(params, cache, tokens[:, t:t + 1],
                                      jnp.asarray(t, jnp.int32), cfg)
        outs.append(lt)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               atol=2e-2, rtol=2e-2)
