"""Personalization via classifier calibration (paper Sec. IV-D / Fig. 7):
train FedADC+ globally, then calibrate each client's head locally with the
self-confidence KD regulariser and compare per-client accuracy.

Run:  PYTHONPATH=src python examples/personalization.py
"""
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core.personalization import calibrate_head
from repro.data.partition import class_counts, dirichlet_partition
from repro.data.synthetic import make_image_dataset
from repro.federated.simulator import FederatedSimulator, SimConfig


def main():
    x, y, xt, yt = make_image_dataset(3000, 600, 10, image_size=16,
                                      noise=0.6, seed=0)
    parts = dirichlet_partition(y, 20, alpha=0.1, seed=0)
    fed = FedConfig(strategy="fedadc", local_steps=8, clients_per_round=4,
                    n_clients=20, eta=0.01, beta_global=0.7, beta_local=0.7,
                    distill=True)
    sim = SimConfig(model="cnn", n_classes=10, batch_size=32, rounds=20,
                    eval_every=20, cnn_width=8)
    s = FederatedSimulator(fed, sim, x, y, xt, yt, parts)
    s.run()
    counts = class_counts(y, parts, 10)

    print(f"{'client':>6} {'global':>8} {'personal':>9} {'gain':>7}")
    gains = []
    for ci, p in enumerate(parts[:8]):
        classes = np.unique(y[p])
        mask = np.isin(yt, classes)
        xte, yte = xt[mask], yt[mask]
        if not len(xte):
            continue

        def acc(params):
            logits = s.apply(params, jnp.asarray(xte))
            return float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(yte)))
        g = acc(s.params)
        pp = calibrate_head(s.params, s.apply, "head", x[p], y[p],
                            jnp.asarray(counts[ci]), steps=60, batch_size=32,
                            eta=0.05, reg="kd")
        pa = acc(pp)
        gains.append(pa - g)
        print(f"{ci:>6} {g:>8.3f} {pa:>9.3f} {pa-g:>+7.3f}")
    print(f"\nmean gain: {np.mean(gains):+.3f} "
          f"(paper: +3.3–4.1% on CIFAR-100; calibration is repeatable when "
          f"local statistics change)")


if __name__ == "__main__":
    main()
