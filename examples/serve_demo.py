"""Serve a (reduced) assigned architecture with batched requests: prefill a
batch of prompts, then decode with the single-token serve_step against the
KV/state cache — the same program the decode_32k / long_500k dry-runs lower
for the production mesh.

``--engine`` instead routes the requests through the continuous-batching
``ServingEngine`` (chunked prefill interleaved with batched decode,
per-request sampling — DESIGN.md §Serving).

Run:  PYTHONPATH=src python examples/serve_demo.py [--arch zamba2-1.2b]
                                                   [--engine]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models.registry import get_model


def run_engine(cfg, args):
    from repro.serving import (SamplingParams, SchedulerConfig, ServingEngine,
                               latency_summary)
    from repro.telemetry import Telemetry
    tel = Telemetry(jsonl=args.telemetry_jsonl, engine="serving") \
        if args.telemetry_jsonl else None
    eng = ServingEngine(cfg, sched=SchedulerConfig(
        n_slots=args.batch, max_len=args.prompt_len + args.gen,
        prefill_chunk=16), telemetry=tel)
    rng = np.random.RandomState(0)
    t0 = time.time()
    for i in range(2 * args.batch):          # oversubscribe the slots
        prompt = rng.randint(0, cfg.vocab_size, args.prompt_len).tolist()
        eng.add_request(prompt, max_new_tokens=args.gen,
                        sampling=SamplingParams(temperature=0.8, top_k=40,
                                                seed=i))
    outs = eng.run()
    dt = time.time() - t0
    toks = sum(len(o.tokens) for o in outs)
    lat = latency_summary(outs)
    print(f"{args.arch}-reduced engine: {len(outs)} requests over "
          f"{args.batch} slots, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, p50 e2e {lat['e2e_s']['p50']:.2f}s, "
          f"p50 TTFT {lat['ttft_s']['p50']:.2f}s); "
          f"sample row: {outs[0].tokens[:16]}")
    if tel is not None:
        tel.close()
        print(f"telemetry events written to {args.telemetry_jsonl}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-1.2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=48)
    ap.add_argument("--engine", action="store_true",
                    help="continuous-batching ServingEngine path")
    ap.add_argument("--telemetry-jsonl", default=None,
                    help="(--engine only) enable serving telemetry and "
                         "write events to this JSONL file")
    args = ap.parse_args()

    if args.telemetry_jsonl and not args.engine:
        ap.error("--telemetry-jsonl needs the --engine path (the batch-"
                 "synchronous demo has no serving telemetry)")
    if args.engine:
        run_engine(get_arch(args.arch).reduced(), args)
        return

    cfg = get_arch(args.arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    B, P, G = args.batch, args.prompt_len, args.gen
    max_len = P + G

    rng = np.random.RandomState(0)
    prompts = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, P)), jnp.int32)

    decode = jax.jit(
        lambda p, c, t, pos: model.decode_step(p, c, t, pos, cfg))
    cache = model.init_cache(cfg, B, max_len, jnp.float32)

    # prefill via incremental decode (state/ring caches make this uniform
    # across attention, MLA, Mamba2 and xLSTM archs)
    t0 = time.time()
    logits = None
    for t in range(P):
        logits, cache = decode(params, cache, prompts[:, t:t + 1],
                               jnp.asarray(t, jnp.int32))
    print(f"{args.arch}-reduced: prefill {P} tokens × {B} seqs "
          f"in {time.time()-t0:.2f}s")

    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    out = [tok]
    t0 = time.time()
    for t in range(P, P + G):
        logits, cache = decode(params, cache, tok, jnp.asarray(t, jnp.int32))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decoded {G} tokens/seq in {dt:.2f}s "
          f"({B*G/dt:.1f} tok/s greedy); sample row: {gen[0, :16].tolist()}")


if __name__ == "__main__":
    main()
