"""Quickstart: FedADC vs FedAvg on a non-iid federation in ~2 minutes (CPU).

Reproduces the paper's core claim in miniature: under skewed client data
(sort-and-partition, s=2), embedding the server momentum into the local
iterations both accelerates training and controls client drift.

Run:  PYTHONPATH=src python examples/quickstart.py [--telemetry-jsonl out.jsonl]

``--telemetry-jsonl`` turns on per-round drift diagnostics (delta
dispersion, momentum alignment, update norm) and streams every telemetry
event to the given JSONL file — the CI telemetry-smoke job validates that
export against the schema.
"""
import argparse

from repro.configs.base import FedConfig
from repro.data.partition import sort_and_partition
from repro.data.synthetic import make_image_dataset
from repro.federated.simulator import FederatedSimulator, SimConfig
from repro.telemetry import Telemetry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--telemetry-jsonl", default=None,
                    help="enable telemetry and write events to this file")
    args = ap.parse_args()
    x, y, xt, yt = make_image_dataset(3000, 600, n_classes=10,
                                      image_size=16, noise=0.6, seed=0)
    parts = sort_and_partition(y, n_clients=20, s=2, seed=0)
    sim = SimConfig(model="cnn", n_classes=10, batch_size=32, rounds=40,
                    eval_every=10, cnn_width=8)
    print(f"{'round':>6} " + "".join(f"{s:>10}" for s in
                                     ("fedavg", "fedadc")))
    histories = {}
    sink = open(args.telemetry_jsonl, "w") if args.telemetry_jsonl else None
    for strat, eta in (("fedavg", 0.05), ("fedadc", 0.01)):
        fed = FedConfig(strategy=strat, local_steps=8, clients_per_round=4,
                        n_clients=20, eta=eta, beta_global=0.7,
                        beta_local=0.7)
        tel = Telemetry(jsonl=sink, engine="sim") if sink else None
        s = FederatedSimulator(fed, sim, x, y, xt, yt, parts, telemetry=tel)
        histories[strat] = s.run()
        if tel is not None:
            tel.emit_summary()
    for i, h in enumerate(histories["fedavg"]):
        row = f"{h['round']:>6} "
        for strat in ("fedavg", "fedadc"):
            row += f"{histories[strat][i]['acc']:>10.3f}"
        print(row)
    final = {s: h[-1]["acc"] for s, h in histories.items()}
    print(f"\nFedADC − FedAvg = {final['fedadc'] - final['fedavg']:+.3f} "
          f"(paper: FedADC > FedAvg, gap grows with skew)")
    if sink is not None:
        sink.close()
        print(f"telemetry events written to {args.telemetry_jsonl}")


if __name__ == "__main__":
    main()
