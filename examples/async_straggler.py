"""Semi-async FedADC under a straggler fleet, in ~2 minutes (CPU).

A quarter of the clients run 4× slower than the rest.  The synchronous
engine (buffer_k = clients_per_round) barriers on the slowest client of every
round; the semi-async engine applies the server update as soon as the
fastest half of the wave arrives, discounting the momentum contribution of
any stale delta that trickles in later.  Both run a top-k 10% + error-
feedback uplink and a **unicast delta downlink**: every dispatched client
is served individually against *its* last-seen server version — a chained
Δθ catch-up when it is ≤ ``resync_horizon`` versions stale, a full-θ
resync beyond that — so the down-MB column is the measured per-client
unicast bytes, and the per-client table below shows who paid for
catch-ups vs resyncs.  Accuracy is plotted against the *virtual clock*
(one unit = one local step on the reference client), so the comparison is
wall-clock-fair.

Run:  PYTHONPATH=src python examples/async_straggler.py \
          [--telemetry-jsonl out.jsonl]

``--telemetry-jsonl`` streams every telemetry event — including the
``downlink.catchups`` / ``downlink.resyncs`` counters and the per-client
``downlink.client_kb`` histogram — to the given JSONL file; the CI
telemetry-smoke job validates that export against the schema.
"""
import argparse

from repro.configs.base import FedConfig, HeteroConfig
from repro.data.partition import sort_and_partition
from repro.data.synthetic import make_image_dataset
from repro.federated.async_engine import AsyncFederatedSimulator
from repro.federated.simulator import SimConfig
from repro.telemetry import Telemetry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--telemetry-jsonl", default=None,
                    help="enable telemetry and write events to this file")
    args = ap.parse_args()
    x, y, xt, yt = make_image_dataset(3000, 600, n_classes=10,
                                      image_size=16, noise=0.6, seed=0)
    parts = sort_and_partition(y, n_clients=20, s=2, seed=0)
    hetero = HeteroConfig(enabled=True, speed_dist="bimodal",
                          straggler_frac=0.25, straggler_slowdown=4.0,
                          seed=0)
    print(f"{'mode':>6} {'rounds':>7} {'virtual time':>13} {'final acc':>10}"
          f" {'up MB':>7} {'down MB':>8} {'catchup':>8} {'resync':>7}")
    results, engines = {}, {}
    sink = open(args.telemetry_jsonl, "w") if args.telemetry_jsonl else None
    for mode, buffer_k, rounds in (("sync", 0, 20), ("semi", 4, 60)):
        fed = FedConfig(strategy="fedadc", local_steps=8,
                        clients_per_round=8, n_clients=20, eta=0.02,
                        beta_global=0.7, beta_local=0.7, buffer_k=buffer_k,
                        staleness_mode="poly", staleness_factor=0.5,
                        compressor="topk", topk_frac=0.1,
                        error_feedback=True,
                        downlink_compressor="delta",
                        downlink_unicast=True, resync_horizon=2)
        sim = SimConfig(model="cnn", n_classes=10, batch_size=32,
                        rounds=rounds, eval_every=5, cnn_width=8, seed=0)
        tel = Telemetry(jsonl=sink, engine=f"async-{mode}") if sink else None
        eng = AsyncFederatedSimulator(fed, sim, hetero, x, y, xt, yt, parts,
                                      telemetry=tel)
        hist = eng.run()
        results[mode], engines[mode] = hist, eng
        if tel is not None:
            tel.emit_summary()
        # measured wire bytes from the round protocol's transport — the
        # uplink rides the top-k+EF codec; the downlink is per-client
        # unicast: each dispatch pays a chained-delta catch-up or (past
        # the horizon) a full-θ resync, classified by the ReferenceStore
        print(f"{mode:>6} {hist[-1]['round']:>7} {hist[-1]['t']:>13.0f} "
              f"{hist[-1]['acc']:>10.3f} {eng.uplink_bytes/2**20:>7.1f} "
              f"{eng.downlink_bytes/2**20:>8.1f} {int(eng.refs.catchups):>8} "
              f"{int(eng.refs.resyncs):>7}")
    print("\nper-client unicast downlink (semi-async run): stragglers fall "
          "past the\nhorizon and pay full-θ resyncs; fast clients ride "
          "cheap chained deltas")
    refs = engines["semi"].refs
    print(f"{'client':>7} {'catchups':>9} {'resyncs':>8} {'down MB':>8}")
    for c in sorted(refs.client_bytes):
        print(f"{c:>7} {refs.client_catchups.get(c, 0):>9} "
              f"{refs.client_resyncs.get(c, 0):>8} "
              f"{refs.client_bytes[c]/2**20:>8.1f}")
    print("\naccuracy vs virtual time (semi-async reaches any level sooner):")
    print(f"{'sync t':>8} {'acc':>8}    | {'semi t':>8} {'acc':>8}")
    from itertools import zip_longest
    for hs, ha in zip_longest(results["sync"], results["semi"]):
        left = f"{hs['t']:>8.0f} {hs['acc']:>8.3f}" if hs else " " * 17
        right = f"{ha['t']:>8.0f} {ha['acc']:>8.3f}" if ha else ""
        print(f"{left}    | {right}")
    if sink is not None:
        sink.close()
        print(f"telemetry events written to {args.telemetry_jsonl}")


if __name__ == "__main__":
    main()
