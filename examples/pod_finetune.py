"""End-to-end driver: federated fine-tuning of a (reduced) assigned
architecture with the pod engine — a few hundred FedADC rounds of a ~100M
LM on synthetic domain-skewed token data, with checkpointing.

This is the same `make_train_step` program the multi-pod dry-run lowers for
the 256/512-chip meshes; here it runs on the host mesh end-to-end.

Run:  PYTHONPATH=src python examples/pod_finetune.py [--arch qwen3-4b]
      [--rounds 200]
"""
import argparse
import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpointing import save_checkpoint
from repro.configs import get_arch
from repro.configs.base import FedConfig, RunConfig
from repro.data.synthetic import make_token_dataset
from repro.launch.train import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--rounds", type=int, default=150)
    ap.add_argument("--ckpt-dir", default="/tmp/fedadc_ckpt")
    ap.add_argument("--full", action="store_true",
                    help="~100M-param variant (slow on CPU; the dry-run "
                         "exercises the full-size configs)")
    args = ap.parse_args()

    base = get_arch(args.arch).reduced()
    if args.full:   # ~100M params
        mcfg = replace(base, n_layers=4, d_model=512, d_ff=1408,
                       vocab_size=2048, n_heads=8, n_kv_heads=4, head_dim=64)
    else:           # CPU-friendly demo (~8M params)
        mcfg = replace(base, n_layers=2, d_model=256, d_ff=704,
                       vocab_size=1024, n_heads=4, n_kv_heads=2, head_dim=64)
    fed = FedConfig(strategy="fedadc", variant="nesterov", local_steps=4,
                    clients_per_round=4, eta=0.02, beta_global=0.7,
                    beta_local=0.7)
    run = RunConfig(remat="none")

    seq, n_docs = 128 if args.full else 64, 512
    tokens, domains = make_token_dataset(n_docs, seq + 1, mcfg.vocab_size,
                                         seed=0)
    # non-iid: each client holds one domain's documents
    clients = [np.where(domains == d % 10)[0] for d in range(8)]

    state = init_state(jax.random.PRNGKey(0), mcfg, fed, run)
    step = jax.jit(make_train_step(mcfg, fed, run))
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"{args.arch}-reduced: {n_params/1e6:.1f}M params, "
          f"{fed.clients_per_round} clients × H={fed.local_steps}")

    rng = np.random.RandomState(0)
    b = 4 if args.full else 2
    t0 = time.time()
    for r in range(args.rounds):
        picks = rng.choice(len(clients), fed.clients_per_round, replace=False)
        batch_tok = np.zeros((1, fed.clients_per_round, fed.local_steps, b,
                              seq + 1), np.int32)
        for ci, c in enumerate(picks):
            sel = rng.choice(clients[c], (fed.local_steps, b))
            batch_tok[0, ci] = tokens[sel]
        batch = {"tokens": jnp.asarray(batch_tok[..., :-1]),
                 "labels": jnp.asarray(batch_tok[..., 1:])}
        state, metrics = step(state, batch)
        if (r + 1) % 25 == 0:
            print(f"round {r+1:4d}  loss {float(metrics['loss']):.4f}  "
                  f"({(time.time()-t0)/(r+1):.2f}s/round)")
    path = save_checkpoint(args.ckpt_dir, args.rounds, state["params"])
    print(f"saved {path}")


if __name__ == "__main__":
    main()
