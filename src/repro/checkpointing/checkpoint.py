"""Flat-npz checkpointing for arbitrary parameter/state pytrees.

Leaves are addressed by their joined tree path so restore round-trips exact
structure without pickling.  Writes are atomic (tmp + rename) so a killed
training run never leaves a torn checkpoint.
"""
from __future__ import annotations

import os
import re
import tempfile
from typing import Any, Dict

import jax
import numpy as np

_SEP = "|"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = np.asarray(leaf)
        view = {1: np.uint8, 2: np.uint16, 4: np.uint32,
                8: np.uint64}.get(arr.dtype.itemsize)
        if arr.dtype.isbuiltin != 1 and view is not None:
            # ml_dtypes (bf16, fp8, ...) don't round-trip through npz —
            # some versions expose them as kind "V", newer ones as kind
            # "f", and either way np.load chokes on the descriptor.  Store
            # the raw bits; restore views them back as the target dtype.
            arr = arr.view(view)
        flat[key] = arr
    return flat


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".npz")
    os.close(fd)
    try:
        np.savez(tmp, **_flatten(tree))
    except BaseException:
        # a crashed save must not strand a partial tmp file next to the
        # real checkpoints
        if os.path.exists(tmp):
            os.remove(tmp)
        raise
    os.replace(tmp, path)
    return path


def latest_step(directory: str):
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like: Any) -> Any:
    """Restore into the structure of `like` (shapes validated)."""
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    flat_like = _flatten(like)
    missing = set(flat_like) - set(data.files)
    extra = set(data.files) - set(flat_like)
    if missing or extra:
        raise ValueError(f"checkpoint mismatch: missing={sorted(missing)[:3]} "
                         f"extra={sorted(extra)[:3]}")
    leaves_with_path = jax.tree_util.tree_flatten_with_path(like)
    restored = []
    for path_k, leaf in leaves_with_path[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path_k)
        arr = data[key]
        like_dtype = np.asarray(leaf).dtype
        if arr.dtype != like_dtype and arr.dtype.kind in ("u", "V") \
                and arr.dtype.itemsize == like_dtype.itemsize:
            arr = arr.view(like_dtype)      # raw-bit ml_dtypes round-trip
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        restored.append(arr)
    return jax.tree_util.tree_unflatten(leaves_with_path[1], restored)
