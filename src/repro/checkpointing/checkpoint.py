"""Flat-npz checkpointing for arbitrary parameter/state pytrees.

Leaves are addressed by their joined tree path so restore round-trips exact
structure without pickling.  Writes are atomic (tmp + rename) so a killed
training run never leaves a torn checkpoint.
"""
from __future__ import annotations

import os
import re
import tempfile
from typing import Any, Dict

import jax
import numpy as np

_SEP = "|"

# ml_dtypes (bf16, fp8, ...) don't round-trip through npz or frombuffer —
# some versions expose them as kind "V", newer ones as kind "f", and either
# way np.load chokes on the descriptor.  Storage keeps the raw bits as the
# same-width uint; readers view them back as the target dtype.  Shared with
# the paged client store's spill tier (repro.federated.fleet.paged_store).
_STORAGE_UINT = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def storage_dtype(dtype) -> np.dtype:
    """The raw-bit dtype an array of `dtype` is serialised as."""
    dtype = np.dtype(dtype)
    view = _STORAGE_UINT.get(dtype.itemsize)
    if dtype.isbuiltin != 1 and view is not None:
        return np.dtype(view)
    return dtype


def storage_view(arr: np.ndarray) -> np.ndarray:
    """Bit-view a host array into its serialisable storage dtype (no copy)."""
    view = storage_dtype(arr.dtype)
    return arr.view(view) if view != arr.dtype else arr


def from_storage_view(arr: np.ndarray, dtype) -> np.ndarray:
    """Invert ``storage_view``: raw uint bits back to the target dtype."""
    dtype = np.dtype(dtype)
    if arr.dtype != dtype and arr.dtype.kind in ("u", "V") \
            and arr.dtype.itemsize == dtype.itemsize:
        return arr.view(dtype)
    return arr


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = storage_view(np.asarray(leaf))
    return flat


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".npz")
    os.close(fd)
    try:
        np.savez(tmp, **_flatten(tree))
    except BaseException:
        # a crashed save must not strand a partial tmp file next to the
        # real checkpoints
        if os.path.exists(tmp):
            os.remove(tmp)
        raise
    os.replace(tmp, path)
    return path


def latest_step(directory: str):
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like: Any) -> Any:
    """Restore into the structure of `like` (shapes validated)."""
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    flat_like = _flatten(like)
    missing = set(flat_like) - set(data.files)
    extra = set(data.files) - set(flat_like)
    if missing or extra:
        raise ValueError(f"checkpoint mismatch: missing={sorted(missing)[:3]} "
                         f"extra={sorted(extra)[:3]}")
    leaves_with_path = jax.tree_util.tree_flatten_with_path(like)
    restored = []
    for path_k, leaf in leaves_with_path[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path_k)
        # raw-bit ml_dtypes round-trip
        arr = from_storage_view(data[key], np.asarray(leaf).dtype)
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        restored.append(arr)
    return jax.tree_util.tree_unflatten(leaves_with_path[1], restored)
