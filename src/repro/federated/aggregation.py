"""Pluggable server aggregators (DESIGN.md §Heterogeneity).

Every strategy's server step consumes Δ̄ = Σ_i w_i·Δ_i / Σ_i w_i over the
round's client deltas.  The weight families:

* ``uniform``  — the paper's 1/|S| mean (FedAvg/FedADC default).
* ``examples`` — w_i ∝ n_i local examples (the FedAvg paper's weighting).
* ``drag``     — DRAG-style divergence-adaptive weights: clients whose delta
  direction diverges from a reference direction (the server momentum when the
  strategy keeps one, else the round mean) are exponentially down-weighted,
  w_i = exp(−λ·(1 − cos(Δ_i, ref))).  Cosine divergence is scale-invariant,
  so the same formula serves both the η-scaled deltas of the simulator and
  the streaming per-client weights of the pod engine.

``weighted_mean`` is the one reduction everything funnels through; with
``use_pallas`` it lowers to the fused weighted-delta-reduce kernel
(kernels/weighted_reduce.py) — one VMEM pass over the stacked deltas.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import tree as T
from repro.federated.compression import is_sparse_leaf, is_sparse_tree

_EPS = 1e-12


def _leading_dim(deltas) -> int:
    return jax.tree.leaves(deltas)[0].shape[0]


def cosine_divergence(delta, ref):
    """1 − cos(Δ, ref) over pytrees; 1.0 (neutral) when ref is ~zero."""
    num = T.dot(delta, ref)
    den = jnp.sqrt(T.sq_norm(delta) * T.sq_norm(ref) + _EPS)
    return 1.0 - num / jnp.maximum(den, _EPS)


# ---------------------------------------------------------------------------
# sparse-wire primitives: norms / dots / means at K·k cost, never
# materialising a per-client dense tree (DESIGN.md §Sparse aggregation)
# ---------------------------------------------------------------------------
def sparse_sq_norms(wire):
    """‖Δ_i‖² from the SparseLeaf wire alone: Σ v² in fp32.  (K,) for a
    client-stacked wire, scalar for a single client's.  Assumes per-client
    indices are unique within a leaf — top-k wires are by construction (the
    aggregate kernel itself tolerates duplicates, but a duplicated index
    denses to v₁+v₂ whose square is not v₁²+v₂²)."""
    parts = jax.tree.leaves(jax.tree.map(
        lambda w: jnp.sum(jnp.square(w.values.astype(jnp.float32)), axis=-1),
        wire, is_leaf=is_sparse_leaf))
    return sum(parts)


def sparse_dot_dense(wire, dense):
    """⟨Δ_i, ref⟩ against a dense pytree at k-cost: gather ref at the wire
    indices.  (K,) for a stacked wire, scalar for a single client's."""
    def leaf(w, d):
        flat = d.reshape(-1).astype(jnp.float32)
        return jnp.sum(w.values.astype(jnp.float32) * flat[w.indices],
                       axis=-1)
    return sum(jax.tree.leaves(
        jax.tree.map(leaf, wire, dense, is_leaf=is_sparse_leaf)))


def sparse_cosine_divergence(wire, ref):
    """1 − cos(Δ, ref) with Δ read straight off the sparse wire."""
    num = sparse_dot_dense(wire, ref)
    den = jnp.sqrt(sparse_sq_norms(wire)
                   * T.sq_norm(ref).astype(jnp.float32) + _EPS)
    return 1.0 - num / jnp.maximum(den, _EPS)


def sparse_weighted_mean(wire, weights, like, use_pallas: bool = False):
    """Σ_i w_i·Δ_i / Σ_i w_i where the stacked deltas are SparseLeaf wires
    (leading axis K on values/indices): a weighted segment-sum builds each
    dense output leaf directly at K·k cost.  `like` supplies the dense leaf
    shapes/dtypes (params or any same-shaped template).  fp32 accumulation,
    cast to the leaf dtype on write — the same precision contract as
    `weighted_mean`, parity-pinned in tests/test_kernels.py."""
    wn = weights.astype(jnp.float32) / jnp.maximum(jnp.sum(weights), _EPS)
    if use_pallas:
        from repro.kernels import ops
        fn = ops.sparse_weighted_delta_reduce
    else:
        from repro.kernels import ref as kref
        fn = kref.sparse_weighted_delta_reduce
    return jax.tree.map(
        lambda w, l: fn(w.values, w.indices, wn, l.shape, l.dtype),
        wire, like, is_leaf=is_sparse_leaf)


KNOWN_AGGREGATORS = ("uniform", "examples", "drag")


def reference_direction(server_state):
    """The DRAG reference direction: the server momentum when the strategy
    keeps one (``None`` otherwise — ``drag_weights`` then falls back to the
    round mean).  Shared by every RoundProtocol backend so the three engines
    resolve the reference identically."""
    return server_state.get("m") if server_state is not None else None


def streaming_weight(delta, ref, name: str, lam: float):
    """Per-client scalar weight, computable without the other deltas
    (pod-engine streaming form).  `name` is static.

    `examples` is uniform here by construction: every pod-engine client
    contributes the same (H, b, L) token budget.  `drag` requires a momentum
    reference — the caller must reject momentum-less strategies up front
    (there is no round mean to fall back on in streaming form)."""
    if name not in KNOWN_AGGREGATORS:
        raise ValueError(f"unknown aggregator {name!r}; "
                         f"known: {', '.join(KNOWN_AGGREGATORS)}")
    if name == "drag":
        if ref is None:
            raise ValueError("streaming drag weights need a momentum "
                             "reference direction")
        if is_sparse_tree(delta):
            return jnp.exp(-lam * sparse_cosine_divergence(delta, ref))
        return jnp.exp(-lam * cosine_divergence(delta, ref))
    return jnp.ones(())


def drag_weights(deltas, ref=None, lam: float = 4.0):
    """Divergence-adaptive weights over stacked deltas (leading axis K)."""
    if ref is None:
        ref = jax.tree.map(lambda d: jnp.mean(d, 0), deltas)
    div = jax.vmap(lambda d: cosine_divergence(d, ref))(deltas)
    return jnp.exp(-lam * div)


def sparse_drag_weights(deltas, like, ref=None, lam: float = 4.0,
                        use_pallas: bool = False):
    """DRAG weights read straight off a stacked SparseLeaf wire.  The
    ref=None fallback mirrors `drag_weights`: the round mean, built once
    by the sparse aggregate (uniform weights) instead of densifying K
    clients.  The per-client divergences are k-cost gathers against it."""
    if ref is None:
        K = _leading_dim(deltas)
        ref = sparse_weighted_mean(deltas, jnp.ones((K,), jnp.float32),
                                   like, use_pallas=use_pallas)
    return jnp.exp(-lam * sparse_cosine_divergence(deltas, ref))


def compute_weights(name: str, deltas, n_examples=None, ref=None,
                    lam: float = 4.0, like=None, use_pallas: bool = False):
    """Unnormalised aggregation weights (K,) for stacked deltas — dense or
    SparseLeaf wires (`like` supplies the dense template the sparse drag
    fallback aggregates into; unused otherwise)."""
    K = _leading_dim(deltas)
    if name == "uniform":
        return jnp.ones((K,), jnp.float32)
    if name == "examples":
        if n_examples is None:
            raise ValueError("aggregator='examples' needs per-client counts")
        return jnp.asarray(n_examples, jnp.float32)
    if name == "drag":
        if is_sparse_tree(deltas):
            if like is None:
                raise ValueError("sparse drag weights need a dense template "
                                 "(like=) for the round-mean fallback")
            return sparse_drag_weights(deltas, like, ref=ref, lam=lam,
                                       use_pallas=use_pallas)
        return drag_weights(deltas, ref=ref, lam=lam)
    raise ValueError(f"unknown aggregator {name!r}; "
                     f"known: {', '.join(KNOWN_AGGREGATORS)}")


def weighted_mean(deltas, weights, use_pallas: bool = False):
    """Σ_i w_i·Δ_i / Σ_i w_i over a stacked pytree (leading axis K).

    The reduction accumulates in fp32 whatever the delta dtype and casts on
    write: summing bf16 deltas in bf16 loses the aggregate to rounding as K
    grows (once the partial sum's ulp outgrows the per-client increments,
    late clients round away entirely) — fp32↔ref↔fp64 parity is pinned at
    bf16, K ≥ 64, in tests/test_kernels.py."""
    wn = weights.astype(jnp.float32) / jnp.maximum(jnp.sum(weights), _EPS)
    if use_pallas:
        from repro.kernels import ops
        return ops.weighted_delta_reduce(deltas, wn)

    def leaf(d):
        # at least fp32, but never downcast (float64 deltas reduce in f64)
        acc_t = jnp.promote_types(d.dtype, jnp.float32)
        return jnp.tensordot(wn.astype(acc_t), d.astype(acc_t),
                             axes=([0], [0])).astype(d.dtype)
    return jax.tree.map(leaf, deltas)
