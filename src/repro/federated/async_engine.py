"""Virtual-clock, event-driven semi-asynchronous federated engine.

The synchronous engines barrier every round on the slowest selected client;
under realistic speed heterogeneity (see ``repro.federated.hetero``) that
straggler bound dominates wall-clock.  This engine removes the barrier:

* a fleet of ``clients_per_round`` clients is kept in flight; each client
  trains on the parameter version it was dispatched with and finishes after
  ``H_i / speed_i`` units of *virtual time* (one unit = one local step on the
  reference client);
* finished deltas enter a server buffer; when the buffer holds
  ``fed.buffer_k`` deltas (buffered-K aggregation; ``buffer_k == 0`` means
  ``clients_per_round``, i.e. the synchronous barrier) the server applies one
  update and immediately re-dispatches the freed slots with fresh parameters;
* a delta dispatched at parameter version v and aggregated at version v+s is
  *s versions stale*; its contribution to the FedADC momentum recursion
  m ← (β_g−β_l)·m + Δ̄/η is damped by ``staleness_discount(s)`` so stale
  pseudo-gradients cannot destabilise the acceleration;
* per-client variable local work H_i is FedNova-normalised (Δ·H_ref/H_i)
  before aggregation, and the pluggable aggregator weights (uniform /
  examples / DRAG) apply exactly as in the synchronous engines via the
  shared ``strategy.server_aggregate`` hook.

With heterogeneity disabled the engine degenerates *exactly* to the
synchronous simulator: equal speeds make every wave arrive together, the
buffer flushes with staleness 0 and discount 1, and the same client-update /
aggregation / server-update code paths (inherited from
``FederatedSimulator``) reproduce its round trajectory to numerical
tolerance (tested).

Scheduling is a deterministic function of (fed, sim, hetero) seeds: client
sampling draws from the simulator RandomState in dispatch order and all
system randomness (availability, drops, jitter) draws from the
ClientSystemModel RandomState in event order.
"""
from __future__ import annotations

import heapq
from collections import deque
from dataclasses import dataclass
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig, HeteroConfig
from repro.core import tree as T
from repro.core.selection import SELECTORS
from repro.federated import aggregation as A
from repro.federated.hetero import ClientSystemModel, staleness_discount
from repro.federated.simulator import FederatedSimulator, SimConfig
from repro.telemetry import drift as drift_metrics

EVENT_LOG_MAXLEN = 65536

# Strategies with per-client cross-round state cannot ride the async engine
# (a stale client would need its state rolled forward); same restriction as
# the pod engine (DESIGN.md §Engines).
ASYNC_UNSUPPORTED = ("scaffold", "feddyn", "moon")


@dataclass
class _InFlight:
    """One dispatched client round, finished at `finish_time`."""
    client: int
    version: int                  # parameter version trained against
    delta: object                 # pytree
    loss: float
    n_examples: float
    delta_scale: float            # FedNova H_ref/H_i normalisation
    finish_time: float


class AsyncFederatedSimulator(FederatedSimulator):
    _engine_name = "async"

    def __init__(self, fed: FedConfig, sim: SimConfig, hetero: HeteroConfig,
                 x_train, y_train, x_test, y_test, parts: List[np.ndarray],
                 telemetry=None, scheduler=None, store=None):
        if fed.strategy in ASYNC_UNSUPPORTED:
            raise ValueError(
                f"async engine supports stateless-client strategies only; "
                f"use the synchronous simulator for {fed.strategy!r}")
        super().__init__(fed, sim, x_train, y_train, x_test, y_test, parts,
                         telemetry=telemetry, scheduler=scheduler,
                         store=store)
        self.hetero = hetero
        self.system = ClientSystemModel(hetero, self.n_clients,
                                        fed.local_steps)
        self._deltas_fn = jax.jit(self._make_deltas_fn())
        self._apply_fn = jax.jit(self._make_apply_fn())
        self.version = 0              # number of server updates applied
        self.vtime = 0.0              # virtual clock
        # (kind, time, client, version) events; bounded so a long-lived
        # engine cannot grow host memory without limit (the staleness_seen
        # class) — 64k events cover ~10k rounds of scheduling history
        self.event_log: Deque[tuple] = deque(maxlen=EVENT_LOG_MAXLEN)
        # bounded staleness summary, reset at each run() — replaces the
        # old unbounded staleness_seen list that double-counted across
        # consecutive run() calls
        self.staleness_hist = self.telemetry.histogram("staleness")
        self._dispatch_ctr = 0        # compression PRNG stream, event order

    # ------------------------------------------------------------------
    def _broadcast(self):
        """The version-v broadcast: one wire per server version, memoised
        in the ``ReferenceStore`` (every dispatch at version v hands out
        the same reconstruction; the lossy delta codec's reference
        advances exactly once per version — stale clients trained against
        the reference version they were dispatched with)."""

        def compute(ref):
            key = jax.random.fold_in(
                # explicit uint32 transfer of the version counter (a bare
                # Python int would be an implicit H2D under transfer guard)
                jax.random.fold_in(self._comp_key,
                                   jnp.asarray(np.asarray(0xB0, np.uint32))),
                jnp.asarray(np.asarray(self.version, np.uint32)))
            with self.telemetry.tracer.span("transport.encode") as sp:
                params_w, ctx, new_ref = self._bcast_fn(
                    self.params, self.server_state, ref, key)
                if self.telemetry.enabled:
                    sp.sync = params_w
            return params_w, ctx, new_ref

        return self.refs.broadcast(self.version, compute)

    def _make_deltas_fn(self):
        """(params_w, ctx, xb, yb, counts, cstates, efs, keys) -> (stacked
        uplink deltas, new EF states, losses) for one dispatch group — the
        same vmapped client_update the synchronous round uses, minus the
        aggregation, plus the uplink wire round trips.  The dispatched
        clients train on the downlink broadcast reconstruction handed in
        from ``_broadcast`` (one per server version); each uplinks against
        its EF memory at dispatch; the server later discounts/aggregates
        the decoded reconstructions."""
        protocol = self.protocol
        client_update = self._make_client_update()
        transported = protocol.transport.up is not None
        sparse_native = protocol.sparse_native

        def deltas_fn(params_w, ctx, xb, yb, counts, cstates, efs, keys):
            deltas, _, losses, _ = jax.vmap(
                lambda x, y, c, cs: client_update(params_w, ctx, x, y, c, cs)
            )(xb, yb, counts, cstates)
            new_efs = efs
            if transported:
                if sparse_native:
                    # the in-flight record holds the SparseLeaf wire, not a
                    # dense reconstruction — K·k floats buffered per client
                    # instead of d, and the flush aggregates it directly
                    deltas, new_efs = jax.vmap(protocol.uplink_encode)(
                        deltas, efs, keys)
                else:
                    deltas, new_efs = jax.vmap(protocol.uplink)(deltas, efs,
                                                                keys)
            return deltas, new_efs, losses

        return deltas_fn

    def _make_apply_fn(self):
        """(params, server_state, stacked deltas, n_examples, scales)
        -> (params', server_state').  `scales` folds the per-delta staleness
        discount and FedNova normalisation into one multiplier."""
        protocol = self.protocol
        sparse_native = protocol.sparse_native
        # static gating, exactly as in the synchronous round function: the
        # disabled apply_fn is bit-identical to the pre-telemetry one
        with_metrics = self.telemetry.enabled
        has_momentum = A.reference_direction(self.server_state) is not None

        def scale_leaf(d, scales):
            return d * scales.reshape((-1,) + (1,) * (d.ndim - 1)
                                      ).astype(d.dtype)

        def apply_fn(params, server_state, deltas, n_examples, scales):
            if sparse_native:
                # only the values carry magnitude — scaling them is exactly
                # scaling the dense reconstruction; indices pass through
                scaled = jax.tree.map(
                    lambda w: w._replace(values=scale_leaf(w.values, scales)),
                    deltas, is_leaf=A.is_sparse_leaf)
            else:
                scaled = jax.tree.map(lambda d: scale_leaf(d, scales), deltas)
            weights = protocol.weights(scaled, n_examples=n_examples,
                                       server_state=server_state, like=params)
            mean_delta = protocol.aggregate(scaled, weights, like=params)
            new_params, new_ss = protocol.server_update(server_state, params,
                                                        mean_delta)
            metrics = {}
            if with_metrics:
                # dispersion over the discounted/normalised deltas — what
                # the server actually averaged this flush
                metrics = drift_metrics.round_metrics(
                    scaled, mean_delta,
                    momentum=(A.reference_direction(server_state)
                              if has_momentum else None))
            return new_params, new_ss, metrics

        return apply_fn

    # ------------------------------------------------------------------
    def _sample_clients(self, n: int) -> np.ndarray:
        if self.scheduler is not None:
            # fleet scheduler: availability/speed-weighted draw (its own
            # RandomState, so the engine's rng stream is untouched); the
            # dispatch wave is region-agnostic — a redispatch of 1 has no
            # meaningful region split
            return self.scheduler.sample(n)
        sel = SELECTORS[self.sim.selector]

        def draw():
            if self.sim.selector == "random":
                return sel(self.rng, self.n_clients, n)
            return sel(self.rng, self.n_clients, n, self.counts)

        picks = draw()
        if self.hetero.enabled and self.hetero.availability < 1.0:
            # best-effort: redraw until the whole wave is reachable
            for _ in range(20):
                if all(self.system.is_available(int(c)) for c in picks):
                    break
                picks = draw()
        return picks

    def _dispatch(self, heap: list, n: int, now: float):
        """Sample n clients, run their local rounds against the *current*
        parameters (the version they would be handed), and schedule their
        arrival events.  Clients with equal H_i are batched into one vmapped
        call — with a homogeneous fleet this is exactly the synchronous
        round's client computation."""
        if n <= 0:
            return
        picks = self._sample_clients(n)
        params_w, ctx = self._broadcast()
        by_h: Dict[int, List[int]] = {}
        for c in picks:
            by_h.setdefault(int(self.system.local_steps[int(c)]), []).append(
                int(c))
        for h, group in by_h.items():
            xs, ys = zip(*[self._client_batches(c, local_steps=h)
                           for c in group])
            xb = jnp.asarray(np.stack(xs))
            yb = jnp.asarray(np.stack(ys))
            counts = jnp.asarray(self.counts[np.asarray(group)])
            cstates = self._get_client_states(group)
            efs = self._get_ef_states(group)
            gkey = jax.random.fold_in(
                self._comp_key,
                jnp.asarray(np.asarray(self._dispatch_ctr, np.uint32)))
            keys = jax.random.split(gkey, len(group))
            self._dispatch_ctr += 1
            with self.telemetry.tracer.span("local_train") as sp:
                deltas, new_efs, losses = self._deltas_fn(
                    params_w, ctx, xb, yb, counts, cstates, efs, keys)
                if self.telemetry.enabled:
                    sp.sync = deltas
            if self.ef_enabled:
                self._put_ef_states(group, new_efs)
            # one explicit host fetch for the group's losses instead of a
            # per-client implicit sync in the loop below (host-sync-in-jit
            # hygiene: deltas stay on device, scalars cross once)
            losses = np.asarray(jax.device_get(losses))
            # every dispatched client receives the version-v broadcast —
            # downlink bytes are paid at dispatch (dropped uploads lose the
            # uplink only).  Multicast: version 0's broadcast is the full
            # initial sync under the delta codec.  Unicast: per-client
            # fresh/catch-up/resync classification against the last version
            # each client actually saw.
            self.refs.dispatch(group, self.version, wire=(params_w, ctx))
            for j, c in enumerate(group):
                rec = _InFlight(
                    client=c, version=self.version,
                    # static slice: x[j] would gather with a device-side
                    # index (an implicit H2D transfer per client)
                    delta=jax.tree.map(
                        lambda x: jax.lax.index_in_dim(x, j, keepdims=False),
                        deltas),
                    loss=float(losses[j]),
                    n_examples=float(len(self.parts[c])),
                    delta_scale=self.system.delta_scale(c),
                    finish_time=now + self.system.round_time(c))
                self._seq += 1
                heapq.heappush(heap, (rec.finish_time, self._seq, rec))
                self.event_log.append(("dispatch", now, c, self.version))

    def _flush(self, buffer: List[_InFlight]):
        """Apply one buffered-K server update from the collected deltas."""
        fed, tel = self.fed, self.telemetry
        stale = np.asarray([self.version - r.version for r in buffer])
        self.staleness_hist.observe_many(int(s) for s in stale)
        disc = staleness_discount(stale, fed.staleness_mode,
                                  fed.staleness_factor)
        # np first, then one explicit device_put each: jnp.asarray(host,
        # dtype) would convert on device (an implicit transfer)
        scales = jnp.asarray(np.asarray(
            disc * np.asarray([r.delta_scale for r in buffer]), np.float32))
        n_ex = jnp.asarray(np.asarray([r.n_examples for r in buffer],
                                      np.float32))
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                               *[r.delta for r in buffer])
        with tel.tracer.span("aggregate") as sp:
            self.params, self.server_state, metrics = self._apply_fn(
                self.params, self.server_state, stacked, n_ex, scales)
            if tel.enabled:
                sp.sync = self.params
        self.version += 1
        loss = float(np.mean([r.loss for r in buffer]))
        if tel.enabled:
            metrics = jax.device_get(metrics)    # one host fetch per flush
            tel.record_round(self.version, {
                **metrics, "loss": loss,
                "staleness_mean": float(stale.mean()),
                "staleness_max": float(stale.max()),
            })
        return loss

    # ------------------------------------------------------------------
    def run(self, rounds: Optional[int] = None, log_fn: Callable = None):
        """Run until `rounds` server updates have been applied.  History
        entries carry the virtual time `t` of each update so wall-clock-to-
        accuracy comparisons against the synchronous engines are direct."""
        # explicit None check: run(rounds=0) is a valid no-op request and
        # must not fall back to sim.rounds (truthiness-on-config)
        rounds = self.sim.rounds if rounds is None else rounds
        fed = self.fed
        # per-run staleness summary: a fresh run() must not double-count
        # the previous run's observations
        self.staleness_hist.reset()
        # buffer_k == 0 is the documented synchronous-barrier sentinel
        K = fed.buffer_k if fed.buffer_k > 0 else fed.clients_per_round
        inflight = max(fed.clients_per_round, K)
        heap: list = []
        buffer: List[_InFlight] = []
        self._seq = 0
        self._dispatch(heap, inflight, self.vtime)
        while self.version < rounds and heap:
            ft, _, rec = heapq.heappop(heap)
            self.vtime = max(self.vtime, ft)
            if self.system.drops_out(rec.client):
                self.event_log.append(("drop", self.vtime, rec.client,
                                       self.version))
                if self.ef_enabled:
                    # the upload is lost: fold the untransported
                    # reconstruction back into the client's EF memory so
                    # mass is conserved (Σ arrived q + e = Σ Δ) even when
                    # the client was re-dispatched meanwhile — addition
                    # commutes with later EF updates
                    lost = rec.delta
                    if self.protocol.sparse_native:
                        # the record holds the sparse wire; the EF store is
                        # dense, so densify this one delta here — bitwise
                        # the reconstruction the server would have decoded
                        lost = self.transport.uplink_decode(lost, self.params)
                    cur = self.ef_states.get(rec.client)
                    self.ef_states[rec.client] = T.add(
                        self._ef_init() if cur is None else cur, lost)
                self._dispatch(heap, 1, self.vtime)
                continue
            self.event_log.append(("arrive", self.vtime, rec.client,
                                   rec.version))
            # a successful upload — dropped clients never transmit
            self.transport.account_uplink(1)
            buffer.append(rec)
            if len(buffer) >= K:
                loss = self._flush(buffer)
                buffer = []
                self.event_log.append(("update", self.vtime, -1,
                                       self.version))
                done = self.version >= rounds
                if not done:
                    self._dispatch(heap, K, self.vtime)
                if self.version % self.sim.eval_every == 0 or done:
                    acc = self.evaluate()
                    self.telemetry.record_eval({"round": self.version,
                                                "t": self.vtime, "acc": acc,
                                                "loss": loss})
                    if log_fn:
                        log_fn(self.history[-1])
        return self.history
