"""Paper-scale federated simulator (host round loop, jit'd client updates).

Reproduces the paper's experimental setup: N clients with non-iid partitions
(sort-and-partition or Dirichlet), cN sampled per round, H local SGD steps,
then the strategy's server update.  Selected clients are vmapped into a
single jit call per round.  The engine drives the unified round protocol
(DESIGN.md §Transport): per-client cross-round state — SCAFFOLD/FedDyn
control variates, MOON previous models, and the uplink error-feedback
residuals — lives in the protocol's ``ClientStore`` (gathered for the
round's picks, updated inside jit, scattered back), and both wire
directions (downlink broadcast, uplink delta) go through the protocol's
``Transport`` with measured-byte accounting.

This engine runs the paper's CNN / ResNet-18 experiments; the pod-scale
engine in ``repro.launch.train`` runs the assigned big architectures.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core import distillation as D
from repro.core import tree as T
from repro.core.selection import SELECTORS
from repro.core.strategies import get_strategy
from repro.data.partition import class_counts
from repro.federated import aggregation as A
from repro.federated.protocol import RoundProtocol
from repro.models.vision import VISION_MODELS
from repro.telemetry import Telemetry
from repro.telemetry import drift as drift_metrics


@dataclass
class SimConfig:
    model: str = "cnn"
    n_classes: int = 10
    batch_size: int = 64
    rounds: int = 100
    eval_every: int = 5
    eval_batch: int = 512
    selector: str = "random"
    moon_mu: float = 1.0
    moon_temp: float = 0.5
    fedrs_alpha: float = 0.5
    fedgkd_lambda: float = 0.1
    fedgkd_tau: float = 0.5
    fedntd_beta: float = 0.3
    fedntd_tau: float = 1.0
    seed: int = 0
    cnn_width: int = 32


class FederatedSimulator:
    _engine_name = "sim"

    def __init__(self, fed: FedConfig, sim: SimConfig,
                 x_train, y_train, x_test, y_test,
                 parts: List[np.ndarray],
                 telemetry: Optional[Telemetry] = None,
                 scheduler=None, store=None):
        self.fed, self.sim = fed, sim
        # optional fleet substrate (repro.federated.fleet): a FleetScheduler
        # replaces the flat SELECTORS pick with region-major cohorts, and a
        # PagedClientStore bounds the per-client state's resident bytes —
        # both are engine arguments, like telemetry, so FedConfig hashes
        # and traces identically with or without them
        self.scheduler = scheduler
        # observability is an engine argument, not a FedConfig field: the
        # same config must hash/trace identically with telemetry on or off
        self.telemetry = telemetry if telemetry is not None \
            else Telemetry.disabled(self._engine_name)
        if not self.telemetry.engine:
            self.telemetry.engine = self._engine_name
        self.x_train, self.y_train = x_train, y_train
        self.x_test, self.y_test = x_test, y_test
        self.parts = parts
        self.n_clients = len(parts)
        self.rng = np.random.RandomState(sim.seed)
        self.counts = class_counts(y_train, parts, sim.n_classes)

        init, apply, features, head_key = VISION_MODELS[sim.model]
        if sim.model == "cnn":
            init = functools.partial(init, n_classes=sim.n_classes,
                                     width=sim.cnn_width,
                                     image_size=x_train.shape[1])
        else:
            init = functools.partial(init, n_classes=sim.n_classes)
        self.apply, self.features = apply, features
        self.params = init(jax.random.PRNGKey(sim.seed))
        self.strategy = get_strategy(fed.strategy)
        # the unified round protocol: transport (both wire directions) +
        # sharded client store + aggregator, with cross-cutting validation
        # (lossy/weighted aggregation × SCAFFOLD/FedDyn rejections)
        self.protocol = RoundProtocol(fed, strategy=self.strategy,
                                      store=store, telemetry=self.telemetry)
        self.transport = self.protocol.transport
        self.server_state = self.strategy.server_init(self.params)
        self.needs_teacher = fed.distill or fed.strategy in ("fedgkd", "fedntd")
        self.stateful = not getattr(self.strategy, "stateless_clients", True) \
            or fed.strategy == "moon"
        self.protocol.register_client_state(self._client_state_init)
        self.ef_enabled = self.protocol.ef_enabled
        self.protocol.register_ef(self._ef_init)
        self._comp_key = jax.random.PRNGKey(sim.seed ^ 0x5F5E1)
        # wire accounting templates: uplink = the delta tree, downlink =
        # (θ_t, client ctx) — ctx shapes via eval_shape, no allocation
        ctx_t = jax.eval_shape(
            lambda ss, p: self.strategy.client_setup(ss, p, fed),
            self.server_state, self.params)
        self.transport.set_wire_templates(self.params, (self.params, ctx_t))
        # the unified downlink reference layer (repro.federated.reference):
        # ONE ReferenceStore owns the delta codec's broadcast reference,
        # the one-wire-per-version memo, and the per-client unicast
        # bookkeeping for every engine.  The round-0 reference is the
        # out-of-band initial sync, so the first wire delta is exactly
        # zero (held only for the lossy delta family — the lossless
        # reconstruction never reads it)
        self.refs = self.protocol.refs
        self.refs.seed(self.protocol.init_downlink_ref(self.server_state,
                                                       self.params))
        self._rounds_done = 0
        self._round_fn = jax.jit(self._make_round_fn())
        # one server broadcast through the downlink codec, jit'd separately
        # from the round body so the ReferenceStore computes each version's
        # wire exactly once (used by the delta family here; the async
        # engine routes every codec through it)
        self._bcast_fn = jax.jit(self._make_bcast_fn())
        self._eval_fn = jax.jit(self._make_eval_fn())

    @property
    def history(self) -> List[Dict]:
        """The eval history — absorbed into the telemetry facade (appended
        there whether or not telemetry is enabled)."""
        return self.telemetry.history

    # --- store/transport views (the pre-protocol public surface) ----------
    @property
    def client_states(self) -> Dict[int, object]:
        return self.protocol.store.states("state")

    @property
    def ef_states(self) -> Dict[int, object]:
        return self.protocol.store.states("ef")

    @property
    def uplink_bytes(self) -> int:
        return self.transport.uplink_bytes

    @property
    def uplink_bytes_raw(self) -> int:
        return self.transport.uplink_bytes_raw

    @property
    def downlink_bytes(self) -> int:
        return self.transport.downlink_bytes

    @property
    def downlink_bytes_raw(self) -> int:
        return self.transport.downlink_bytes_raw

    @property
    def _lossy_uplink(self) -> bool:
        up = self.transport.up
        return up is not None and up.lossy

    # ------------------------------------------------------------------
    def _client_state_init(self):
        s, fed = self.strategy, self.fed
        if fed.strategy == "moon":
            return {"prev": self.params}
        if hasattr(s, "client_state_init"):
            return s.client_state_init(self.params)
        return {"_": jnp.zeros(())}

    def _get_client_states(self, picks):
        return self.protocol.store.gather("state", picks)

    def _put_client_states(self, picks, stacked):
        self.protocol.store.scatter("state", picks, stacked)

    # --- error-feedback namespace (same store, second collection) --------
    def _ef_init(self):
        if self._lossy_uplink:
            return T.zeros_like(self.params)
        return {"_": jnp.zeros(())}    # codec bypassed / lossless passthrough

    def _get_ef_states(self, picks):
        return self.protocol.store.gather("ef", picks)

    def _put_ef_states(self, picks, stacked):
        self.protocol.store.scatter("ef", picks, stacked)

    # ------------------------------------------------------------------
    def _local_loss(self, theta, xb, yb, theta_t, counts, cstate):
        """The strategy-specific local objective (Sec. III / IV-A)."""
        fed, sim = self.fed, self.sim
        name = fed.strategy
        logits = self.apply(theta, xb)
        if fed.distill:   # FedADC+ self-confidence KD (eq. 7-9)
            t_logits = jax.lax.stop_gradient(self.apply(theta_t, xb))
            loss, aux = D.self_confidence_kd_loss(
                logits, t_logits, yb, counts, fed.distill_lambda,
                fed.distill_tau)
            return loss
        if name == "fedgkd":
            t_logits = jax.lax.stop_gradient(self.apply(theta_t, xb))
            return D.fedgkd_loss(logits, t_logits, yb, sim.fedgkd_lambda,
                                 sim.fedgkd_tau)[0]
        if name == "fedntd":
            t_logits = jax.lax.stop_gradient(self.apply(theta_t, xb))
            return D.fedntd_loss(logits, t_logits, yb, sim.fedntd_beta,
                                 sim.fedntd_tau)[0]
        if name == "fedrs":
            present = (counts > 0).astype(jnp.float32)
            return D.cross_entropy(D.fedrs_logits(logits, present,
                                                  sim.fedrs_alpha), yb)
        if name == "moon":
            z = self.features(theta, xb)
            z_g = jax.lax.stop_gradient(self.features(theta_t, xb))
            z_p = jax.lax.stop_gradient(self.features(cstate["prev"], xb))
            return D.cross_entropy(logits, yb) + D.moon_loss(
                z, z_g, z_p, sim.moon_mu, sim.moon_temp)
        return D.cross_entropy(logits, yb)

    # ------------------------------------------------------------------
    def _make_client_update(self):
        """The per-client local-training function, shared with the semi-async
        engine (repro.federated.async_engine) so both produce bit-identical
        deltas from the same inputs."""
        strategy, fed = self.strategy, self.fed

        def client_update(theta_t, ctx, xb, yb, counts, cstate):
            """xb (H,b,...), yb (H,b) -> (delta, new_cstate, loss_mean)."""
            def grad_builder(batch_x, batch_y):
                def loss(theta):
                    return self._local_loss(theta, batch_x, batch_y,
                                            theta_t, counts, cstate)
                return loss

            def step(carry, hb):
                theta, extra = carry
                bx, by = hb

                def grad_fn(th, _):
                    val, g = jax.value_and_grad(grad_builder(bx, by))(th)
                    return g, val
                theta, extra, val = strategy.local_step(
                    theta, ctx, grad_fn, None, fed, extra)
                return (theta, extra), val

            # stateful-client strategies (SCAFFOLD c_i, FedDyn h_i) carry
            # their cross-round state through the local-step `extra` slot
            if hasattr(strategy, "client_state_init"):
                extra0 = cstate
            else:
                extra0 = strategy.init_extra(theta_t, fed)
            (theta_H, _), losses = jax.lax.scan(step, (theta_t, extra0),
                                                (xb, yb))
            delta = T.sub(theta_t, theta_H)
            new_cstate = cstate
            if hasattr(strategy, "client_state_update"):
                new_cstate = strategy.client_state_update(
                    cstate, ctx, theta_t, theta_H, fed)
            elif fed.strategy == "moon":
                new_cstate = {"prev": theta_H}
            return delta, new_cstate, jnp.mean(losses), theta_H

        return client_update

    def _make_bcast_fn(self):
        """(params, server_state, down_ref, key) -> (params_w, ctx_w,
        new_ref): one server broadcast through the downlink codec.  Jit'd
        separately from the round body so a version's broadcast is computed
        once (the ReferenceStore memoises the wire per version) and every
        dispatch at that version receives the same reconstruction.
        Callers pre-fold the per-round key; lossless codecs ignore it."""
        protocol = self.protocol
        down = protocol.transport.down
        lossy_down = down is not None and down.lossy

        def bcast_fn(params, server_state, down_ref, key):
            dkey = key if lossy_down else None
            return protocol.client_ctx(server_state, params, dkey, down_ref)

        return bcast_fn

    def _make_round_fn(self):
        strategy, fed = self.strategy, self.fed
        protocol = self.protocol
        client_update = self._make_client_update()
        transported = protocol.transport.up is not None
        sparse_native = protocol.sparse_native
        down = protocol.transport.down
        lossy_down = down is not None and down.lossy
        # drift diagnostics are gated on STATIC facts only (telemetry flag,
        # momentum-keeping strategy, EF on) — the disabled round function is
        # bit-identical to the pre-telemetry one and neither path retraces
        with_metrics = self.telemetry.enabled
        has_momentum = A.reference_direction(self.server_state) is not None
        ef_metrics = self.ef_enabled

        def round_fn(params, server_state, xb, yb, counts, cstates,
                     n_examples, efs, key, bcast):
            # downlink: clients train on the broadcast wire reconstruction
            # (bit-identical passthrough for none/identity/delta+identity
            # codecs).  `bcast` is the externally computed (params_w, ctx)
            # wire for the reference-coded delta family (ReferenceStore →
            # _bcast_fn, one broadcast per version); stateless codecs
            # compute it inline — a static Python branch, one trace each.
            if bcast is None:
                dkey = jax.random.fold_in(key, 0xD0) if lossy_down else None
                params_w, ctx, _ = protocol.client_ctx(server_state, params,
                                                       dkey, None)
            else:
                params_w, ctx = bcast
            deltas, ncs, losses, theta_Hs = jax.vmap(
                lambda x, y, c, cs: client_update(params_w, ctx, x, y, c, cs)
            )(xb, yb, counts, cstates)
            if transported:
                # uplink: each client ships q(Δ + e); the server aggregates
                # the decoded reconstructions below, so the momentum
                # recursion in server_update composes with the lossy wire
                keys = jax.random.split(key, xb.shape[0])
                if sparse_native:
                    # encode only: the (values, indices) wire flows straight
                    # into the segment-sum aggregate — no per-client dense
                    # reconstruction exists in the round.  encode returns
                    # the same exact-complement EF residual the roundtrip
                    # would (decode never touches it), so the EF contract
                    # is path-independent.
                    deltas, new_efs = jax.vmap(protocol.uplink_encode)(
                        deltas, efs, keys)
                else:
                    deltas, new_efs = jax.vmap(protocol.uplink)(deltas, efs,
                                                                keys)
            else:
                new_efs = efs
            weights = protocol.weights(deltas, n_examples=n_examples,
                                       server_state=server_state, like=params)
            mean_delta = protocol.aggregate(deltas, weights, like=params)
            if fed.strategy == "feddyn":
                mean_theta_H = jax.tree.map(lambda d: jnp.mean(d, 0), theta_Hs)
                sum_drift = jax.tree.map(
                    lambda d: -jnp.sum(d, 0) / self.n_clients, deltas)
                new_params, new_ss = strategy.server_update_feddyn(
                    server_state, params, mean_theta_H, sum_drift, fed)
            elif fed.strategy == "scaffold":
                dcs = jax.tree.map(lambda a, b: a - b, ncs, cstates)
                mean_dc = jax.tree.map(lambda d: jnp.mean(d, 0), dcs)["c_i"]
                part_frac = xb.shape[0] / self.n_clients
                new_params, new_ss = strategy.server_update_scaffold(
                    server_state, params, mean_delta, mean_dc, fed, part_frac)
            else:
                new_params, new_ss = protocol.server_update(
                    server_state, params, mean_delta)
            metrics = {}
            if with_metrics:
                metrics = drift_metrics.round_metrics(
                    deltas, mean_delta,
                    momentum=(A.reference_direction(server_state)
                              if has_momentum else None),
                    efs=new_efs if ef_metrics else None)
            return (new_params, new_ss, ncs, new_efs, jnp.mean(losses),
                    metrics)

        return round_fn

    def _make_eval_fn(self):
        def eval_fn(params, x, y):
            logits = self.apply(params, x)
            return jnp.sum(jnp.argmax(logits, -1) == y)
        return eval_fn

    # ------------------------------------------------------------------
    def _client_batches(self, client: int, local_steps: Optional[int] = None):
        fed, sim = self.fed, self.sim
        h = fed.local_steps if local_steps is None else local_steps
        idx = self.parts[client]
        need = h * sim.batch_size
        reps = max(int(np.ceil(need / len(idx))), 1)
        pool = np.concatenate([self.rng.permutation(idx) for _ in range(reps)])
        sel = pool[:need].reshape(h, sim.batch_size)
        return self.x_train[sel], self.y_train[sel]

    def evaluate(self) -> float:
        n = len(self.x_test)
        b = self.sim.eval_batch
        # device-resident partial sums; one explicit host fetch at the end
        # (host-sync-in-jit hygiene: no per-batch implicit int() syncs)
        parts = [self._eval_fn(self.params,
                               jnp.asarray(self.x_test[i:i + b]),
                               jnp.asarray(self.y_test[i:i + b]))
                 for i in range(0, n, b)]
        correct = int(np.sum(jax.device_get(parts)))
        return correct / n

    def run(self, rounds: Optional[int] = None, log_fn: Callable = None):
        rounds = self.sim.rounds if rounds is None else rounds
        sel = SELECTORS[self.sim.selector]
        tel = self.telemetry
        for t in range(rounds):
            if self.scheduler is not None:
                # region-major cohort: pick k of a scheduler cohort lands
                # in the aggregator region that owns it by construction
                picks = self.scheduler.sample_cohort(
                    self.fed.clients_per_round).clients
            elif self.sim.selector == "random":
                picks = sel(self.rng, self.n_clients, self.fed.clients_per_round)
            else:
                picks = sel(self.rng, self.n_clients,
                            self.fed.clients_per_round, self.counts)
            xs, ys = zip(*[self._client_batches(int(c)) for c in picks])
            xb = jnp.asarray(np.stack(xs))
            yb = jnp.asarray(np.stack(ys))
            counts = jnp.asarray(self.counts[picks])
            cstates = self._get_client_states(picks)
            # np first, then one explicit device_put: jnp.asarray(list,
            # dtype) would convert on device (an implicit transfer)
            n_examples = jnp.asarray(np.asarray(
                [len(self.parts[int(c)]) for c in picks], np.float32))
            efs = self._get_ef_states(picks)
            # explicit uint32 transfer of the round counter — a bare
            # Python int would be an implicit H2D (transfer guard)
            round_key = jax.random.fold_in(
                self._comp_key, jnp.asarray(np.asarray(t, np.uint32)))
            def compute_bcast(ref):
                # the key folds match the fused in-round derivation
                # bitwise (fold_in is deterministic eager or traced)
                return self._bcast_fn(
                    self.params, self.server_state, ref,
                    jax.random.fold_in(
                        round_key, jnp.asarray(np.asarray(0xD0, np.uint32))))
            bcast = None
            if self.transport.stateful_downlink:
                # lossy delta family: the broadcast is computed through the
                # ReferenceStore (one wire per version, the reference
                # advances exactly once) and handed into the round body
                bcast = self.refs.broadcast(self._rounds_done, compute_bcast)
            wire = bcast
            if wire is None and self.refs.unicast:
                # lossless delta stays *inline* in the round body (the
                # fused graph is bit-identical to the identity downlink's,
                # which the materialised jit-boundary broadcast is not) —
                # the unicast layer still materialises the wire once per
                # round so per-client reference pages hold real bytes
                wire = self.refs.broadcast(self._rounds_done, compute_bcast)
            with tel.tracer.span("round") as sp:
                (self.params, self.server_state, ncs, nefs, loss,
                 metrics) = self._round_fn(
                    self.params, self.server_state, xb, yb, counts, cstates,
                    n_examples, efs, round_key, bcast)
                if tel.enabled:
                    # span stops after the round's device work, not after
                    # the async dispatch that launched it
                    sp.sync = (self.params, loss)
            if self.stateful:
                self._put_client_states(picks, ncs)
            if self.ef_enabled:
                self._put_ef_states(picks, nefs)
            # downlink accounting + per-client unicast bookkeeping (the
            # delta codec's first broadcast is the full initial sync)
            self.refs.dispatch(picks, self._rounds_done, wire=wire)
            self._rounds_done += 1
            self.transport.account_uplink(len(picks))
            if tel.enabled:
                # ONE host fetch for the whole diagnostic tree + loss
                metrics, loss_h = jax.device_get((metrics, loss))
                tel.record_round(t, {**metrics, "loss": float(loss_h)})
            if (t + 1) % self.sim.eval_every == 0 or t == rounds - 1:
                acc = self.evaluate()
                # explicit device_get — with telemetry off this is the
                # round's single sanctioned host fetch
                tel.record_eval({"round": t + 1, "acc": acc,
                                 "loss": float(jax.device_get(loss))})
                if log_fn:
                    log_fn(self.history[-1])
        return self.history
