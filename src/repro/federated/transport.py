"""First-class bidirectional wire layer for the federated round
(DESIGN.md §Transport).

The round protocol used to hand-wire compression per engine through the
``strategy.compress_delta`` hook (uplink only, dense reconstruction only,
analytic downlink accounting).  ``Transport`` owns both directions of the
wire instead, and every engine drives it identically:

* **downlink** — ``broadcast(params, ctx, key, ref)``: the server compresses
  the per-round broadcast (θ_t plus the strategy's client context, e.g. the
  FedADC m̄_t) once, and clients train on the wire reconstruction.  The
  plain codecs (``none``/``identity``/``topk``/``qsgd``) are stateless
  server-side; the **delta family** (``delta`` ≡ ``delta+identity``,
  ``delta+topk``, ``delta+qsgd``) is reference-coded: the server keeps the
  last broadcast reconstruction (θ_{t−1}, m̄_{t−1}) — the tree every
  up-to-date client already holds — in its round state and ships only the
  change, optionally composing a lossy codec on the delta (where
  compression actually bites; the reference tracks the *reconstruction*,
  so coding error self-corrects like error feedback instead of
  accumulating).  Momentum-aware: strategies whose ctx is an exact scalar
  image of the θ-delta (FedADC: Δθ_t = −αη·m_t, m̄_t = β_l/H·m_t) declare
  ``ctx_from_broadcast_delta`` and their ctx costs **0 wire bytes** — the
  clients derive m̄_t from the θ wire, recovering the paper's overlapped 1×
  broadcast.  ``none``/``identity``/``delta+identity`` are bit-exact.
* **uplink** — ``uplink(delta, ef, key)``: one client's delta is encoded
  against its error-feedback memory, transported, and decoded; the server
  only ever aggregates wire reconstructions, so the FedADC momentum
  recursion stays consistent with what a bandwidth-constrained deployment
  can compute (DESIGN.md §Compression).
* **accounting** — measured (wire-format) and raw byte counters for BOTH
  directions, unified here instead of per-engine ad-hoc sums.  Wire sizes
  come from the exact formats in ``repro.federated.compression`` and work
  on ``ShapeDtypeStruct`` templates (no allocation).

Codecs wrap the compressors in ``repro.federated.compression``:
``identity`` (lossless), ``topk``/``qsgd`` dense round trips, and — new —
a **true sparse top-k** path (``FedConfig.sparse_uplink``): inside jit the
wire is per-leaf ``(values, indices)`` pairs (``SparseLeaf``); the server
decodes with one scatter per client instead of re-running a dense
threshold pass, so the wire representation the byte accounting always
assumed now exists as an actual program object.  The sparse reconstruction
equals the dense path exactly (oracle-tested).

Engines construct their own ``Transport`` (counters are per-engine); the
deprecated ``strategy.compress_delta`` shim goes through a cached
stateless instance.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import tree as T
from repro.federated import compression as C
# the wire format itself lives with the compressor arithmetic so the
# aggregation layer can consume it codec-free; re-exported here because
# transport is the wire's public face
from repro.federated.compression import SparseLeaf, is_sparse_leaf

_is_sparse = is_sparse_leaf


# ---------------------------------------------------------------------------
# codecs — one direction of the wire each
# ---------------------------------------------------------------------------
class Codec:
    """encode/decode run inside jit (per client, vmap-safe); wire_nbytes is
    host-side accounting of the same format."""
    name = "base"
    lossy = True

    def encode(self, tree, ef, key):
        """(pytree, EF pytree, key) -> (wire, new EF = exact residual)."""
        raise NotImplementedError

    def decode(self, wire, like):
        """Wire -> dense pytree shaped like `like` (the server's view)."""
        raise NotImplementedError

    def roundtrip(self, tree, ef, key):
        """encode ∘ decode fused: -> (dense reconstruction, new EF)."""
        wire, new_ef = self.encode(tree, ef, key)
        return self.decode(wire, tree), new_ef

    def wire_nbytes(self, template) -> int:
        raise NotImplementedError


class IdentityCodec(Codec):
    name = "identity"
    lossy = False

    def encode(self, tree, ef, key):
        # pure passthrough — no arithmetic, so engine trajectories are
        # bit-identical to transport-off runs (tested)
        return tree, ef

    def decode(self, wire, like):
        return wire

    def wire_nbytes(self, template) -> int:
        return C.raw_nbytes(template)


class DenseCodec(Codec):
    """Lossy compressor whose in-program wire is the dense reconstruction
    (the pre-redesign representation: real bytes live only in the
    accounting).  Wraps topk/qsgd from repro.federated.compression."""

    def __init__(self, comp: C.Compressor):
        self._comp = comp
        self.name = comp.name
        self.lossy = comp.lossy

    def encode(self, tree, ef, key):
        return self._comp.compress(tree, ef, key)

    def decode(self, wire, like):
        return wire

    def wire_nbytes(self, template) -> int:
        return self._comp.wire_nbytes(template)


class SparseTopKCodec(Codec):
    """Top-k magnitude sparsification whose in-program wire IS the sparse
    (value, index) format: per leaf, ``lax.top_k`` selects the k = ⌈frac·n⌉
    largest-|v| entries of v = Δ + e, the residual zeroes exactly those
    indices, and the server scatters the pairs back into a dense zero
    tensor.  Reconstruction and residual match the dense threshold path
    exactly away from magnitude ties (on a tie the dense path keeps every
    entry ≥ τ while top-k keeps exactly k)."""
    name = "topk"
    lossy = True

    def __init__(self, frac: float):
        # reuse the dense compressor's validation + wire accounting
        self._acct = C.TopKCompressor(frac)
        self.frac = frac

    def encode(self, tree, ef, key):
        from repro.kernels import ops
        v = T.add(tree, ef)
        # flatten/unflatten rather than an is_leaf-on-tuples tree.map: the
        # input pytree may itself contain tuple internal nodes, which an
        # isinstance(tuple) heuristic would mistake for (wire, ef) pairs
        leaves, treedef = jax.tree.flatten(v)
        wire_leaves, ef_leaves = [], []
        for x in leaves:
            values, indices, residual = ops.topk_sparse_leaf(
                x, self._acct._k(x.size))
            wire_leaves.append(SparseLeaf(values, indices))
            ef_leaves.append(residual)
        return (jax.tree.unflatten(treedef, wire_leaves),
                jax.tree.unflatten(treedef, ef_leaves))

    def decode(self, wire, like):
        from repro.kernels import ops
        return jax.tree.map(
            lambda w, l: ops.sparse_scatter_leaf(w.values, w.indices,
                                                 l.shape, l.dtype),
            wire, like, is_leaf=_is_sparse)

    def wire_nbytes(self, template) -> int:
        return self._acct.wire_nbytes(template)


class DeltaDownlinkCodec(Codec):
    """Reference-coded (momentum-aware) broadcast codec — the first
    *stateful server-side* wire object.

    The server keeps ``ref`` = the previous round's broadcast
    reconstruction (θ_{t−1}, ctx_{t−1}), exactly what every up-to-date
    client holds, and transmits the change:

    * lossless inner codec (``delta`` ≡ ``delta+identity``) — the residual
      is transported exactly (think a bitwise delta of the float encoding),
      so the reconstruction IS the current tree; the program passes (θ_t,
      ctx_t) through untouched and the wire accounting charges the delta
      tree's raw bytes.  Bit-identical to the plain broadcast (tested on
      all three engines).
    * lossy inner codec (``delta+topk`` / ``delta+qsgd``) — the wire is
      q(θ_t − ref_θ); clients accumulate ref_θ + q, and the new reference
      is that reconstruction, so coding error enters once and self-corrects
      across rounds (the broadcast analogue of error feedback).

    Momentum-aware ctx: when the strategy declares
    ``ctx_from_broadcast_delta`` (FedADC family: Δθ_t = −αη·m_t while
    m̄_t = β_l/H·m_t, an exact scalar image), the ctx is never transported —
    clients derive it from the decoded θ-delta — and it costs 0 wire bytes,
    which is what drives FedADC's measured downlink from 2× raw θ to ~1×.
    Otherwise the ctx delta rides the inner codec like the params.

    The codec is **stateless**: it holds no arrays and ``ref`` is threaded
    in functionally.  All reference *state* lives in one place — the
    ``repro.federated.reference.ReferenceStore`` every engine drives (the
    lossy pod configuration additionally carries the tree inside its train
    state so it rides the mesh).  Only the lossy family needs a reference
    at all (``Transport.stateful_downlink``); the lossless configuration
    accepts ``ref=None``.  The round-0 reference is the out-of-band
    initial sync (θ_0, ctx_0) — accounted as one raw broadcast per client
    dispatched at version 0 (``ReferenceStore.dispatch``).
    """
    lossy = True          # overwritten from the inner codec

    def __init__(self, inner: Codec, ctx_derive=None, name: str = "delta"):
        self.inner = inner
        self.ctx_derive = ctx_derive
        self.lossy = inner.lossy
        self.name = name

    def init_ref(self, params, ctx):
        """The reference clients hold before round 0: the initial sync."""
        return (params, ctx)

    def broadcast(self, params, ctx, ref, key):
        """-> (params_w, ctx_w, new_ref); runs inside jit."""
        if not self.lossy:
            # exact residual transport: reconstruction == the current tree
            return params, ctx, (params, ctx)
        ref_p, ref_c = ref
        d_p = T.sub(params, ref_p)
        q_p, _ = self.inner.roundtrip(d_p, T.zeros_like(d_p),
                                      jax.random.fold_in(key, 0))
        params_w = T.add(ref_p, q_p)
        if self.ctx_derive is not None:
            ctx_w = self.ctx_derive(q_p)
        else:
            d_c = T.sub(ctx, ref_c)
            q_c, _ = self.inner.roundtrip(d_c, T.zeros_like(d_c),
                                          jax.random.fold_in(key, 1))
            ctx_w = T.add(ref_c, q_c)
        return params_w, ctx_w, (params_w, ctx_w)

    def wire_nbytes(self, template) -> int:
        """Steady-state per-client bytes: the delta tree through the inner
        codec, with a derivable ctx charged 0 (the scale is config-derived,
        never transmitted).  The round-0 resync is accounted separately."""
        p_t, c_t = template
        nbytes = self.inner.wire_nbytes(p_t)
        if self.ctx_derive is None:
            nbytes += self.inner.wire_nbytes(c_t)
        return nbytes


KNOWN_DOWNLINK = ("none", "identity", "topk", "qsgd", "delta",
                  "delta+identity", "delta+topk", "delta+qsgd")


def make_codec(name: str, fed, direction: str = "uplink") -> Optional[Codec]:
    """Codec for one wire direction (None = bypass, the pre-transport code
    path with zero added arithmetic).  The downlink direction resolves the
    per-direction knobs (``downlink_topk_frac``/``downlink_qsgd_bits``),
    falling back to the uplink values when unset."""
    topk_frac, qsgd_bits = fed.topk_frac, fed.qsgd_bits
    if direction == "downlink":
        if fed.downlink_topk_frac is not None:
            topk_frac = fed.downlink_topk_frac
        if fed.downlink_qsgd_bits is not None:
            qsgd_bits = fed.downlink_qsgd_bits
    if name == "none":
        return None
    if name == "identity":
        return IdentityCodec()
    if name == "topk":
        if direction == "uplink" and fed.sparse_uplink:
            return SparseTopKCodec(topk_frac)
        return DenseCodec(C.TopKCompressor(topk_frac, fed.use_pallas))
    if name == "qsgd":
        return DenseCodec(C.QSGDCompressor(qsgd_bits, fed.use_pallas))
    if name == "delta" or name.startswith("delta+"):
        if direction != "downlink":
            raise ValueError(
                f"{name!r} is a downlink (broadcast) codec: uplink deltas "
                f"already are deltas and ride the EF codecs")
        inner_name = "identity" if name == "delta" else name.partition("+")[2]
        if inner_name not in ("identity", "topk", "qsgd"):
            # rejects "delta+", "delta+none", "delta+delta", typos — the
            # inner codec must be an explicit known transform
            raise ValueError(f"unknown downlink compressor {name!r}; "
                             f"known: {', '.join(KNOWN_DOWNLINK)}")
        inner = make_codec(inner_name, fed, "downlink")
        from repro.core.strategies import get_strategy  # lazy: layering
        strategy = get_strategy(fed.strategy)
        derive = None
        if hasattr(strategy, "ctx_from_broadcast_delta"):
            derive = functools.partial(strategy.ctx_from_broadcast_delta,
                                       fed=fed)
        return DeltaDownlinkCodec(inner, ctx_derive=derive, name=name)
    known = KNOWN_DOWNLINK if direction == "downlink" \
        else C.KNOWN_COMPRESSORS
    raise ValueError(f"unknown {direction} compressor {name!r}; "
                     f"known: {', '.join(known)}")


# ---------------------------------------------------------------------------
# the transport
# ---------------------------------------------------------------------------
class Transport:
    """Bidirectional wire layer: downlink broadcast codec, uplink delta
    codec, and measured-byte accounting for both directions.

    jit-side methods (`broadcast`, `uplink`, `uplink_encode`,
    `uplink_decode`) are pure; the byte counters are host-side and advance
    through `account_uplink` / `account_downlink` once per transported
    client.  Engines own their instance — counters are engine-local."""

    def __init__(self, fed, counters=None):
        if fed.sparse_uplink and fed.compressor not in ("topk", "none"):
            raise ValueError(
                f"sparse_uplink is the (value, index) top-k wire format; "
                f"compressor={fed.compressor!r} has no sparse path")
        self.fed = fed
        self.up = make_codec(fed.compressor, fed, "uplink")
        self.down = make_codec(fed.downlink_compressor, fed, "downlink")
        if fed.downlink_unicast:
            # unicast catch-up ships each client the chained delta against
            # THEIR version; only the lossless delta family reconstructs
            # exact θ_t for every staleness level, so the in-jit program
            # stays a single broadcast tree (a lossy per-client
            # reconstruction would need one tree per staleness level)
            if not (isinstance(self.down, DeltaDownlinkCodec)
                    and not self.down.lossy):
                raise ValueError(
                    f"downlink_unicast needs the lossless delta downlink "
                    f"(downlink_compressor='delta' / 'delta+identity'); "
                    f"got {fed.downlink_compressor!r}")
            if fed.resync_horizon < 0:
                raise ValueError(
                    f"resync_horizon must be >= 0, got {fed.resync_horizon}")
        self.ef_enabled = (self.up is not None and self.up.lossy
                          and fed.error_feedback)
        # byte totals live in a telemetry Counters registry (shared with
        # the engine's Telemetry when one is wired; private otherwise) —
        # the uplink_bytes/... names below stay as property views
        if counters is None:
            from repro.telemetry import Counters
            counters = Counters()
        self.counters = counters
        self._up_nbytes = self._up_raw = 0
        self._down_nbytes = self._down_raw = 0

    # measured (wire format) totals + uncompressed baselines — views over
    # the counter registry so one snapshot captures the whole wire
    @property
    def uplink_bytes(self):
        return self.counters.get("transport.uplink_bytes")

    @property
    def uplink_bytes_raw(self):
        return self.counters.get("transport.uplink_bytes_raw")

    @property
    def downlink_bytes(self):
        return self.counters.get("transport.downlink_bytes")

    @property
    def downlink_bytes_raw(self):
        return self.counters.get("transport.downlink_bytes_raw")

    @property
    def sparse_native(self) -> bool:
        """True when the uplink wire is SparseLeaf pairs AND the config
        asks the server to aggregate them natively
        (``FedConfig.sparse_aggregate``): engines keep the wire sparse all
        the way into the segment-sum aggregate instead of decoding each
        client to dense first.  False falls back to the dense-decode path
        (the CI parity axis)."""
        return (isinstance(self.up, SparseTopKCodec)
                and self.fed.sparse_aggregate)

    @property
    def needs_downlink_ref(self) -> bool:
        """True for the reference-coded (delta) downlink: the broadcast is
        encoded against a reference and the byte accounting distinguishes
        delta payloads from full-θ resyncs."""
        return isinstance(self.down, DeltaDownlinkCodec)

    @property
    def stateful_downlink(self) -> bool:
        """True when the downlink reconstruction genuinely DEPENDS on the
        reference (the lossy delta family): engines must thread the
        reference tree through jit.  The lossless delta configuration
        reconstructs exact θ_t regardless of reference, so it carries no
        reference state at all (the pod train state drops the copy, the
        ReferenceStore holds None)."""
        return self.needs_downlink_ref and self.down.lossy

    def init_downlink_ref(self, params, ctx):
        """The round-0 reference (the out-of-band initial sync), or None
        when the downlink codec is stateless."""
        if not self.needs_downlink_ref:
            return None
        return self.down.init_ref(params, ctx)

    # --- jit-side ------------------------------------------------------
    def broadcast(self, params, ctx, key=None, ref=None):
        """Downlink: (θ_t, client ctx) -> (params_w, ctx_w, new_ref) — what
        the clients actually receive, plus the advanced reference state for
        the delta codec (None otherwise).  Lossless codecs return the
        inputs untouched (bit-exact)."""
        if self.down is not None and self.down.lossy and key is None:
            # failing fast beats silently reusing one noise draw: a constant
            # key would correlate the stochastic-rounding error across every
            # round, and the downlink has no EF to drain the resulting bias
            raise ValueError("a lossy downlink codec needs a per-round PRNG "
                             "key; pass key= to broadcast()/client_ctx()")
        if self.needs_downlink_ref:
            # only the LOSSY delta reconstruction depends on the reference;
            # the lossless configuration never reads it, and ref=None is
            # the supported "reference dropped" form (stateful_downlink)
            if self.down.lossy and ref is None:
                raise ValueError(
                    "the lossy delta downlink codec is stateful: pass ref= "
                    "(see Transport.init_downlink_ref) and thread the "
                    "returned reference into the next round")
            return self.down.broadcast(params, ctx, ref, key)
        if self.down is None or not self.down.lossy:
            return params, ctx, None
        tree = (params, ctx)
        (params_w, ctx_w), _ = self.down.roundtrip(tree, T.zeros_like(tree),
                                                   key)
        return params_w, ctx_w, None

    def uplink(self, delta, ef, key):
        """One client's uplink round trip: -> (dense reconstruction the
        server aggregates, new EF residual).  vmap over clients."""
        if self.up is None:
            return delta, ef
        return self.up.roundtrip(delta, ef, key)

    def uplink_encode(self, delta, ef, key):
        if self.up is None:
            return delta, ef
        return self.up.encode(delta, ef, key)

    def uplink_decode(self, wire, like):
        if self.up is None:
            return wire
        return self.up.decode(wire, like)

    # --- host-side accounting ------------------------------------------
    def set_wire_templates(self, uplink_template, downlink_template=None):
        """Precompute per-client wire sizes from (ShapeDtypeStruct) pytree
        templates: uplink = the delta tree, downlink = (θ_t, ctx)."""
        self._up_raw = C.raw_nbytes(uplink_template)
        self._up_nbytes = (self._up_raw if self.up is None
                           else self.up.wire_nbytes(uplink_template))
        if downlink_template is not None:
            self._down_raw = C.raw_nbytes(downlink_template)
            self._down_nbytes = (self._down_raw if self.down is None
                                 else self.down.wire_nbytes(downlink_template))

    def account_uplink(self, n_clients: int = 1):
        self.counters.inc("transport.uplink_bytes",
                          n_clients * self._up_nbytes)
        self.counters.inc("transport.uplink_bytes_raw",
                          n_clients * self._up_raw)

    def account_downlink(self, n_clients: int = 1, resync: bool = False):
        """`resync=True` marks broadcasts that ship the full tree instead of
        a delta — the delta codec's round-0 initial sync (engines pass it
        for every client dispatched at version 0); stateless codecs ignore
        it (their per-round bytes never depend on history)."""
        nbytes = self._down_nbytes
        if resync and self.needs_downlink_ref:
            nbytes = self._down_raw
        self.counters.inc("transport.downlink_bytes", n_clients * nbytes)
        self.counters.inc("transport.downlink_bytes_raw",
                          n_clients * self._down_raw)

    def account_unicast(self, n_fresh: int, n_catchup: int, n_resync: int):
        """Per-dispatched-client unicast downlink accounting (the
        ReferenceStore's classification): fresh clients already hold the
        current version (0 measured bytes), catch-up clients receive the
        chained delta against their version (steady-state delta bytes),
        resync clients get the full-θ payload.  The raw baseline charges
        every dispatched client one full broadcast, exactly like the
        multicast model — under full participation the two accountings
        coincide by construction."""
        measured = (n_catchup * self._down_nbytes
                    + n_resync * self._down_raw)
        n = n_fresh + n_catchup + n_resync
        self.counters.inc("transport.downlink_bytes", measured)
        self.counters.inc("transport.downlink_bytes_raw",
                          n * self._down_raw)

    # template-free probes (benchmarks, shims)
    def uplink_wire_nbytes(self, template) -> int:
        return (C.raw_nbytes(template) if self.up is None
                else self.up.wire_nbytes(template))

    def downlink_wire_nbytes(self, template) -> int:
        return (C.raw_nbytes(template) if self.down is None
                else self.down.wire_nbytes(template))


@functools.lru_cache(maxsize=None)
def _shim_transport(compressor: str, topk_frac: float, qsgd_bits: int,
                    error_feedback: bool, sparse_uplink: bool,
                    use_pallas: bool) -> Transport:
    from repro.configs.base import FedConfig  # lazy: layering
    return Transport(FedConfig(
        compressor=compressor, topk_frac=topk_frac, qsgd_bits=qsgd_bits,
        error_feedback=error_feedback, sparse_uplink=sparse_uplink,
        use_pallas=use_pallas))


def shim_transport(fed) -> Transport:
    """Stateless cached instance backing the deprecated
    ``strategy.compress_delta`` shim (counters unused there).

    The cache is keyed on the uplink-wire-relevant fields only — the shim
    never touches the downlink — rather than on the whole config: keying on
    ``fed`` itself leaks one Transport per distinct config (every ``eta``
    sweep point would pin an instance) and, were the config mutable, could
    serve a codec built from stale knobs.  Configs must be frozen so the
    key fields cannot drift after the codec is built."""
    params = getattr(type(fed), "__dataclass_params__", None)
    if params is None or not params.frozen:
        raise TypeError(
            f"shim_transport needs a frozen config (got "
            f"{type(fed).__name__}): a mutable config could change its "
            f"wire knobs after the cached codec was built")
    return _shim_transport(fed.compressor, fed.topk_frac, fed.qsgd_bits,
                           fed.error_feedback, fed.sparse_uplink,
                           fed.use_pallas)


def downlink_nbytes(fed, params, ctx) -> int:
    """Measured bytes one client receives per round under fed's downlink
    codec (raw broadcast bytes when downlink compression is off)."""
    return Transport(fed).downlink_wire_nbytes((params, ctx))
