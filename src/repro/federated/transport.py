"""First-class bidirectional wire layer for the federated round
(DESIGN.md §Transport).

The round protocol used to hand-wire compression per engine through the
``strategy.compress_delta`` hook (uplink only, dense reconstruction only,
analytic downlink accounting).  ``Transport`` owns both directions of the
wire instead, and every engine drives it identically:

* **downlink** — ``broadcast(params, ctx, key)``: the server compresses the
  per-round broadcast (θ_t plus the strategy's client context, e.g. the
  FedADC m̄_t) once, and clients train on the wire reconstruction.  The
  downlink codec is stateless server-side (a broadcast has no per-client
  residual to carry).  ``none``/``identity`` are bit-exact passthroughs.
* **uplink** — ``uplink(delta, ef, key)``: one client's delta is encoded
  against its error-feedback memory, transported, and decoded; the server
  only ever aggregates wire reconstructions, so the FedADC momentum
  recursion stays consistent with what a bandwidth-constrained deployment
  can compute (DESIGN.md §Compression).
* **accounting** — measured (wire-format) and raw byte counters for BOTH
  directions, unified here instead of per-engine ad-hoc sums.  Wire sizes
  come from the exact formats in ``repro.federated.compression`` and work
  on ``ShapeDtypeStruct`` templates (no allocation).

Codecs wrap the compressors in ``repro.federated.compression``:
``identity`` (lossless), ``topk``/``qsgd`` dense round trips, and — new —
a **true sparse top-k** path (``FedConfig.sparse_uplink``): inside jit the
wire is per-leaf ``(values, indices)`` pairs (``SparseLeaf``); the server
decodes with one scatter per client instead of re-running a dense
threshold pass, so the wire representation the byte accounting always
assumed now exists as an actual program object.  The sparse reconstruction
equals the dense path exactly (oracle-tested).

Engines construct their own ``Transport`` (counters are per-engine); the
deprecated ``strategy.compress_delta`` shim goes through a cached
stateless instance.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import tree as T
from repro.federated import compression as C


class SparseLeaf(NamedTuple):
    """One leaf's sparse wire format: the k surviving (value, index) pairs.
    A NamedTuple, so it is a pytree — it vmaps over clients and crosses jit
    boundaries like any other array pair."""
    values: jax.Array     # (k,)
    indices: jax.Array    # (k,) int32, flat index into the leaf


def _is_sparse(x) -> bool:
    return isinstance(x, SparseLeaf)


# ---------------------------------------------------------------------------
# codecs — one direction of the wire each
# ---------------------------------------------------------------------------
class Codec:
    """encode/decode run inside jit (per client, vmap-safe); wire_nbytes is
    host-side accounting of the same format."""
    name = "base"
    lossy = True

    def encode(self, tree, ef, key):
        """(pytree, EF pytree, key) -> (wire, new EF = exact residual)."""
        raise NotImplementedError

    def decode(self, wire, like):
        """Wire -> dense pytree shaped like `like` (the server's view)."""
        raise NotImplementedError

    def roundtrip(self, tree, ef, key):
        """encode ∘ decode fused: -> (dense reconstruction, new EF)."""
        wire, new_ef = self.encode(tree, ef, key)
        return self.decode(wire, tree), new_ef

    def wire_nbytes(self, template) -> int:
        raise NotImplementedError


class IdentityCodec(Codec):
    name = "identity"
    lossy = False

    def encode(self, tree, ef, key):
        # pure passthrough — no arithmetic, so engine trajectories are
        # bit-identical to transport-off runs (tested)
        return tree, ef

    def decode(self, wire, like):
        return wire

    def wire_nbytes(self, template) -> int:
        return C.raw_nbytes(template)


class DenseCodec(Codec):
    """Lossy compressor whose in-program wire is the dense reconstruction
    (the pre-redesign representation: real bytes live only in the
    accounting).  Wraps topk/qsgd from repro.federated.compression."""

    def __init__(self, comp: C.Compressor):
        self._comp = comp
        self.name = comp.name
        self.lossy = comp.lossy

    def encode(self, tree, ef, key):
        return self._comp.compress(tree, ef, key)

    def decode(self, wire, like):
        return wire

    def wire_nbytes(self, template) -> int:
        return self._comp.wire_nbytes(template)


class SparseTopKCodec(Codec):
    """Top-k magnitude sparsification whose in-program wire IS the sparse
    (value, index) format: per leaf, ``lax.top_k`` selects the k = ⌈frac·n⌉
    largest-|v| entries of v = Δ + e, the residual zeroes exactly those
    indices, and the server scatters the pairs back into a dense zero
    tensor.  Reconstruction and residual match the dense threshold path
    exactly away from magnitude ties (on a tie the dense path keeps every
    entry ≥ τ while top-k keeps exactly k)."""
    name = "topk"
    lossy = True

    def __init__(self, frac: float):
        # reuse the dense compressor's validation + wire accounting
        self._acct = C.TopKCompressor(frac)
        self.frac = frac

    def encode(self, tree, ef, key):
        from repro.kernels import ops
        v = T.add(tree, ef)
        # flatten/unflatten rather than an is_leaf-on-tuples tree.map: the
        # input pytree may itself contain tuple internal nodes, which an
        # isinstance(tuple) heuristic would mistake for (wire, ef) pairs
        leaves, treedef = jax.tree.flatten(v)
        wire_leaves, ef_leaves = [], []
        for x in leaves:
            values, indices, residual = ops.topk_sparse_leaf(
                x, self._acct._k(x.size))
            wire_leaves.append(SparseLeaf(values, indices))
            ef_leaves.append(residual)
        return (jax.tree.unflatten(treedef, wire_leaves),
                jax.tree.unflatten(treedef, ef_leaves))

    def decode(self, wire, like):
        from repro.kernels import ops
        return jax.tree.map(
            lambda w, l: ops.sparse_scatter_leaf(w.values, w.indices,
                                                 l.shape, l.dtype),
            wire, like, is_leaf=_is_sparse)

    def wire_nbytes(self, template) -> int:
        return self._acct.wire_nbytes(template)


def make_codec(name: str, fed, direction: str = "uplink") -> Optional[Codec]:
    """Codec for one wire direction (None = bypass, the pre-transport code
    path with zero added arithmetic)."""
    if name == "none":
        return None
    if name == "identity":
        return IdentityCodec()
    if name == "topk":
        if direction == "uplink" and fed.sparse_uplink:
            return SparseTopKCodec(fed.topk_frac)
        return DenseCodec(C.TopKCompressor(fed.topk_frac, fed.use_pallas))
    if name == "qsgd":
        return DenseCodec(C.QSGDCompressor(fed.qsgd_bits, fed.use_pallas))
    raise ValueError(f"unknown {direction} compressor {name!r}; "
                     f"known: {', '.join(C.KNOWN_COMPRESSORS)}")


# ---------------------------------------------------------------------------
# the transport
# ---------------------------------------------------------------------------
class Transport:
    """Bidirectional wire layer: downlink broadcast codec, uplink delta
    codec, and measured-byte accounting for both directions.

    jit-side methods (`broadcast`, `uplink`, `uplink_encode`,
    `uplink_decode`) are pure; the byte counters are host-side and advance
    through `account_uplink` / `account_downlink` once per transported
    client.  Engines own their instance — counters are engine-local."""

    def __init__(self, fed):
        if fed.sparse_uplink and fed.compressor not in ("topk", "none"):
            raise ValueError(
                f"sparse_uplink is the (value, index) top-k wire format; "
                f"compressor={fed.compressor!r} has no sparse path")
        self.fed = fed
        self.up = make_codec(fed.compressor, fed, "uplink")
        self.down = make_codec(fed.downlink_compressor, fed, "downlink")
        self.ef_enabled = (self.up is not None and self.up.lossy
                          and fed.error_feedback)
        self.uplink_bytes = 0        # measured (wire format) totals
        self.uplink_bytes_raw = 0    # uncompressed baselines
        self.downlink_bytes = 0
        self.downlink_bytes_raw = 0
        self._up_nbytes = self._up_raw = 0
        self._down_nbytes = self._down_raw = 0

    # --- jit-side ------------------------------------------------------
    def broadcast(self, params, ctx, key=None):
        """Downlink: (θ_t, client ctx) -> what the clients actually receive.
        Lossless codecs return the inputs untouched (bit-exact)."""
        if self.down is None or not self.down.lossy:
            return params, ctx
        if key is None:
            # failing fast beats silently reusing one noise draw: a constant
            # key would correlate the stochastic-rounding error across every
            # round, and the downlink has no EF to drain the resulting bias
            raise ValueError("a lossy downlink codec needs a per-round PRNG "
                             "key; pass key= to broadcast()/client_ctx()")
        tree = (params, ctx)
        (params_w, ctx_w), _ = self.down.roundtrip(tree, T.zeros_like(tree),
                                                   key)
        return params_w, ctx_w

    def uplink(self, delta, ef, key):
        """One client's uplink round trip: -> (dense reconstruction the
        server aggregates, new EF residual).  vmap over clients."""
        if self.up is None:
            return delta, ef
        return self.up.roundtrip(delta, ef, key)

    def uplink_encode(self, delta, ef, key):
        if self.up is None:
            return delta, ef
        return self.up.encode(delta, ef, key)

    def uplink_decode(self, wire, like):
        if self.up is None:
            return wire
        return self.up.decode(wire, like)

    # --- host-side accounting ------------------------------------------
    def set_wire_templates(self, uplink_template, downlink_template=None):
        """Precompute per-client wire sizes from (ShapeDtypeStruct) pytree
        templates: uplink = the delta tree, downlink = (θ_t, ctx)."""
        self._up_raw = C.raw_nbytes(uplink_template)
        self._up_nbytes = (self._up_raw if self.up is None
                           else self.up.wire_nbytes(uplink_template))
        if downlink_template is not None:
            self._down_raw = C.raw_nbytes(downlink_template)
            self._down_nbytes = (self._down_raw if self.down is None
                                 else self.down.wire_nbytes(downlink_template))

    def account_uplink(self, n_clients: int = 1):
        self.uplink_bytes += n_clients * self._up_nbytes
        self.uplink_bytes_raw += n_clients * self._up_raw

    def account_downlink(self, n_clients: int = 1):
        self.downlink_bytes += n_clients * self._down_nbytes
        self.downlink_bytes_raw += n_clients * self._down_raw

    # template-free probes (benchmarks, shims)
    def uplink_wire_nbytes(self, template) -> int:
        return (C.raw_nbytes(template) if self.up is None
                else self.up.wire_nbytes(template))

    def downlink_wire_nbytes(self, template) -> int:
        return (C.raw_nbytes(template) if self.down is None
                else self.down.wire_nbytes(template))


@functools.lru_cache(maxsize=None)
def shim_transport(fed) -> Transport:
    """Stateless cached instance backing the deprecated
    ``strategy.compress_delta`` shim (counters unused there)."""
    return Transport(fed)


def downlink_nbytes(fed, params, ctx) -> int:
    """Measured bytes one client receives per round under fed's downlink
    codec (raw broadcast bytes when downlink compression is off)."""
    return Transport(fed).downlink_wire_nbytes((params, ctx))
