"""The unified round protocol: strategy + aggregator + transport + store
(DESIGN.md §Transport).

Every engine runs the same abstract round:

    1. broadcast   — θ_t and the strategy's client context go down the wire
                     (``RoundProtocol.client_ctx`` → ``Transport.broadcast``)
    2. local work  — clients run H local steps (engine-specific execution:
                     vmapped in the simulator, event-driven dispatch groups
                     in the async engine, client-serial × pod-parallel scan
                     in the pod engine)
    3. uplink      — each delta rides ``RoundProtocol.uplink`` against the
                     client's EF residual from the ``ClientStore``
    4. aggregate   — pluggable weights + ``strategy.server_aggregate``
    5. server step — the strategy's momentum/update recursion

``RoundProtocol`` is deliberately thin: it owns the *composition* (which
codec, which store namespaces, which aggregator reference) and the
cross-cutting validation, while the engines keep their execution schedule.
Three divergent round loops become one protocol with three execution
backends.
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.core.strategies import get_strategy
from repro.federated import aggregation as A
from repro.federated.reference import ReferenceStore
from repro.federated.store import ClientStore
from repro.federated.transport import Transport

# strategies whose server corrections are rebuilt from auxiliary uplink
# state (SCAFFOLD c_i deltas, FedDyn raw drift sums) the wire codecs do not
# model — a lossy delta would silently break those invariants, and their
# corrections are *uniform* means, so non-uniform weights would bias them
STATEFUL_SERVER_CORRECTION = ("scaffold", "feddyn")


class RoundProtocol:
    """One federated round's pluggable pieces, composed once per engine."""

    def __init__(self, fed, strategy=None, store: Optional[ClientStore] = None,
                 transport: Optional[Transport] = None, telemetry=None):
        self.fed = fed
        self.strategy = strategy if strategy is not None \
            else get_strategy(fed.strategy)
        if transport is not None:
            self.transport = transport
        else:
            # a wired Telemetry shares its counter registry with the wire
            # layer so transport bytes land in the same snapshot/export
            counters = telemetry.counters if telemetry is not None else None
            self.transport = Transport(fed, counters=counters)
        self.store = store if store is not None else ClientStore()
        # the unified downlink reference layer (DESIGN.md §Transport): one
        # ReferenceStore per engine owns the broadcast reference, the
        # one-wire-per-version memo, and the per-client unicast bookkeeping
        # — per-client reference pages ride this protocol's client store,
        # so a paged backend spills them through its LRU/zlib tier
        self.refs = ReferenceStore(fed, self.transport, store=self.store,
                                   telemetry=telemetry)
        # two-tier fleet topology: aggregate() routes through the regional/
        # global reduce instead of the flat one (fleet.hierarchy; lazy
        # import — repro.federated.fleet composes on top of this module)
        self.hierarchical = None
        if fed.fleet_regions > 0:
            from repro.federated.fleet import HierarchicalAggregator
            self.hierarchical = HierarchicalAggregator(fed, self.strategy)
        if fed.strategy in STATEFUL_SERVER_CORRECTION:
            if fed.aggregator != "uniform":
                raise ValueError(
                    f"aggregator={fed.aggregator!r} is not supported with "
                    f"{fed.strategy!r}; use aggregator='uniform'")
            if self.transport.up is not None and self.transport.up.lossy:
                raise ValueError(
                    f"compressor={fed.compressor!r} is not supported with "
                    f"{fed.strategy!r}; use compressor='none'")
            if self.transport.down is not None and self.transport.down.lossy:
                raise ValueError(
                    f"downlink_compressor={fed.downlink_compressor!r} is not "
                    f"supported with {fed.strategy!r}: the broadcast carries "
                    f"its server correction")
        self.ef_enabled = self.transport.ef_enabled

    # --- store wiring ---------------------------------------------------
    def register_client_state(self, init_fn: Callable) -> None:
        self.store.register("state", init_fn)

    def register_ef(self, init_fn: Callable) -> None:
        self.store.register("ef", init_fn)

    def init_downlink_ref(self, server_state, params):
        """The delta downlink codec's round-0 broadcast reference: the
        out-of-band initial sync (θ_0, ctx_0) every client starts from, so
        the first wire delta is exactly zero.  None for stateless codecs."""
        if not self.transport.needs_downlink_ref:
            return None
        ctx = self.strategy.client_setup(server_state, params, self.fed)
        return self.transport.init_downlink_ref(params, ctx)

    # --- jit-side protocol steps ----------------------------------------
    def client_ctx(self, server_state, params, key=None, ref=None):
        """Step 1: build the strategy's client context and push (θ_t, ctx)
        through the downlink codec.  -> (params', ctx', new_ref) as
        received; `ref`/`new_ref` carry the delta codec's broadcast
        reference state (None for stateless downlink codecs) — engines
        thread it through their round loop."""
        ctx = self.strategy.client_setup(server_state, params, self.fed)
        return self.transport.broadcast(params, ctx, key, ref)

    def uplink(self, delta, ef, key):
        """Step 3: one client's wire round trip (vmap over clients)."""
        return self.transport.uplink(delta, ef, key)

    def uplink_encode(self, delta, ef, key):
        """Step 3, sparse-native form: encode only — the wire (SparseLeaf
        pairs) flows straight into the sparse aggregate, never decoded to a
        per-client dense tree.  The EF residual is the same exact
        complement `uplink` would return (encode computes it; decode never
        touches it), so switching paths cannot drift the EF contract."""
        return self.transport.uplink_encode(delta, ef, key)

    def uplink_decode(self, wire, like):
        return self.transport.uplink_decode(wire, like)

    @property
    def sparse_native(self) -> bool:
        """True when the engines should keep the uplink wire sparse into
        the aggregate (Transport.sparse_native)."""
        return self.transport.sparse_native

    def weights(self, deltas, n_examples=None, server_state=None, like=None):
        """Step 4a: aggregation weights from the pluggable aggregator; the
        DRAG reference is the server momentum when the strategy keeps one.
        `like` is the dense template sparse-wire DRAG aggregates its
        round-mean fallback into (ignored for dense deltas)."""
        ref = A.reference_direction(server_state)
        return A.compute_weights(self.fed.aggregator, deltas,
                                 n_examples=n_examples, ref=ref,
                                 lam=self.fed.drag_lambda, like=like,
                                 use_pallas=self.fed.use_pallas)

    def aggregate(self, deltas, weights, like=None):
        """Step 4b: Δ̄ through the strategy's shared reduction.  A stacked
        SparseLeaf wire takes the sparse-native segment-sum (K·k cost,
        `like` required for the dense output template); stateful-correction
        strategies never reach it (they reject lossy uplinks above)."""
        if self.hierarchical is not None:
            # the two-tier topology reuses the same regional reduces
            # (strategy hook dense, segment-sum sparse) and combines the R
            # partials in fp32 — every engine inherits it through this one
            # dispatch point (bitwise == flat at fleet_regions=1)
            return self.hierarchical(deltas, weights, like=like)
        if A.is_sparse_tree(deltas):
            if like is None:
                raise ValueError("sparse-native aggregation needs a dense "
                                 "template (like=)")
            return A.sparse_weighted_mean(deltas, weights, like,
                                          use_pallas=self.fed.use_pallas)
        return self.strategy.server_aggregate(deltas, weights, self.fed)

    def server_update(self, server_state, params, mean_delta):
        """Step 5 (common path; SCAFFOLD/FedDyn keep their dedicated server
        hooks in the simulator)."""
        return self.strategy.server_update(server_state, params, mean_delta,
                                           self.fed)
