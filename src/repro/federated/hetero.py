"""Client system-heterogeneity model (DESIGN.md §Heterogeneity).

Real edge fleets are never the synchronous, identically-fast population the
paper's experiments assume: clients differ in compute speed (stragglers),
come and go (availability), and run variable amounts of local work H_i.
This module models that fleet — sampled once per federation from
``HeteroConfig`` distributions — and provides the two pieces of algebra the
engines need to stay *correct* under it:

* FedNova-style normalisation (``fednova_scale``): a client that ran H_i
  local SGD steps produced a delta whose expected magnitude scales with H_i;
  rescaling by H_ref/H_i removes the objective inconsistency that otherwise
  biases the aggregate towards fast/verbose clients.
* staleness discounting (``staleness_discount``): in the semi-async engine a
  delta computed against parameter version v applies at version v+s; the
  FedADC momentum contribution of that pseudo-gradient is damped by a factor
  that decays with s so acceleration survives stale directions.

All randomness is drawn from a single ``RandomState(hetero.seed)`` in event
order, so the virtual-clock scheduler built on top is fully deterministic.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.configs.base import HeteroConfig


def sample_speeds(hetero: HeteroConfig, n_clients: int,
                  rng: np.random.RandomState) -> np.ndarray:
    """Per-client relative compute speed (1.0 = reference client)."""
    if not hetero.enabled or hetero.speed_dist == "constant":
        return np.ones(n_clients, np.float64)
    if hetero.speed_dist == "lognormal":
        s = np.exp(hetero.speed_sigma * rng.randn(n_clients))
        return s / s.max()                      # fastest client = 1.0
    if hetero.speed_dist == "uniform":
        lo, hi = hetero.speed_range
        return rng.uniform(lo, hi, size=n_clients)
    if hetero.speed_dist == "bimodal":
        slow = rng.rand(n_clients) < hetero.straggler_frac
        return np.where(slow, 1.0 / hetero.straggler_slowdown, 1.0)
    raise ValueError(f"unknown speed_dist {hetero.speed_dist!r}")


def sample_local_steps(hetero: HeteroConfig, n_clients: int, base_h: int,
                       rng: np.random.RandomState) -> np.ndarray:
    """Per-client local work H_i (fixed for the federation's lifetime)."""
    if not hetero.enabled or not hetero.local_steps_choices:
        return np.full(n_clients, base_h, np.int64)
    choices = np.asarray(hetero.local_steps_choices, np.int64)
    return choices[rng.randint(0, len(choices), size=n_clients)]


def fednova_scale(h_i, h_ref) -> float:
    """Delta rescale for a client that ran h_i local steps (reference h_ref).

    For plain local SGD the FedNova a_i coefficient is the step count, so the
    normalised delta is Δ_i · (h_ref / h_i)."""
    return float(h_ref) / float(h_i)


def staleness_discount(s, mode: str = "poly", factor: float = 0.5):
    """Momentum damping for a delta that is `s` server versions stale.

    none: 1;  poly: (1+s)^(−factor);  exp: factor^s.  s may be a numpy
    array; the return broadcasts."""
    s = np.asarray(s, np.float64)
    if mode == "none":
        return np.ones_like(s)
    if mode == "poly":
        return (1.0 + s) ** (-factor)
    if mode == "exp":
        return np.asarray(factor, np.float64) ** s
    raise ValueError(f"unknown staleness_mode {mode!r}")


class ClientSystemModel:
    """The fleet: speeds, per-client H_i, availability and dropout draws.

    Speed and H_i are sampled once at construction; availability/dropout/
    jitter are drawn from the same RandomState in event order, which makes a
    fixed-seed simulation bit-reproducible (tested)."""

    def __init__(self, hetero: HeteroConfig, n_clients: int,
                 base_local_steps: int):
        self.hetero = hetero
        self.n_clients = n_clients
        self.base_local_steps = base_local_steps
        rng = np.random.RandomState(hetero.seed)
        self.speeds = sample_speeds(hetero, n_clients, rng)
        self.local_steps = sample_local_steps(hetero, n_clients,
                                              base_local_steps, rng)
        self._rng = rng

    def round_time(self, client: int) -> float:
        """Virtual time for one full local round on `client` (H_i / speed,
        one unit = one local step on the reference client)."""
        base = float(self.local_steps[client]) / float(self.speeds[client])
        if self.hetero.enabled and self.hetero.time_jitter > 0:
            base *= 1.0 + self.hetero.time_jitter * abs(self._rng.randn())
        return base

    def is_available(self, client: int) -> bool:
        if not self.hetero.enabled or self.hetero.availability >= 1.0:
            return True
        return bool(self._rng.rand() < self.hetero.availability)

    def drops_out(self, client: int) -> bool:
        if not self.hetero.enabled or self.hetero.drop_prob <= 0.0:
            return False
        return bool(self._rng.rand() < self.hetero.drop_prob)

    def delta_scale(self, client: int) -> float:
        """FedNova normalisation factor for this client's delta."""
        if not (self.hetero.enabled and self.hetero.fednova):
            return 1.0
        return fednova_scale(self.local_steps[client], self.base_local_steps)
