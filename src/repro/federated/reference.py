"""Unified downlink reference layer (DESIGN.md §Transport).

Before this module the repo carried **three** disjoint downlink reference
mechanisms: the simulator threaded the delta codec's reference through its
jit'd ``round_fn`` signature, the async engine kept a version-keyed
broadcast cache next to its own copy of the reference, and the pod engine
stored a third copy inside the sharded train state — three code paths for
one fact ("what tree do the clients currently hold").  ``ReferenceStore``
owns that fact behind one interface, and every engine drives it
identically:

* **global multicast reference** (today's model) — ``broadcast(version,
  compute)`` memoises one wire reconstruction per server version (the old
  async cache, now shared by every engine) and advances the codec
  reference exactly once per version.  The reference itself is held only
  for the *lossy* delta family (``Transport.stateful_downlink``): the
  lossless configuration reconstructs θ_t bit-exactly regardless of
  reference, so it carries none — which is also what lets the pod engine
  drop the unread reference copy from its train state.
* **per-client unicast backend** (``FedConfig.downlink_unicast``) —
  ``dispatch`` tracks each client's last-received version and classifies
  every dispatch: *fresh* (client already holds this version, 0 measured
  bytes), *catch-up* (staleness ≤ ``FedConfig.resync_horizon``: the chained
  delta against *their* version, steady-state delta bytes), or *resync*
  (past the horizon or never seen: the full-θ payload).  Accounting
  switches from one-multicast-payload to per-dispatched-client unicast
  bytes (``Transport.account_unicast``) in both the measured and raw
  counters, plus ``downlink.catchups`` / ``downlink.resyncs`` counters and
  a per-dispatch payload histogram.  When a client store is attached, each
  dispatched client's wire lands in a ``"downlink_ref"`` store namespace —
  under a ``PagedClientStore`` the per-client references therefore spill
  through the LRU/zlib tier instead of growing host memory with the fleet.

The per-client bookkeeping is bounded by construction: every mapping is
keyed by client id and written by plain item assignment, so a long-lived
engine holds O(clients) host state (the dynamic counterpart of the
``unbounded-host-accumulator`` analysis rule, pinned in tests), and the
wire memo is a single slot — the old per-engine caches never return.

Unicast is restricted to the *lossless* delta family: a per-client lossy
reconstruction would need one broadcast tree per staleness level, while the
lossless codec hands every client bit-exact θ_t regardless of their
reference — only the bookkeeping and the bytes are per-client, so the
in-jit program stays a single tree (``Transport`` validates this).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

# the store namespace per-client reference pages live in (one page per
# dispatched client: the (params_w, ctx_w) wire that client last received)
REF_NAMESPACE = "downlink_ref"


class ReferenceStore:
    """All downlink reference state behind one interface (engine-local,
    host-side; the jit'd broadcast functions stay pure and take the
    reference as an explicit argument)."""

    def __init__(self, fed, transport, store=None, telemetry=None):
        self.fed = fed
        self.transport = transport
        self.store = store
        self.unicast = bool(fed.downlink_unicast)
        self.horizon = int(fed.resync_horizon)
        # the codec reference R_v = the previous broadcast reconstruction;
        # held only for the lossy delta family (stateful_downlink) — the
        # lossless configuration drops it entirely
        self._ref = None
        # single-slot wire memo: one broadcast per server version (the old
        # async per-version cache, generalised to every engine)
        self._wire_version: Optional[int] = None
        self._wire = None
        # per-client bookkeeping, written by plain item assignment only and
        # keyed by client id — bounded at O(clients) by construction
        self._client_version: Dict[int, int] = {}
        self.client_bytes: Dict[int, int] = {}
        self.client_catchups: Dict[int, int] = {}
        self.client_resyncs: Dict[int, int] = {}
        self._registered = False
        self._page_specs = None
        if telemetry is not None:
            self._kb_hist = telemetry.histogram("downlink.client_kb")
        else:
            from repro.telemetry import Histogram
            self._kb_hist = Histogram(n_bins=32)

    @property
    def counters(self):
        return self.transport.counters

    @property
    def catchups(self) -> int:
        return self.counters.get("downlink.catchups")

    @property
    def resyncs(self) -> int:
        return self.counters.get("downlink.resyncs")

    # --- the codec reference -------------------------------------------
    def seed(self, ref) -> None:
        """Install the round-0 reference (the out-of-band initial sync the
        clients start from).  Dropped unless the downlink reconstruction
        genuinely depends on it (the lossy delta family) — the lossless
        codec never reads the reference, so none is held."""
        self._ref = ref if self.transport.stateful_downlink else None

    def reference(self):
        """The reference the next broadcast encodes against (None when the
        codec is stateless or lossless)."""
        return self._ref

    def advance(self, version: int, wire, new_ref) -> None:
        """Record version `version`'s wire in the memo and advance the
        codec reference to the new reconstruction."""
        self._wire_version = version
        self._wire = wire
        if self.transport.stateful_downlink:
            self._ref = new_ref

    # --- the broadcast memo --------------------------------------------
    def broadcast(self, version: int, compute):
        """The version-`version` broadcast wire, computed at most once per
        server version: `compute(ref) -> (params_w, ctx_w, new_ref)` runs
        only on a memo miss, the reference advances exactly once per
        version, and every dispatch at that version receives the same wire
        reconstruction.  -> (params_w, ctx_w)."""
        if self._wire_version != version:
            params_w, ctx_w, new_ref = compute(self._ref)
            self.advance(version, (params_w, ctx_w), new_ref)
        return self._wire

    # --- dispatch accounting + per-client bookkeeping -------------------
    def dispatch(self, clients, version: int, wire=None) -> None:
        """Account one dispatch wave at server version `version`.

        Multicast mode reproduces the historical accounting exactly: every
        dispatched client pays the steady-state payload, with version 0
        charged as the delta codec's full initial sync.  Unicast mode
        classifies each client against their last-received version
        (fresh / catch-up / full resync), charges per-client bytes, and —
        when a store is attached and the wave's `wire` is given — writes
        the wire into that client's reference page."""
        clients = [int(c) for c in clients]
        if not self.unicast:
            self.transport.account_downlink(len(clients),
                                            resync=(version == 0))
            return
        t = self.transport
        n_fresh = n_catchup = n_resync = 0
        for c in clients:
            last = self._client_version.get(c)
            if last is None or version - last > self.horizon:
                # never seen, or past the horizon: full-θ resync
                n_resync += 1
                nbytes = t._down_raw
                self.client_resyncs[c] = self.client_resyncs.get(c, 0) + 1
            elif version == last:
                # already holds this version: nothing to ship
                n_fresh += 1
                nbytes = 0
            else:
                # 1 ≤ staleness ≤ horizon: the chained delta against THEIR
                # version — the lossless dense delta costs steady-state
                # bytes regardless of how many versions it spans
                n_catchup += 1
                nbytes = t._down_nbytes
                self.client_catchups[c] = self.client_catchups.get(c, 0) + 1
            self._client_version[c] = version
            self.client_bytes[c] = self.client_bytes.get(c, 0) + nbytes
            self._kb_hist.observe(nbytes // 1024)
        t.account_unicast(n_fresh, n_catchup, n_resync)
        self.counters.inc("downlink.catchups", n_catchup)
        self.counters.inc("downlink.resyncs", n_resync)
        if wire is not None and self.store is not None:
            self._write_pages(clients, wire)

    def client_staleness(self, client, version: int) -> Optional[int]:
        """`version` minus the client's last-received version (None when
        the client has never been dispatched)."""
        last = self._client_version.get(int(client))
        return None if last is None else version - last

    # --- per-client reference pages --------------------------------------
    def _write_pages(self, clients, wire) -> None:
        if not self._registered:
            # the store's lazy-init contract needs a REAL zeros builder
            # (a paged backend materialises the template to size empty
            # slots), so capture the wire's specs on first write
            self._page_specs = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(jnp.shape(x), x.dtype), wire)
            specs = self._page_specs
            self.store.register(
                REF_NAMESPACE,
                lambda: jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                     specs))
            self._registered = True
        view = self.store.states(REF_NAMESPACE)
        for c in clients:
            view[c] = wire

    def client_reference(self, client):
        """The reference page one client holds (the wire it last received),
        or None before its first dispatch.  A single-pick gather: a paged
        backend faults a spilled page back in through its zlib tier."""
        if self.store is None or not self._registered:
            return None
        if int(client) not in self.store.states(REF_NAMESPACE):
            return None
        stacked = self.store.gather(REF_NAMESPACE, [int(client)])
        return jax.tree.map(lambda x: x[0], stacked)
