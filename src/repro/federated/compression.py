"""Pluggable uplink delta compressors with per-client error feedback.

The paper claims acceleration + drift control with *no additional
communication load* (Sec. II-A); this module makes the uplink side of that
claim measurable instead of analytic.  Each client compresses its round
delta before transport; the server aggregates and runs the FedADC momentum
recursion on the *decompressed* reconstruction, so drift control composes
with a lossy uplink (DESIGN.md §Compression).

Compressors (``FedConfig.compressor``):

* ``none``     — the hook is bypassed entirely (pre-compression code path).
* ``identity`` — goes through the hook but is lossless; engine runs are
  bit-identical to ``none`` (tested), which pins the hook's placement.
* ``topk``     — top-k magnitude sparsification: per leaf, the k =
  ⌈topk_frac·n⌉ largest-|v| entries survive; the wire carries (value, index)
  pairs, ⌈log₂ n⌉ bits per index.
* ``qsgd``     — QSGD-style stochastic uniform quantisation: magnitudes are
  scaled by the per-leaf max into ``2^qsgd_bits − 1`` levels and
  stochastically rounded (unbiased given the scale); the wire carries
  ``qsgd_bits``+sign per entry plus one f32 scale per leaf.

Error feedback (``FedConfig.error_feedback``): the client quantises
``v_t = Δ_t + e_{t-1}`` and keeps ``e_t = v_t − q(v_t)`` — the *exact*
compression residual — to re-inject next round, so systematic quantisation
bias cannot accumulate in the server trajectory.  The per-client ``e``
state lives in the round protocol's ``ClientStore`` (DESIGN.md
§Transport): host-backed in the simulator/async engines, mesh-sharded
inside the pod engine's train state.

These compressors are the *codecs'* arithmetic: engines drive them through
``repro.federated.transport.Transport`` (uplink round trips, downlink
broadcast, measured-byte accounting for both directions; the old
``strategy.compress_delta`` hook survives as a deprecation shim).
``compress`` is jit/vmap-friendly: it returns the decompressed delta (what
the server reconstructs from the wire) plus the new EF state; the dense
codecs never materialise the wire format inside the round, while
``FedConfig.sparse_uplink`` swaps in the true (value, index)
representation (transport.SparseTopKCodec).  ``wire_nbytes`` is the
host-side accounting of that wire format — exact byte counts from leaf
shapes (works on ShapeDtypeStructs, so pod-scale archs need no allocation).
With ``fed.use_pallas`` the quantise-dequant round trips run as fused
single-pass VMEM kernels (kernels/compress.py); otherwise as the pure-jnp
oracles in kernels/ref.py — both parity-tested against each other.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tree as T
from repro.kernels import ref

KNOWN_COMPRESSORS = ("none", "identity", "topk", "qsgd")


class SparseLeaf(NamedTuple):
    """One leaf's sparse wire format: the k surviving (value, index) pairs.
    A NamedTuple, so it is a pytree — it vmaps over clients and crosses jit
    boundaries like any other array pair.  Lives here (not transport) so
    the aggregation layer can consume the wire without importing the
    codec machinery; transport re-exports it."""
    values: jax.Array     # (k,) — or (K, k) once stacked over clients
    indices: jax.Array    # same shape, int32 flat index into the leaf


def is_sparse_leaf(x) -> bool:
    return isinstance(x, SparseLeaf)


def is_sparse_tree(tree) -> bool:
    """True when the pytree's aggregation-level leaves are SparseLeaf wires
    (the sparse-native uplink); False for dense trees.  Mixed trees don't
    occur: SparseTopKCodec encodes every leaf."""
    return any(is_sparse_leaf(l)
               for l in jax.tree.leaves(tree, is_leaf=is_sparse_leaf))


def _leaf_elems(leaf) -> int:
    """Element count of an array OR a ShapeDtypeStruct."""
    return int(np.prod(leaf.shape)) if leaf.shape else 1


def _leaf_itembits(leaf) -> int:
    return jnp.dtype(leaf.dtype).itemsize * 8


def raw_nbytes(tree) -> int:
    """Uncompressed wire size of a pytree (arrays or ShapeDtypeStructs)."""
    return sum(_leaf_elems(l) * jnp.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(tree))


class Compressor:
    """compress() runs inside jit (per client, vmap-safe); wire_nbytes()
    runs on the host for byte accounting."""
    name = "base"
    lossy = True

    def compress(self, delta, ef, key):
        """(delta, ef pytrees, PRNG key) -> (decompressed q, new ef).
        q is what the server reconstructs from the wire; new ef is the
        exact residual (delta + ef) − q."""
        raise NotImplementedError

    def wire_nbytes(self, tree) -> int:
        raise NotImplementedError


class IdentityCompressor(Compressor):
    name = "identity"
    lossy = False

    def compress(self, delta, ef, key):
        # pure passthrough — no arithmetic, so engine trajectories are
        # bit-identical to compressor="none" (tested)
        return delta, ef

    def wire_nbytes(self, tree) -> int:
        return raw_nbytes(tree)


class TopKCompressor(Compressor):
    """Top-k magnitude sparsification, k per leaf, exact threshold via
    lax.top_k; the select itself is a streaming per-block threshold pass
    (kernels/compress.py) so only the (cheap) threshold scan depends on k."""
    name = "topk"

    def __init__(self, frac: float, use_pallas: bool = False):
        if not 0.0 < frac <= 1.0:
            raise ValueError(f"topk_frac must be in (0, 1]; got {frac}")
        self.frac = frac
        self.use_pallas = use_pallas

    def _k(self, n: int) -> int:
        return max(1, int(math.ceil(self.frac * n)))

    def compress(self, delta, ef, key):
        v = T.add(delta, ef)
        # flatten/unflatten rather than unzipping an is_leaf-on-tuples map:
        # the input pytree may contain tuple internal nodes a tuple
        # heuristic would mistake for (q, residual) pairs
        leaves, treedef = jax.tree.flatten(v)
        pairs = []
        for x in leaves:
            flat = jnp.abs(x.reshape(-1))
            thresh = jax.lax.top_k(flat, self._k(flat.size))[0][-1]
            if self.use_pallas:
                from repro.kernels import ops
                pairs.append(ops.topk_compress_leaf(x, thresh))
            else:
                pairs.append(ref.topk_threshold_select(x, thresh))
        return (jax.tree.unflatten(treedef, [p[0] for p in pairs]),
                jax.tree.unflatten(treedef, [p[1] for p in pairs]))

    def wire_nbytes(self, tree) -> int:
        bits = 0
        for l in jax.tree.leaves(tree):
            n = _leaf_elems(l)
            idx_bits = max(1, math.ceil(math.log2(n))) if n > 1 else 1
            bits += self._k(n) * (_leaf_itembits(l) + idx_bits) + 32
        return (bits + 7) // 8


class QSGDCompressor(Compressor):
    """QSGD-style stochastic uniform quantisation, per-leaf max scale."""
    name = "qsgd"

    def __init__(self, bits: int, use_pallas: bool = False):
        if bits < 1:
            raise ValueError(f"qsgd_bits must be >= 1; got {bits}")
        self.bits = bits
        self.levels = (1 << bits) - 1     # magnitude levels; sign is separate
        self.use_pallas = use_pallas

    def compress(self, delta, ef, key):
        v = T.add(delta, ef)
        leaves, treedef = jax.tree.flatten(v)
        keys = jax.random.split(key, len(leaves))
        pairs = []
        for x, k in zip(leaves, keys):
            u = jax.random.uniform(k, x.shape, dtype=x.dtype)
            scale = jnp.max(jnp.abs(x))
            if self.use_pallas:
                from repro.kernels import ops
                pairs.append(ops.qsgd_compress_leaf(x, u, scale, self.levels))
            else:
                pairs.append(ref.qsgd_quantize(x, u, scale, self.levels))
        return (jax.tree.unflatten(treedef, [p[0] for p in pairs]),
                jax.tree.unflatten(treedef, [p[1] for p in pairs]))

    def wire_nbytes(self, tree) -> int:
        bits = sum(_leaf_elems(l) * (self.bits + 1) + 32
                   for l in jax.tree.leaves(tree))
        return (bits + 7) // 8


@functools.lru_cache(maxsize=None)
def _get_compressor(name: str, topk_frac: float, qsgd_bits: int,
                    use_pallas: bool) -> Optional[Compressor]:
    if name == "none":
        return None
    if name == "identity":
        return IdentityCompressor()
    if name == "topk":
        return TopKCompressor(topk_frac, use_pallas)
    if name == "qsgd":
        return QSGDCompressor(qsgd_bits, use_pallas)
    raise ValueError(f"unknown compressor {name!r}; "
                     f"known: {', '.join(KNOWN_COMPRESSORS)}")


def get_compressor(fed) -> Optional[Compressor]:
    """FedConfig -> Compressor instance (None when compressor='none', i.e.
    the hook is bypassed and the round runs the pre-compression code path).
    Cached on the wire-relevant knobs only (not the whole config), so jit
    tracing reuses one instance per codec instead of one per config."""
    return _get_compressor(fed.compressor, fed.topk_frac, fed.qsgd_bits,
                           fed.use_pallas)


def uplink_nbytes(fed, params) -> int:
    """Measured bytes one client uploads per round under fed's compressor
    (raw delta bytes when compression is off)."""
    comp = get_compressor(fed)
    return raw_nbytes(params) if comp is None else comp.wire_nbytes(params)
