"""Sharded per-client pytree store (DESIGN.md §Transport).

One gather/scatter interface for every piece of per-client cross-round
state the engines carry: SCAFFOLD control variates ``c_i``, FedDyn drift
corrections ``h_i``, MOON previous models, error-feedback residuals
``e_i``, personalization heads.  Before this module each engine hand-wired
its own store (two parallel dicts in the simulator, none in the pod
engine — which is why lossy compression + EF was rejected there).

Two backends share the same semantics:

* ``ClientStore`` — host-backed, namespaced.  The simulator and the
  semi-async engine gather the round's picks into one stacked pytree
  (vmapped into the jit'd round), then scatter the updated states back.
  A state is lazily initialised on first gather; ``is None`` (not
  truthiness) decides whether a slot is empty, so falsy-but-present
  pytrees survive round trips (§Fixed semantics).

* the ``sharded_*`` functions — functional, jit-side.  The pod engine
  keeps the whole store as one stacked pytree (leading axis
  ``n_clients``) inside its train state; gather is a leading-axis index,
  scatter an ``.at[ids].set``.  The leading client axis is replicated
  and the parameter dims shard exactly like the parameter they mirror
  (``sharding.specs.param_shardings`` pads a leading ``None`` for
  stacked runs), so the store rides the pod mesh without new sharding
  rules.  This is what lifts the "lossy rejected for pod + EF"
  restriction: EF residuals now have a mesh-resident home.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Sequence

import jax
import jax.numpy as jnp


class ClientStore:
    """Host-backed per-client pytree store with named state collections.

    Namespaces keep independent per-client facts (strategy state, EF
    residual) separate while sharing one gather/scatter implementation —
    the "second store, same plumbing" pattern the simulator used to
    hand-roll twice.
    """

    def __init__(self):
        self._ns: Dict[str, Dict[int, Any]] = {}
        self._init: Dict[str, Callable[[], Any]] = {}
        self._template: Dict[str, Any] = {}

    def register(self, name: str, init_fn: Callable[[], Any]) -> None:
        """Declare a namespace; `init_fn()` builds one client's fresh state."""
        self._ns.setdefault(name, {})
        self._init[name] = init_fn
        self._template.pop(name, None)

    def namespaces(self):
        return tuple(self._ns)

    def states(self, name: str) -> Dict[int, Any]:
        """The live dict for a namespace (mutable view, keyed by client id)."""
        return self._ns[name]

    def gather(self, name: str, picks: Sequence[int]):
        """Stack the picks' states (fresh-initialising empty slots) into one
        pytree with leading axis len(picks), ready to vmap over."""
        store, init_fn = self._ns[name], self._init[name]
        states = []
        for c in picks:
            s = store.get(int(c))
            if s is None:
                # `is None`, not truthiness: a stored state whose pytree
                # happens to be falsy (e.g. a zero scalar) must not be
                # re-initialised.  The fresh template is built once per
                # namespace and reused — a steady-state gather performs no
                # new host->device transfer (transfer-guard clean).
                if name not in self._template:
                    self._template[name] = init_fn()
                s = self._template[name]
            states.append(s)
        return jax.tree.map(lambda *xs: jnp.stack(xs), *states)

    def scatter(self, name: str, picks: Sequence[int], stacked) -> None:
        """Write each pick's slice of the stacked pytree back to its slot."""
        store = self._ns[name]
        for j, c in enumerate(picks):
            # static slice: x[j] would gather with a device-side index
            # (an implicit H2D transfer per client under transfer guard)
            store[int(c)] = jax.tree.map(
                lambda x: jax.lax.index_in_dim(x, j, keepdims=False), stacked)


# ---------------------------------------------------------------------------
# functional (jit-side) store — the pod engine's mesh-sharded backend
# ---------------------------------------------------------------------------
def sharded_init(template, n_clients: int):
    """Stacked all-zeros store: every leaf gains a leading (n_clients,) axis.
    Lives inside the engine's train state so updates stay functional."""
    return jax.tree.map(
        lambda x: jnp.zeros((n_clients,) + x.shape, x.dtype), template)


def sharded_gather(store, ids):
    """store (N, ...) × ids (K,) int -> stacked (K, ...); jit/vmap-safe."""
    return jax.tree.map(lambda x: x[ids], store)


def sharded_scatter(store, ids, values):
    """Functional write-back: store' = store with rows `ids` <- values.
    Duplicate ids resolve to the last write (jnp scatter semantics)."""
    return jax.tree.map(lambda x, v: x.at[ids].set(v.astype(x.dtype)),
                        store, values)
