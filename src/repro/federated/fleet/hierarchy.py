"""Two-tier hierarchical aggregation (DESIGN.md §Fleet).

A real fleet never ships every client delta to one server: edge deltas
reduce at a regional aggregator and only the R regional partials travel to
the global tier.  This module maps that topology onto the repo's one
weighted reduction:

* **stage 1 (regional)** — the round's K deltas chunk into R contiguous
  regional cohorts (``region_slices``; the ``FleetScheduler`` emits its
  picks region-major against the same split, so cohort order and jit-side
  chunking agree by construction).  Each region runs the *existing* reduce
  over its slice: ``strategy.server_aggregate`` for dense deltas (the
  fused weighted-delta-reduce kernel under ``use_pallas``), PR 8's
  ``sparse_weighted_mean`` segment-sum for ``SparseLeaf`` wires — so
  regional partials cost K·k for sparse uplinks and only the R
  regional→global partials are dense.
* **stage 2 (global)** — ``weighted_mean`` over the stacked (R, ...)
  partials with weights W_r = Σ_{i∈r} w_i: fp32 accumulation, cast to the
  delta dtype on write.  By linearity Σ_r W_r·M_r / Σ_r W_r equals the
  flat Σ_i w_i·Δ_i / Σ_i w_i — exactly in real arithmetic, to fp
  reassociation tolerance in floats.

**Identity configuration (R=1): bitwise.**  Stage 1 is then the verbatim
flat call on the full slice; stage 2 normalises the single region weight
to W/W = 1.0 (exact for any finite normal W), multiplies the promoted-fp32
partial by exactly 1.0, and the dtype round-trip of an unchanged value is
exact — so the two-tier reduction at R=1 is bit-identical to flat on every
engine (pinned in tests/test_transport.py and the CI engine-parity
``Hierarchical`` axis).  FedADC's momentum recursion consumes only the
stage-2 global aggregate, never a regional partial.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.federated import aggregation as A


def region_sizes(total: int, n_regions: int) -> Tuple[int, ...]:
    """Contiguous chunk sizes for `total` items over `n_regions` regions:
    the first ``total % n_regions`` regions take the ceiling.  Shared by the
    scheduler (cohort sizes) and the aggregator (slice bounds) so the two
    sides cannot disagree about which delta belongs to which region."""
    if n_regions < 1:
        raise ValueError(f"n_regions must be >= 1, got {n_regions}")
    if total < n_regions:
        raise ValueError(f"{total} items cannot fill {n_regions} regions "
                         f"(every region needs at least one)")
    base, rem = divmod(total, n_regions)
    return tuple(base + 1 if r < rem else base for r in range(n_regions))


def region_slices(total: int, n_regions: int) -> Tuple[Tuple[int, int], ...]:
    """((start, size), ...) static slice bounds matching ``region_sizes``."""
    out, start = [], 0
    for size in region_sizes(total, n_regions):
        out.append((start, size))
        start += size
    return tuple(out)


def hierarchical_aggregate(deltas, weights, fed, strategy, like=None):
    """Δ̄ through the two-tier topology (see module docstring).  `deltas`
    is the stacked (K, ...) pytree — dense or SparseLeaf wire — and
    `weights` the (K,) aggregation weights; slice bounds are static, so the
    jit'd round traces once per (K, fleet_regions)."""
    n_regions = fed.fleet_regions
    sparse = A.is_sparse_tree(deltas)
    if sparse and like is None:
        raise ValueError("sparse-native hierarchical aggregation needs a "
                         "dense template (like=)")
    partials, region_w = [], []
    for start, size in region_slices(weights.shape[0], n_regions):
        d_r = jax.tree.map(
            lambda x: jax.lax.slice_in_dim(x, start, start + size), deltas)
        w_r = jax.lax.slice_in_dim(weights, start, start + size)
        if sparse:
            m_r = A.sparse_weighted_mean(d_r, w_r, like,
                                         use_pallas=fed.use_pallas)
        else:
            m_r = strategy.server_aggregate(d_r, w_r, fed)
        partials.append(m_r)
        region_w.append(jnp.sum(w_r))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *partials)
    return A.weighted_mean(stacked, jnp.stack(region_w),
                           use_pallas=fed.use_pallas)


def hierarchical_combine(partials, weights, fed, strategy):
    """Pod-engine form: the per-pod partial means arriving at the final
    combine ARE stage-1 units (each pod's client-serial scan is a regional
    reduce already); chunk the CP pod axis into ``fed.fleet_regions``
    regions and recombine — exact by the same linearity the flat pod
    recombination relies on, bitwise at R=1."""
    return hierarchical_aggregate(partials, weights, fed, strategy)


class HierarchicalAggregator:
    """The two-tier reduce bound to one (fed, strategy) pair — the object
    ``RoundProtocol`` routes ``aggregate`` through when
    ``fed.fleet_regions > 0``."""

    def __init__(self, fed, strategy):
        if fed.fleet_regions < 1:
            raise ValueError("HierarchicalAggregator needs fleet_regions "
                             f">= 1, got {fed.fleet_regions}")
        # fail at composition time, not at trace time inside the round:
        # every flush must fill every region (buffer_k is the async
        # engine's round size; 0 falls back to clients_per_round)
        round_k = fed.buffer_k if fed.buffer_k > 0 else fed.clients_per_round
        if fed.fleet_regions > round_k:
            raise ValueError(
                f"fleet_regions={fed.fleet_regions} exceeds the round's "
                f"{round_k} deltas; every region needs at least one client")
        self.fed = fed
        self.strategy = strategy
        self.n_regions = fed.fleet_regions

    def __call__(self, deltas, weights, like=None):
        return hierarchical_aggregate(deltas, weights, self.fed,
                                      self.strategy, like=like)
