"""Memory-bounded paged backend for the per-client store (DESIGN.md §Fleet).

Per-client EF residuals / strategy state for 10^5–10^6 clients cannot live
resident on the host: one bf16 EF residual of a 1e8-parameter model is
200 MB, so a fleet-scale store must page.  ``PagedClientStore`` duck-types
``ClientStore`` (register / gather / scatter / states / namespaces) behind
a two-tier page table:

* **resident tier** — one page per (namespace, client id): a host-numpy
  pytree in an ``OrderedDict`` in LRU order, with a hard
  ``budget_bytes`` ceiling on the summed ``.nbytes``.  Admitting a page
  past the budget evicts from the LRU end until the budget holds again —
  the page table itself is the bound (no auxiliary bookkeeping grows with
  fleet size beyond the spill map, which holds compressed blobs only).
* **spill tier** — evicted pages are serialised per-leaf: raw bits
  (``checkpointing.storage_view`` — the same uint bit-view that makes
  bf16/fp8 checkpoints round-trip) through ``zlib``, kept in memory or,
  with ``spill_dir``, written to one file per page.  Loading a spilled
  page decompresses, re-views the target dtype, and re-admits — the
  round-trip is bitwise (pinned in tests/test_fleet.py for fp32/bf16/fp8
  leaves).

Gather stacks the picks on host and performs **one** explicit
``jnp.asarray`` transfer; scatter performs **one** explicit
``jax.device_get`` of the stacked round output — both are the sanctioned
wire points under the steady-state transfer guard, and the values are
bit-identical to the device-resident host backend (tested).

Telemetry gauges/counters ride the shared ``Counters`` registry:
``store.resident_pages`` / ``store.resident_bytes`` /
``store.spilled_pages`` (gauges), ``store.spills`` / ``store.loads``
(monotonic counts).
"""
from __future__ import annotations

import os
import zlib
from collections import OrderedDict
from collections.abc import MutableMapping
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpointing.checkpoint import (from_storage_view, storage_dtype,
                                            storage_view)

PageKey = Tuple[str, int]


def page_nbytes(page) -> int:
    """Resident cost of one page: the summed leaf ``.nbytes``."""
    return sum(int(leaf.nbytes) for leaf in jax.tree.leaves(page))


class _NamespaceView(MutableMapping):
    """Dict-like view of one namespace keyed by client id — the
    ``ClientStore.states`` surface, read/write-through the page table (a
    read may fault a spilled page in; a write admits and may evict)."""

    def __init__(self, store: "PagedClientStore", name: str):
        self._store = store
        self._name = name

    def __getitem__(self, cid: int):
        page = self._store._load(self._name, int(cid))
        if page is None:
            raise KeyError(cid)
        return page

    def __setitem__(self, cid: int, value) -> None:
        self._store._put(self._name, int(cid), value)

    def __delitem__(self, cid: int) -> None:
        self._store._drop(self._name, int(cid))

    def __iter__(self):
        return iter(self._store._client_ids(self._name))

    def __len__(self) -> int:
        return len(self._store._client_ids(self._name))

    def __contains__(self, cid) -> bool:
        return int(cid) in self._store._client_ids(self._name)


class PagedClientStore:
    """Host page table with LRU spill under a hard resident-bytes budget.

    Drop-in for ``ClientStore`` wherever the engines compose one (the
    ``store=`` argument of ``RoundProtocol`` / the simulators); gather and
    scatter return/accept the same stacked device pytrees with the same
    lazy-init / ``is None`` semantics.
    """

    def __init__(self, budget_bytes: int, counters=None,
                 spill_dir: Optional[str] = None, compress_level: int = 1):
        if budget_bytes <= 0:
            raise ValueError(f"budget_bytes must be > 0, got {budget_bytes}")
        self.budget_bytes = int(budget_bytes)
        self.counters = counters
        self.spill_dir = spill_dir
        if spill_dir is not None:
            os.makedirs(spill_dir, exist_ok=True)
        self.compress_level = compress_level
        self._init: Dict[str, Callable[[], Any]] = {}
        self._template: Dict[str, Any] = {}
        self._specs: Dict[str, Any] = {}  # ns -> (treedef, [(shape, dtype)])
        # the page table IS the bound: resident pages evict to the spill
        # map once their bytes pass the budget, and spill entries are
        # popped on load — neither mapping outgrows (touched clients).
        self._resident: "OrderedDict[PageKey, Any]" = OrderedDict()
        self._spilled: Dict[PageKey, Any] = {}
        self._resident_bytes = 0
        self._peak_resident_bytes = 0

    # --- ClientStore interface -------------------------------------------
    def register(self, name: str, init_fn: Callable[[], Any]) -> None:
        self._init[name] = init_fn
        self._template.pop(name, None)
        self._specs.pop(name, None)

    def namespaces(self):
        return tuple(self._init)

    def states(self, name: str) -> _NamespaceView:
        if name not in self._init:
            raise KeyError(name)
        return _NamespaceView(self, name)

    def gather(self, name: str, picks: Sequence[int]):
        """Stack the picks' pages (fresh template for empty slots) and push
        them through ONE explicit host->device transfer."""
        tmpl = self._ns_template(name)
        pages = []
        for c in picks:
            page = self._load(name, int(c))
            pages.append(tmpl if page is None else page)
        return jax.tree.map(lambda *xs: jnp.asarray(np.stack(xs)), *pages)

    def scatter(self, name: str, picks: Sequence[int], stacked) -> None:
        """One explicit device->host fetch of the stacked pytree, then one
        page admit per pick (evicting LRU pages past the budget)."""
        host = jax.device_get(stacked)
        for j, c in enumerate(picks):
            # .copy() so the page owns its bytes — a bare x[j] view keeps
            # the whole stacked round buffer alive behind every page
            page = jax.tree.map(lambda x: np.asarray(x[j]).copy(), host)
            self._admit((name, int(c)), page)

    # --- gauges -----------------------------------------------------------
    @property
    def resident_bytes(self) -> int:
        return self._resident_bytes

    @property
    def peak_resident_bytes(self) -> int:
        """High-water mark of resident bytes, including the admit transient
        (a fresh page is admitted before the LRU evictions that pay for
        it), so it is the honest peak the budget gate measures."""
        return self._peak_resident_bytes

    @property
    def resident_pages(self) -> int:
        return len(self._resident)

    @property
    def spilled_pages(self) -> int:
        return len(self._spilled)

    # --- page table -------------------------------------------------------
    def _ns_template(self, name: str):
        if name not in self._template:
            # one host copy of the fresh state; np.asarray on a device
            # array is an explicit fetch, and the template is reused for
            # every subsequent empty-slot gather
            tmpl = jax.tree.map(np.asarray, self._init[name]())
            self._template[name] = tmpl
            leaves, treedef = jax.tree.flatten(tmpl)
            self._specs[name] = (treedef,
                                 [(leaf.shape, leaf.dtype) for leaf in leaves])
        return self._template[name]

    def _client_ids(self, name: str):
        ids = {cid for ns, cid in self._resident if ns == name}
        ids.update(cid for ns, cid in self._spilled if ns == name)
        return sorted(ids)

    def _load(self, name: str, cid: int):
        """The page for (name, cid), faulting it in from the spill tier;
        None when the client has no state yet (lazy-init contract)."""
        key = (name, cid)
        page = self._resident.get(key)
        if page is not None:
            self._resident.move_to_end(key)
            return page
        blob = self._spilled.pop(key, None)
        if blob is None:
            return None
        page = self._decode(name, blob)
        self._count("store.loads")
        self._admit(key, page)
        return page

    def _put(self, name: str, cid: int, value) -> None:
        host = jax.device_get(value)
        page = jax.tree.map(lambda x: np.asarray(x).copy(), host)
        self._admit((name, cid), page)

    def _drop(self, name: str, cid: int) -> None:
        key = (name, cid)
        page = self._resident.pop(key, None)
        if page is not None:
            self._resident_bytes -= page_nbytes(page)
        blob = self._spilled.pop(key, None)
        if page is None and blob is None:
            raise KeyError(cid)
        if isinstance(blob, str) and os.path.exists(blob):
            os.remove(blob)
        self._publish()

    def _admit(self, key: PageKey, page) -> None:
        """Insert/refresh a resident page, evicting LRU pages FIRST until
        the new page fits — resident bytes therefore never exceed the
        budget (provided one page fits it), which is what the fleet-bench
        budget gate asserts.  A write supersedes any spilled copy
        (scatter-to-evicted-page keeps exactly one live version)."""
        old_blob = self._spilled.pop(key, None)
        if isinstance(old_blob, str) and os.path.exists(old_blob):
            os.remove(old_blob)
        old = self._resident.pop(key, None)
        if old is not None:
            self._resident_bytes -= page_nbytes(old)
        need = page_nbytes(page)
        while self._resident and self._resident_bytes + need > self.budget_bytes:
            self._evict_lru()
        self._resident[key] = page
        self._resident_bytes += need
        if self._resident_bytes > self._peak_resident_bytes:
            self._peak_resident_bytes = self._resident_bytes
        self._publish()

    def _evict_lru(self) -> None:
        key, page = self._resident.popitem(last=False)
        self._resident_bytes -= page_nbytes(page)
        self._spilled[key] = self._encode(key, page)
        self._count("store.spills")

    # --- spill serialisation ----------------------------------------------
    def _encode(self, key: PageKey, page):
        """Per-leaf raw bits (storage_view handles bf16/fp8) through zlib;
        returns the blob tuple, or the spill file path when on-disk."""
        blobs = tuple(
            zlib.compress(storage_view(np.ascontiguousarray(leaf)).tobytes(),
                          self.compress_level)
            for leaf in jax.tree.leaves(page))
        if self.spill_dir is None:
            return blobs
        path = os.path.join(self.spill_dir, f"{key[0]}_{key[1]}.page")
        with open(path, "wb") as f:
            for b in blobs:
                f.write(len(b).to_bytes(8, "little"))
                f.write(b)
        return path

    def _decode(self, name: str, blob):
        self._ns_template(name)
        treedef, specs = self._specs[name]
        if isinstance(blob, str):
            blobs = []
            with open(blob, "rb") as f:
                for _ in specs:
                    n = int.from_bytes(f.read(8), "little")
                    blobs.append(f.read(n))
            os.remove(blob)
        else:
            blobs = blob
        leaves = []
        for b, (shape, dtype) in zip(blobs, specs):
            raw = np.frombuffer(zlib.decompress(b), dtype=storage_dtype(dtype))
            leaves.append(
                from_storage_view(raw, dtype).reshape(shape).copy())
        return jax.tree.unflatten(treedef, leaves)

    # --- telemetry ----------------------------------------------------------
    def _publish(self) -> None:
        if self.counters is None:
            return
        self.counters.set("store.resident_pages", len(self._resident))
        self.counters.set("store.resident_bytes", self._resident_bytes)
        self.counters.set("store.spilled_pages", len(self._spilled))

    def _count(self, name: str) -> None:
        if self.counters is not None:
            self.counters.inc(name)
