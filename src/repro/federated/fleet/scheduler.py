"""Region-aware cohort sampling — client selection as a first-class
scheduler (DESIGN.md §Fleet).

``selection.py`` draws a flat pick from the whole fleet; a hierarchical
topology needs the round's cohort shaped to its regions and weighted by
the fleet's system model.  ``FleetScheduler``:

* assigns the N clients to R contiguous regions with the same
  ``region_sizes`` split the ``HierarchicalAggregator`` slices by, and
  emits its picks **region-major** — so the k-th delta of a scheduler
  cohort lands in the aggregator region that owns client k by
  construction, with no id plumbing between the two;
* samples each region's sub-cohort with availability/speed weights from
  the ``hetero`` system model (faster clients respond to a dispatch more
  often; an availability draw thins the candidate set per round), or
  delegates to ``selection.py``'s data-aware ``class_coverage`` selector
  on the region's sub-population;
* is deterministic under its seed: all draws come from one private
  ``RandomState`` in call order, independent of the engines' RNG streams
  (same seed + same call sequence ⇒ same cohorts, pinned in tests);
* feeds every engine: ``sample_cohort()`` gives the sync round its picks,
  ``sample(n)`` gives the async engine region-agnostic weighted dispatch
  waves, and ``Cohort.pod_client_ids`` shapes a cohort into the pod
  engine's ``batch["client_ids"]`` (CP, CS) grid.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.core.selection import class_coverage_selection
from repro.federated.fleet.hierarchy import region_sizes, region_slices
from repro.federated.hetero import sample_speeds

KNOWN_SELECTORS = ("random", "class_coverage")


@dataclass(frozen=True)
class Cohort:
    """One round's picks in region-major order: ``clients[offset_r :
    offset_r + sizes[r]]`` is region r's sub-cohort."""
    clients: np.ndarray
    sizes: Tuple[int, ...]

    def region_slices(self) -> Tuple[Tuple[int, int], ...]:
        out, start = [], 0
        for size in self.sizes:
            out.append((start, size))
            start += size
        return tuple(out)

    def pod_client_ids(self, cp: int, cs: int) -> np.ndarray:
        """The cohort as the pod engine's (CP, CS) int32 client-id grid
        (client-serial within a pod, pod-parallel across)."""
        if cp * cs != len(self.clients):
            raise ValueError(f"cohort of {len(self.clients)} clients does "
                             f"not fill a ({cp}, {cs}) pod grid")
        return np.asarray(self.clients, np.int32).reshape(cp, cs)


class FleetScheduler:
    """Deterministic region-aware cohort sampler over the fleet."""

    def __init__(self, fed, hetero=None, *, n_regions: Optional[int] = None,
                 selector: str = "random", counts=None, seed: int = 0,
                 system=None):
        if selector not in KNOWN_SELECTORS:
            raise ValueError(f"unknown selector {selector!r}; "
                             f"known: {', '.join(KNOWN_SELECTORS)}")
        if selector == "class_coverage" and counts is None:
            raise ValueError("selector='class_coverage' needs per-client "
                             "class counts (counts=)")
        self.fed = fed
        self.n_clients = fed.n_clients
        regions = n_regions if n_regions is not None \
            else max(fed.fleet_regions, 1)
        if not 1 <= regions <= self.n_clients:
            raise ValueError(f"n_regions={regions} outside "
                             f"[1, {self.n_clients}]")
        self.n_regions = regions
        self.selector = selector
        self.counts = None if counts is None else np.asarray(counts)
        self.rng = np.random.RandomState(seed)
        # contiguous region blocks — the aggregator's exact split
        self.bounds = region_slices(self.n_clients, regions)
        self._starts = [start for start, _ in self.bounds]
        # availability/speed sampling weights from the system model; the
        # speeds are re-derived from hetero's own seed when no live
        # ClientSystemModel is handed in, so both views of the fleet agree
        if system is not None:
            speeds = np.asarray(system.speeds, np.float64)
            het = system.hetero
        else:
            het = hetero
            if hetero is not None:
                speeds = sample_speeds(hetero, self.n_clients,
                                       np.random.RandomState(hetero.seed))
            else:
                speeds = np.ones(self.n_clients, np.float64)
        self.speeds = speeds
        self.availability = float(het.availability) \
            if het is not None and het.enabled else 1.0

    # ------------------------------------------------------------------
    def region_of(self, client: int) -> int:
        return bisect.bisect_right(self._starts, int(client)) - 1

    def region_clients(self, r: int) -> np.ndarray:
        start, size = self.bounds[r]
        return np.arange(start, start + size)

    def sample_cohort(self, k: Optional[int] = None) -> Cohort:
        """One region-major cohort of k clients (default
        ``fed.clients_per_round``), split over regions by the shared
        ``region_sizes`` rule."""
        k = self.fed.clients_per_round if k is None else int(k)
        sizes = region_sizes(k, self.n_regions)
        picks = [self._sample_region(r, k_r) for r, k_r in enumerate(sizes)]
        return Cohort(np.concatenate(picks), sizes)

    def sample(self, n: int) -> np.ndarray:
        """Region-agnostic weighted draw of n clients — the async engine's
        dispatch waves (a redispatch of 1 has no meaningful region split)."""
        return self._weighted_pick(np.arange(self.n_clients), n)

    # ------------------------------------------------------------------
    def _sample_region(self, r: int, k_r: int) -> np.ndarray:
        clients = self.region_clients(r)
        if k_r > len(clients):
            raise ValueError(f"region {r} holds {len(clients)} clients; "
                             f"cannot sample {k_r}")
        if self.selector == "class_coverage":
            local = class_coverage_selection(self.rng, len(clients), k_r,
                                             self.counts[clients])
            return clients[np.asarray(local)]
        return self._weighted_pick(clients, k_r)

    def _weighted_pick(self, clients: np.ndarray, k: int) -> np.ndarray:
        """k clients without replacement, ∝ speed over this round's
        available subset (availability thinning is skipped when it would
        leave fewer than k candidates — a dispatch never under-fills)."""
        w = np.asarray(self.speeds[clients], np.float64).copy()
        if self.availability < 1.0:
            up = self.rng.rand(len(clients)) < self.availability
            if int(up.sum()) >= k:
                w = np.where(up, w, 0.0)
        return self.rng.choice(clients, size=k, replace=False, p=w / w.sum())
