"""Fleet-scale substrate: two-tier aggregation topology, memory-bounded
paged client store, and region-aware cohort scheduling (DESIGN.md §Fleet).

* ``hierarchy``   — ``HierarchicalAggregator`` + ``region_sizes``: the
                    regional/global two-tier reduce ``RoundProtocol``
                    routes through when ``fed.fleet_regions > 0``
                    (bitwise == flat at R=1).
* ``paged_store`` — ``PagedClientStore``: LRU page table with a hard
                    resident-bytes budget and a compressed spill tier,
                    duck-typing ``ClientStore``.
* ``scheduler``   — ``FleetScheduler``: deterministic region-major cohort
                    sampling with availability/speed weights.
"""
from repro.federated.fleet.hierarchy import (HierarchicalAggregator,
                                             hierarchical_aggregate,
                                             hierarchical_combine,
                                             region_sizes, region_slices)
from repro.federated.fleet.paged_store import PagedClientStore, page_nbytes
from repro.federated.fleet.scheduler import Cohort, FleetScheduler

__all__ = ["HierarchicalAggregator", "hierarchical_aggregate",
           "hierarchical_combine", "region_sizes", "region_slices",
           "PagedClientStore", "page_nbytes", "Cohort", "FleetScheduler"]
