"""Federated engines and the heterogeneity subsystem.

* ``simulator``    — paper-scale synchronous round loop (CNN/ResNet).
* ``async_engine`` — virtual-clock semi-async engine with staleness-corrected
                     FedADC (buffered-K aggregation).
* ``hetero``       — client system model: speeds, availability, variable H_i.
* ``aggregation``  — pluggable server aggregators (uniform/examples/DRAG).

See DESIGN.md §Engines and §Heterogeneity.
"""
from repro.federated.aggregation import compute_weights, weighted_mean
from repro.federated.async_engine import AsyncFederatedSimulator
from repro.federated.hetero import (ClientSystemModel, fednova_scale,
                                    staleness_discount)
from repro.federated.simulator import FederatedSimulator, SimConfig

__all__ = ["FederatedSimulator", "SimConfig", "AsyncFederatedSimulator",
           "ClientSystemModel", "fednova_scale", "staleness_discount",
           "compute_weights", "weighted_mean"]
