"""Federated engines and the unified round protocol.

* ``protocol``     — ``RoundProtocol``: strategy + aggregator + transport +
                     store composed once; every engine drives it.
* ``transport``    — ``Transport``: bidirectional wire layer (downlink
                     broadcast + uplink delta codecs, measured-byte
                     accounting for both directions, sparse top-k path).
* ``store``        — ``ClientStore``: per-client pytree store (host-backed
                     for the simulator/async engines, functional
                     ``sharded_*`` backend for the pod engine).
* ``simulator``    — paper-scale synchronous round loop (CNN/ResNet).
* ``async_engine`` — virtual-clock semi-async engine with staleness-corrected
                     FedADC (buffered-K aggregation).
* ``hetero``       — client system model: speeds, availability, variable H_i.
* ``aggregation``  — pluggable server aggregators (uniform/examples/DRAG).
* ``compression``  — delta compressors (identity/top-k/QSGD) the transport
                     codecs wrap, with per-client error feedback.
* ``fleet``        — fleet-scale substrate: two-tier hierarchical
                     aggregation, memory-bounded paged client store, and
                     region-aware cohort scheduling.

See DESIGN.md §Engines, §Heterogeneity, §Compression, §Transport, and
§Fleet.
"""
from repro.federated.aggregation import compute_weights, weighted_mean
from repro.federated.async_engine import AsyncFederatedSimulator
from repro.federated.compression import (get_compressor, raw_nbytes,
                                         uplink_nbytes)
from repro.federated.hetero import (ClientSystemModel, fednova_scale,
                                    staleness_discount)
from repro.federated.fleet import (FleetScheduler, HierarchicalAggregator,
                                   PagedClientStore)
from repro.federated.protocol import RoundProtocol
from repro.federated.simulator import FederatedSimulator, SimConfig
from repro.federated.store import ClientStore
from repro.federated.transport import SparseLeaf, Transport, downlink_nbytes

__all__ = ["FederatedSimulator", "SimConfig", "AsyncFederatedSimulator",
           "ClientSystemModel", "fednova_scale", "staleness_discount",
           "compute_weights", "weighted_mean", "get_compressor",
           "raw_nbytes", "uplink_nbytes", "downlink_nbytes",
           "RoundProtocol", "Transport", "ClientStore", "SparseLeaf",
           "FleetScheduler", "HierarchicalAggregator", "PagedClientStore"]
