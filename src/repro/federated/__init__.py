"""Federated engines and the heterogeneity subsystem.

* ``simulator``    — paper-scale synchronous round loop (CNN/ResNet).
* ``async_engine`` — virtual-clock semi-async engine with staleness-corrected
                     FedADC (buffered-K aggregation).
* ``hetero``       — client system model: speeds, availability, variable H_i.
* ``aggregation``  — pluggable server aggregators (uniform/examples/DRAG).
* ``compression``  — uplink delta compressors (identity/top-k/QSGD) with
                     per-client error feedback.

See DESIGN.md §Engines, §Heterogeneity, and §Compression.
"""
from repro.federated.aggregation import compute_weights, weighted_mean
from repro.federated.async_engine import AsyncFederatedSimulator
from repro.federated.compression import (get_compressor, raw_nbytes,
                                         uplink_nbytes)
from repro.federated.hetero import (ClientSystemModel, fednova_scale,
                                    staleness_discount)
from repro.federated.simulator import FederatedSimulator, SimConfig

__all__ = ["FederatedSimulator", "SimConfig", "AsyncFederatedSimulator",
           "ClientSystemModel", "fednova_scale", "staleness_discount",
           "compute_weights", "weighted_mean", "get_compressor",
           "raw_nbytes", "uplink_nbytes"]
