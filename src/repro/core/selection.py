"""Client selection (Sec. IV-E further discussion).

* ``random``          — uniform sampling of cN clients (FedAvg default).
* ``class_coverage``  — data-aware selection: random subsets rejected until
  the union of the selected clients' data covers every class, mitigating the
  momentum bias the paper describes for small participation ratios
  (reported +2.1% final accuracy on CIFAR-10 s=2, C=0.1).
"""
from __future__ import annotations

import numpy as np


def random_selection(rng: np.random.RandomState, n_clients: int,
                     n_pick: int) -> np.ndarray:
    return rng.choice(n_clients, size=n_pick, replace=False)


def class_coverage_selection(rng: np.random.RandomState, n_clients: int,
                             n_pick: int, counts: np.ndarray,
                             max_tries: int = 200) -> np.ndarray:
    """counts (n_clients, n_classes).  Rejection-sample until every class is
    present in the union; greedy-repair on failure."""
    n_classes = counts.shape[1]
    best, best_cov = None, -1
    for _ in range(max_tries):
        pick = rng.choice(n_clients, size=n_pick, replace=False)
        cov = int((counts[pick].sum(0) > 0).sum())
        if cov == n_classes:
            return pick
        if cov > best_cov:
            best, best_cov = pick, cov
    # greedy repair: swap in clients that add missing classes
    pick = list(best)
    missing = set(np.where(counts[pick].sum(0) == 0)[0])
    outside = [c for c in range(n_clients) if c not in pick]
    rng.shuffle(outside)
    for cand in outside:
        if not missing:
            break
        gain = missing & set(np.where(counts[cand] > 0)[0])
        if gain:
            # replace the member whose removal loses no class
            for j, m in enumerate(pick):
                rest = pick[:j] + pick[j + 1:] + [cand]
                if (counts[rest].sum(0) > 0).sum() >= best_cov:
                    pick = rest
                    missing -= gain
                    break
    return np.array(pick[:n_pick])


SELECTORS = {"random": random_selection,
             "class_coverage": class_coverage_selection}
