"""Client selection (Sec. IV-E further discussion).

* ``random``          — uniform sampling of cN clients (FedAvg default).
* ``class_coverage``  — data-aware selection: rejection-sample random
  subsets for a bounded number of tries, then finish the best draw with a
  strict-improvement single-swap hill climb until the union of the selected
  clients' data covers every class (or no swap helps), mitigating the
  momentum bias the paper describes for small participation ratios
  (reported +2.1% final accuracy on CIFAR-10 s=2, C=0.1).

Both selectors are pure functions of (rng state, arguments): the same
RandomState seed and the same counts produce the same picks (pinned in
tests), which is what lets the region-aware ``FleetScheduler``
(repro.federated.fleet) delegate per-region selection here and stay
deterministic under its own seed.
"""
from __future__ import annotations

import numpy as np


def random_selection(rng: np.random.RandomState, n_clients: int,
                     n_pick: int) -> np.ndarray:
    return rng.choice(n_clients, size=n_pick, replace=False)


def class_coverage_selection(rng: np.random.RandomState, n_clients: int,
                             n_pick: int, counts: np.ndarray,
                             max_tries: int = 200) -> np.ndarray:
    """counts (n_clients, n_classes).  Rejection-sample up to `max_tries`
    draws for a pick whose union covers every class; if none does, finish
    the best-coverage draw with a strict-improvement single-swap hill climb
    (PR 2): only swaps that strictly raise coverage — recomputed from the
    candidate pick, never stale bookkeeping — are applied, so the loop
    terminates at full coverage or a single-swap local optimum."""
    n_classes = counts.shape[1]
    best, best_cov = None, -1
    for _ in range(max_tries):
        pick = rng.choice(n_clients, size=n_pick, replace=False)
        cov = int((counts[pick].sum(0) > 0).sum())
        if cov == n_classes:
            return pick
        if cov > best_cov:
            best, best_cov = pick, cov
    # greedy repair: hill-climb on single swaps, recomputing coverage from
    # the CANDIDATE pick each iteration (a swap may drop the removed
    # member's classes, so stale `missing` bookkeeping over-claims).  Only
    # strictly-improving swaps are applied, so the loop terminates with a
    # pick that is single-swap locally optimal.
    pick = list(best)
    outside = [c for c in range(n_clients) if c not in set(pick)]
    rng.shuffle(outside)
    improved = True
    while improved:
        cur_cov = int((counts[pick].sum(0) > 0).sum())
        if cur_cov == n_classes:
            break
        improved = False
        for ci, cand in enumerate(outside):
            best_j, best_c = None, cur_cov
            for j in range(len(pick)):
                rest = pick[:j] + pick[j + 1:] + [cand]
                cov = int((counts[rest].sum(0) > 0).sum())
                if cov > best_c:
                    best_j, best_c = j, cov
            if best_j is not None:
                outside[ci] = pick[best_j]
                pick = pick[:best_j] + pick[best_j + 1:] + [cand]
                improved = True
                break
    return np.array(pick)


SELECTORS = {"random": random_selection,
             "class_coverage": class_coverage_selection}
