"""FL strategy algebra — the paper's contribution (FedADC, Alg. 3/4) plus
every baseline it compares against, expressed over opaque parameter pytrees
so the same code drives both the paper-scale simulator (CNN/ResNet on
CIFAR-like data) and the pod-scale engine (the 10 assigned architectures).

Interface (all pure functions, jit/scan friendly):
  server_init(params)              -> server_state dict
  client_setup(server_state, fed)  -> ctx broadcast to clients (e.g. m̄_t)
  local_step(theta, ctx, grad_fn, batch, fed, extra) -> (theta', extra')
       `extra` carries per-local-step state (double-momentum EMA, step idx).
  server_aggregate(deltas, weights, fed) -> mean_delta
       deltas stacked over clients (leading axis K); weights (K,) from the
       pluggable aggregator (repro.federated.aggregation) — uniform,
       example-weighted, or DRAG divergence-adaptive.
  server_update(server_state, theta_t, mean_delta, fed)
       -> (theta_{t+1}, server_state')
  mean_delta is Σ_i w_i (θ_t - θ_i^H) / Σ_i w_i  (the *pseudo gradient × η*;
  the paper's 1/|S| mean under uniform weights).

Strategies whose clients carry cross-round state (SCAFFOLD c_i, FedDyn h_i,
MOON previous model) additionally implement client_state_* hooks used by the
simulator; the pod engine restricts itself to stateless-client strategies
(see DESIGN.md §Engines).

The wire (uplink compression, downlink broadcast codecs, byte accounting)
is NOT a strategy concern: engines compose a strategy with a Transport and
a ClientStore through repro.federated.protocol.RoundProtocol (DESIGN.md
§Transport).  The old ``compress_delta`` hook remains as a deprecation
shim only.
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core import tree as T

# hooks that have already fired their deprecation warning this process —
# keyed by hook name so a shim warns once, not once per call site or (worse)
# once per jit re-trace of the round function
_DEPRECATION_WARNED: set = set()


def _warn_deprecated(hook: str, replacement: str) -> None:
    if hook in _DEPRECATION_WARNED:
        return
    _DEPRECATION_WARNED.add(hook)
    warnings.warn(f"{hook} is deprecated; use {replacement} "
                  f"(DESIGN.md §Transport migration table)",
                  DeprecationWarning, stacklevel=3)


def _maybe_clip(g, fed: FedConfig):
    if fed.grad_clip > 0:
        g = T.clip_by_global_norm(g, fed.grad_clip)
    return g


def _wd(theta, g, fed: FedConfig):
    if fed.weight_decay > 0:
        g = T.axpy(fed.weight_decay, theta, g)
    return g


def _sgd_step(theta, g, eta, fed):
    g = _wd(theta, _maybe_clip(g, fed), fed)
    if fed.use_pallas:
        from repro.kernels import ops
        return jax.tree.map(lambda t, gi: ops.fused_axpy(t, gi, -eta), theta, g)
    return jax.tree.map(lambda t, gi: t - eta * gi, theta, g)


# ---------------------------------------------------------------------------
# FedAvg (Alg. 1)
# ---------------------------------------------------------------------------
class FedAvg:
    name = "fedavg"
    stateless_clients = True

    def server_init(self, params):
        return {}

    def client_setup(self, server_state, params, fed):
        return {}

    def init_extra(self, params, fed):
        return None

    def local_step(self, theta, ctx, grad_fn, batch, fed, extra):
        g, aux = grad_fn(theta, batch)
        return _sgd_step(theta, g, fed.eta, fed), extra, aux

    def compress_delta(self, delta, ef, key, fed):
        """DEPRECATED shim — the uplink hook moved off the strategy and into
        the wire layer: use ``repro.federated.transport.Transport.uplink``
        (engines drive it through ``RoundProtocol.uplink``).  Kept for one
        release so external callers migrate gracefully; warns once per
        process, then delegates to a cached stateless Transport with the
        exact pre-redesign semantics."""
        _warn_deprecated("strategy.compress_delta",
                         "RoundProtocol.uplink / Transport.uplink")
        from repro.federated.transport import shim_transport  # lazy: layering
        return shim_transport(fed).uplink(delta, ef, key)

    def server_aggregate(self, deltas, weights, fed):
        """Δ̄ = Σ_i w_i·Δ_i / Σ_i w_i over client-stacked deltas.  Shared by
        every strategy; with fed.use_pallas the reduction runs as one fused
        VMEM pass (kernels/weighted_reduce.py)."""
        from repro.federated.aggregation import weighted_mean  # lazy: layering
        return weighted_mean(deltas, weights, use_pallas=fed.use_pallas)

    def server_update(self, server_state, theta_t, mean_delta, fed):
        # θ_{t+1} = mean(θ_i^H) = θ_t - mean_delta
        return T.sub(theta_t, mean_delta), server_state


def _theta_step(theta_t, m, fed):
    """θ_{t+1} = θ_t − α·η·m, computed in fp32 and cast back to the
    parameter dtype (the fp32 momentum must not promote bf16 parameters)."""
    theta = T.axpy(-fed.alpha * fed.eta, m, T.cast(theta_t, jnp.float32))
    return jax.tree.map(lambda nt, t: nt.astype(t.dtype), theta, theta_t)


# ---------------------------------------------------------------------------
# SlowMo (Alg. 2) — server momentum over pseudo gradients.
# ---------------------------------------------------------------------------
class SlowMo(FedAvg):
    name = "slowmo"

    def server_init(self, params):
        # the momentum accumulates Δ̄ across rounds: it is held in fp32
        # regardless of the parameter/wire dtype (a bf16 m loses small
        # late-round pseudo-gradients — the fp32 cast-on-write contract,
        # server side; checked by the trace-accumulation-dtype audit)
        return {"m": T.cast(T.zeros_like(params), jnp.float32)}

    def server_update(self, server_state, theta_t, mean_delta, fed):
        g_bar = T.scale(T.cast(mean_delta, jnp.float32),
                        1.0 / fed.eta)                      # line 12
        m = T.axpy(fed.beta_global, server_state["m"], g_bar)  # line 14
        theta = _theta_step(theta_t, m, fed)                # line 16
        return theta, {"m": m}


# ---------------------------------------------------------------------------
# FedADC (Alg. 3) — THE PAPER'S CONTRIBUTION.
# The global momentum m_t is normalised (m̄_t = β_local · m_t / H) and
# embedded into every local iteration; the server applies the small
# correction (β_global − β_local)·m_t when rebuilding the pseudo momentum.
# ---------------------------------------------------------------------------
class FedADC(FedAvg):
    name = "fedadc"

    def server_init(self, params):
        # fp32 momentum independent of the parameter/wire dtype — see
        # SlowMo.server_init
        return {"m": T.cast(T.zeros_like(params), jnp.float32)}

    def client_setup(self, server_state, params, fed):
        # line 5: m̄_t = β_local · m_t / H, broadcast in the params dtype
        # (the fp32 momentum must not promote a bf16 wire)
        m_bar = T.scale(server_state["m"],
                        fed.beta_local / fed.local_steps)
        return {"m_bar": jax.tree.map(lambda m, p: m.astype(p.dtype),
                                      m_bar, params)}

    # ctx broadcast leaves are an exact scalar image of the θ-delta
    # (server_update: Δθ_t = −α·η·m_t while m̄_t = β_l/H · m_t), so the
    # delta-coded downlink derives the ctx from the θ wire instead of
    # transporting it — the momentum-aware 0-byte ctx (DESIGN.md
    # §Transport).  `delta_params` is the decoded θ-delta the clients
    # received; the scale is config-derived, never transmitted.
    def _ctx_scale(self, fed):
        return -fed.beta_local / (fed.local_steps * fed.alpha * fed.eta)

    def ctx_from_broadcast_delta(self, delta_params, fed):
        return {"m_bar": T.scale(delta_params, self._ctx_scale(fed))}

    def local_step(self, theta, ctx, grad_fn, batch, fed, extra):
        m_bar = ctx["m_bar"]
        if fed.variant == "nesterov":
            # red: θ^{τ-1/2} = θ − η·m̄ ; g at θ^{τ-1/2}; θ = θ^{τ-1/2} − η·g
            theta_half = jax.tree.map(lambda t, m: t - fed.eta * m,
                                      theta, m_bar)
            g, aux = grad_fn(theta_half, batch)
            theta_new = _sgd_step(theta_half, g, fed.eta, fed)
        else:
            # blue (heavy-ball): θ = θ − η·(g + m̄)
            g, aux = grad_fn(theta, batch)
            g_total = T.add(_maybe_clip(g, fed), m_bar)
            theta_new = jax.tree.map(lambda t, gt: t - fed.eta * gt,
                                     theta, _wd(theta, g_total, fed))
        return theta_new, extra, aux

    def server_update(self, server_state, theta_t, mean_delta, fed):
        delta_bar = T.scale(T.cast(mean_delta, jnp.float32),
                            1.0 / fed.eta)                  # line 16
        m = T.axpy(fed.beta_global - fed.beta_local,
                   server_state["m"], delta_bar)            # line 17
        theta = _theta_step(theta_t, m, fed)                # line 19
        return theta, {"m": m}


# ---------------------------------------------------------------------------
# FedADC with double momentum (Alg. 4).
# ---------------------------------------------------------------------------
class FedADCDouble(FedADC):
    name = "fedadc_double"

    def client_setup(self, server_state, params, fed):
        m_bar = T.scale(server_state["m"],
                        fed.beta_global / fed.local_steps)
        return {"m_bar": jax.tree.map(lambda m, p: m.astype(p.dtype),
                                      m_bar, params)}

    def _ctx_scale(self, fed):
        # Alg. 4 broadcasts m̄_t = β_g/H · m_t against the same Δθ = −αη·m_t
        return -fed.beta_global / (fed.local_steps * fed.alpha * fed.eta)

    def init_extra(self, params, fed):
        return {"m_local": T.zeros_like(params), "tau": jnp.zeros((), jnp.int32)}

    def local_step(self, theta, ctx, grad_fn, batch, fed, extra):
        g, aux = grad_fn(theta, batch)
        g = _maybe_clip(g, fed)
        is_first = (extra["tau"] == 0)
        m_local = jax.tree.map(
            lambda ml, gi: jnp.where(is_first, gi,
                                     fed.phi * ml + (1 - fed.phi) * gi),
            extra["m_local"], g)                             # lines 9-12
        upd = T.add(ctx["m_bar"], m_local)                   # line 14
        theta_new = jax.tree.map(lambda t, u: t - fed.eta * u, theta,
                                 _wd(theta, upd, fed))
        return theta_new, {"m_local": m_local, "tau": extra["tau"] + 1}, aux

    def server_update(self, server_state, theta_t, mean_delta, fed):
        m = T.scale(T.cast(mean_delta, jnp.float32),
                    1.0 / fed.eta)                           # line 21 (no carry)
        theta = _theta_step(theta_t, m, fed)                 # line 23
        return theta, {"m": m}


# ---------------------------------------------------------------------------
# FedProx — proximal term μ/2‖θ − θ_t‖² added to the local objective.
# ---------------------------------------------------------------------------
class FedProx(FedAvg):
    name = "fedprox"

    def client_setup(self, server_state, params, fed):
        return {"theta_t": params}

    def local_step(self, theta, ctx, grad_fn, batch, fed, extra):
        g, aux = grad_fn(theta, batch)
        g = T.add(g, T.scale(T.sub(theta, ctx["theta_t"]), fed.mu_prox))
        return _sgd_step(theta, g, fed.eta, fed), extra, aux


# ---------------------------------------------------------------------------
# SCAFFOLD — control variates (stateful clients; simulator only).
# ---------------------------------------------------------------------------
class Scaffold(FedAvg):
    name = "scaffold"
    stateless_clients = False

    def server_init(self, params):
        return {"c": T.zeros_like(params)}

    def client_state_init(self, params):
        return {"c_i": T.zeros_like(params)}

    def client_setup(self, server_state, params, fed):
        return {"c": server_state["c"]}

    def local_step(self, theta, ctx, grad_fn, batch, fed, extra):
        g, aux = grad_fn(theta, batch)
        g = T.add(T.sub(g, extra["c_i"]), ctx["c"])
        return _sgd_step(theta, g, fed.eta, fed), extra, aux

    def client_state_update(self, client_state, ctx, theta_t, theta_H, fed):
        # option II: c_i' = c_i − c + (θ_t − θ_H)/(H·η)
        c_new = T.add(T.sub(client_state["c_i"], ctx["c"]),
                      T.scale(T.sub(theta_t, theta_H),
                              1.0 / (fed.local_steps * fed.eta)))
        return {"c_i": c_new}

    def server_update_scaffold(self, server_state, theta_t, mean_delta,
                               mean_dc, fed, part_frac):
        theta = T.sub(theta_t, mean_delta)
        c = T.add(server_state["c"], T.scale(mean_dc, part_frac))
        return theta, {"c": c}


# ---------------------------------------------------------------------------
# FedDyn — dynamic regularisation (stateful clients; simulator only).
# ---------------------------------------------------------------------------
class FedDyn(FedAvg):
    name = "feddyn"
    stateless_clients = False

    def server_init(self, params):
        return {"h": T.zeros_like(params)}

    def client_state_init(self, params):
        return {"grad_corr": T.zeros_like(params)}

    def client_setup(self, server_state, params, fed):
        return {"theta_t": params}

    def local_step(self, theta, ctx, grad_fn, batch, fed, extra):
        g, aux = grad_fn(theta, batch)
        # ∇ [ f_i(θ) − <∇̂_i, θ> + α/2 ‖θ − θ_t‖² ]
        g = T.sub(g, extra["grad_corr"])
        g = T.add(g, T.scale(T.sub(theta, ctx["theta_t"]), fed.feddyn_alpha))
        return _sgd_step(theta, g, fed.eta, fed), extra, aux

    def client_state_update(self, client_state, ctx, theta_t, theta_H, fed):
        gc = T.sub(client_state["grad_corr"],
                   T.scale(T.sub(theta_H, theta_t), fed.feddyn_alpha))
        return {"grad_corr": gc}

    def server_update_feddyn(self, server_state, theta_t, mean_theta_H,
                             mean_drift_all, fed):
        # h ← h − α · (1/N) Σ_i (θ_i^H − θ_t);  θ ← mean(θ^H) − h/α
        h = T.sub(server_state["h"], T.scale(mean_drift_all, fed.feddyn_alpha))
        theta = T.sub(mean_theta_H, T.scale(h, 1.0 / fed.feddyn_alpha))
        return theta, {"h": h}


STRATEGIES: Dict[str, Any] = {
    s.name: s for s in
    (FedAvg(), SlowMo(), FedADC(), FedADCDouble(), FedProx(), Scaffold(),
     FedDyn())
}
# loss-modifier strategies reuse FedAvg/FedADC update algebra:
for alias in ("moon", "fedgkd", "fedntd", "fedrs"):
    STRATEGIES[alias] = FedAvg()


def get_strategy(name: str):
    if name == "fedadc+":
        return STRATEGIES["fedadc"]
    if name not in STRATEGIES:
        raise KeyError(f"unknown strategy {name!r}; known {sorted(STRATEGIES)}")
    return STRATEGIES[name]
