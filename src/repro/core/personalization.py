"""Personalization via classifier calibration (Sec. IV-D).

After federated training, each client fine-tunes ONLY the classifier head on
its local data (body frozen), optionally regularised by a proximal term
(FedProx-style) or by the self-confidence KD loss of Sec. III.  This is the
computation- and communication-free personalization route the paper
advocates, and it is trivially repeatable when local statistics change.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distillation as D
from repro.core import tree as T


def calibrate_head(params: Dict, apply_fn: Callable, head_key: str,
                   x, y, counts, *, steps: int, batch_size: int, eta: float,
                   reg: str = "none", mu: float = 0.01, lam: float = 0.35,
                   tau: float = 1.0, seed: int = 0):
    """-> personalised params (only params[head_key] differs).

    reg: none | prox | kd   (kd = self-confidence distillation against the
    global model's own predictions, using the local class statistics)."""
    head0 = params[head_key]
    global_params = params

    def loss(head, xb, yb):
        p = dict(params, **{head_key: head})
        logits = apply_fn(p, xb)
        l = D.cross_entropy(logits, yb)
        if reg == "prox":
            l = l + 0.5 * mu * T.sq_norm(T.sub(head, head0))
        elif reg == "kd":
            t_logits = jax.lax.stop_gradient(apply_fn(global_params, xb))
            kd, _ = D.self_confidence_kd_loss(logits, t_logits, yb,
                                              counts, lam, tau)
            l = kd
        return l

    @jax.jit
    def step(head, xb, yb):
        g = jax.grad(loss)(head, xb, yb)
        return jax.tree.map(lambda h, gi: h - eta * gi, head, g)

    rng = np.random.RandomState(seed)
    head = head0
    n = len(x)
    for s in range(steps):
        sel = rng.randint(0, n, size=min(batch_size, n))
        head = step(head, jnp.asarray(x[sel]), jnp.asarray(y[sel]))
    return dict(params, **{head_key: head})


def personalized_accuracy(params, apply_fn, head_key, client_train,
                          client_test, counts, **kw):
    """Calibrate per client and report mean local test accuracy."""
    accs = []
    for (xtr, ytr, cts), (xte, yte) in zip(
            [(a, b, c) for (a, b), c in zip(client_train, counts)],
            client_test):
        if len(xte) == 0 or len(xtr) == 0:
            continue
        p = calibrate_head(params, apply_fn, head_key, xtr, ytr,
                           jnp.asarray(cts), **kw)
        logits = apply_fn(p, jnp.asarray(xte))
        accs.append(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(yte)))
    # device scalars accumulate; one explicit fetch (host-sync-in-jit hygiene)
    return float(np.mean(jax.device_get(accs))) if accs else 0.0
