"""Pytree algebra used by every FL strategy (params, momenta, deltas are all
the same pytree structure as the model parameters)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def zeros_like(t):
    return jax.tree.map(jnp.zeros_like, t)


def add(a, b):
    return jax.tree.map(jnp.add, a, b)


def sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def scale(t, s):
    return jax.tree.map(lambda x: x * s, t)


def axpy(a, x, y):
    """a*x + y."""
    return jax.tree.map(lambda xi, yi: a * xi + yi, x, y)


def lerp(a, b, w):
    """(1-w)*a + w*b."""
    return jax.tree.map(lambda ai, bi: (1 - w) * ai + w * bi, a, b)


def dot(a, b):
    leaves = jax.tree.leaves(jax.tree.map(
        lambda x, y: jnp.vdot(x.astype(jnp.float32), y.astype(jnp.float32)),
        a, b))
    return jnp.sum(jnp.stack(leaves))


def sq_norm(t):
    return dot(t, t)


def global_norm(t):
    return jnp.sqrt(sq_norm(t))


def clip_by_global_norm(t, max_norm):
    n = global_norm(t)
    s = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-12))
    return scale(t, s)


def cast(t, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), t)


def tree_size(t) -> int:
    return sum(x.size for x in jax.tree.leaves(t))
