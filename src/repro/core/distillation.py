"""Self-knowledge-distillation losses (Sec. III).

Implements the paper's *self-confidence knowledge distillation* (FedADC+,
eqs. (6)-(9)) plus the two baselines it generalises:

* FedGKD  — KL(student ‖ global-teacher) over all classes.
* FedNTD  — KL over the NOT-TRUE classes only.
* self-confidence (ours/paper) — the teacher's probabilities are reweighted
  per class by (1 − ρ_{i,k}) where ρ_{i,k} = γ_{i,k}/γ_k^max encodes how
  well class i is represented in client k's local data; the true class
  absorbs the leftover mass (eqs. (8),(9)).  When data is iid, ρ≈1 and the
  loss degrades to plain CE — the paper's adaptivity argument.

All functions operate on logits so they serve both the vision simulator
(class logits) and the pod LM engine (vocab logits; γ = token frequencies).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_T(logits, tau):
    return jax.nn.softmax(logits.astype(jnp.float32) / tau, axis=-1)


def kl_loss(p_student_logits, target_probs, tau):
    """Eq. (6): L_KL(p, p̂) = −Σ p̂_i log(p_i/p̂_i).  Mean over batch."""
    logp = jax.nn.log_softmax(p_student_logits.astype(jnp.float32) / tau, -1)
    t = jnp.clip(target_probs, 1e-9, 1.0)
    kl = jnp.sum(t * (jnp.log(t) - logp), axis=-1)
    return jnp.mean(kl) * (tau ** 2)


def cross_entropy(logits, labels):
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def class_confidence(class_counts):
    """ρ_{i,k} = γ_{i,k} / γ_k^max   (eq. before (8)).  counts (C,)."""
    gamma = class_counts / jnp.maximum(class_counts.sum(), 1.0)
    return gamma / jnp.maximum(gamma.max(), 1e-9)


def self_confidence_targets(teacher_logits, labels, rho, tau):
    """Eqs. (8),(9): build p̂ from the (global-model) teacher prediction and
    the local confidence vector ρ (C,).  labels (B,) int."""
    p_t = softmax_T(teacher_logits, tau)                     # (B, C)
    onehot = jax.nn.one_hot(labels, p_t.shape[-1], dtype=p_t.dtype)
    damp = (1.0 - rho)[None, :] * p_t                        # (1-ρ_i)·p̃^(i)
    non_true = damp * (1.0 - onehot)                         # eq. (8)
    true_mass = 1.0 - non_true.sum(-1, keepdims=True)        # eq. (9)
    return non_true + onehot * true_mass


def self_confidence_kd_loss(student_logits, teacher_logits, labels,
                            class_counts, lam, tau):
    """Eq. (7) with the self-confidence target — the FedADC+ objective."""
    rho = class_confidence(class_counts)
    targets = self_confidence_targets(teacher_logits, labels, rho, tau)
    ce = cross_entropy(student_logits, labels)
    kd = kl_loss(student_logits, jax.lax.stop_gradient(targets), tau)
    return (1.0 - lam) * ce + lam * kd, {"ce": ce, "kd": kd}


def masked_self_confidence_kd_loss(student_logits, teacher_logits, labels,
                                   class_counts, lam, tau, mask):
    """Token-level FedADC+ objective with a validity mask.

    The pod LM engine flattens (B, L) positions; padding positions (label
    −100, clipped to 0 upstream) must contribute to neither the CE nor the
    KD term, so both are computed per token and averaged over valid
    positions only.  mask (N,) bool/0-1, aligned with the flattened logits.
    """
    rho = class_confidence(class_counts)
    targets = self_confidence_targets(teacher_logits, labels, rho, tau)
    s = student_logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(s, axis=-1)
    gold = jnp.take_along_axis(s, labels[..., None], axis=-1)[..., 0]
    ce_tok = lse - gold
    logp = jax.nn.log_softmax(s / tau, axis=-1)
    t = jnp.clip(jax.lax.stop_gradient(targets), 1e-9, 1.0)
    kd_tok = jnp.sum(t * (jnp.log(t) - logp), axis=-1) * tau ** 2
    w = mask.astype(jnp.float32)
    denom = jnp.maximum(w.sum(), 1.0)
    ce = jnp.sum(ce_tok * w) / denom
    kd = jnp.sum(kd_tok * w) / denom
    return (1.0 - lam) * ce + lam * kd, {"ce": ce, "kd": kd}


def fedgkd_loss(student_logits, teacher_logits, labels, lam, tau):
    ce = cross_entropy(student_logits, labels)
    kd = kl_loss(student_logits,
                 jax.lax.stop_gradient(softmax_T(teacher_logits, tau)), tau)
    return ce + lam * kd, {"ce": ce, "kd": kd}


def fedntd_loss(student_logits, teacher_logits, labels, beta, tau):
    """KL over not-true classes only (teacher & student renormalised after
    masking the true class)."""
    C = student_logits.shape[-1]
    onehot = jax.nn.one_hot(labels, C, dtype=jnp.float32)
    mask = 1.0 - onehot
    s = student_logits.astype(jnp.float32) / tau + jnp.log(mask + 1e-30)
    t = teacher_logits.astype(jnp.float32) / tau + jnp.log(mask + 1e-30)
    p_t = jax.nn.softmax(t, -1)
    logp_s = jax.nn.log_softmax(s, -1)
    kl = jnp.sum(jnp.where(mask > 0, p_t * (jnp.log(jnp.clip(p_t, 1e-9))
                                            - logp_s), 0.0), -1)
    ce = cross_entropy(student_logits, labels)
    return ce + beta * jnp.mean(kl) * tau ** 2, {"ce": ce, "kd": jnp.mean(kl)}


def fedrs_logits(logits, class_present, alpha):
    """FedRS restricted softmax: scale logits of classes ABSENT from the
    client's data by α before CE.  class_present (C,) in {0,1}."""
    scale = class_present + (1.0 - class_present) * alpha
    return logits * scale[None, :]


def moon_loss(z, z_glob, z_prev, mu, temperature):
    """MOON model-contrastive term: positive = global-model features,
    negative = previous-local-model features."""
    def _cos(a, b):
        a = a / jnp.maximum(jnp.linalg.norm(a, axis=-1, keepdims=True), 1e-9)
        b = b / jnp.maximum(jnp.linalg.norm(b, axis=-1, keepdims=True), 1e-9)
        return jnp.sum(a * b, -1)
    pos = _cos(z, z_glob) / temperature
    neg = _cos(z, z_prev) / temperature
    return mu * jnp.mean(-pos + jax.nn.logsumexp(
        jnp.stack([pos, neg], -1), axis=-1))
