"""internvl2-26b [vlm] — InternViT (stub frontend) + InternLM2-20B language
backbone [arXiv:2404.16821].  ``input_specs`` provides precomputed patch
embeddings; the framework implements the LM that consumes them."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2-26b",
    family="vlm",
    source="arXiv:2404.16821",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    head_dim=128,
    n_patch_tokens=256,
    rope_theta=1_000_000.0,
    max_seq_len=32_768,
)
