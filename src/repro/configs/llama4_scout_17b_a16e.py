"""llama4-scout-17b-a16e [moe] — 16 routed experts top-1 + shared expert,
iRoPE-style attention interleave (3 chunked/windowed layers : 1 global),
early-fusion multimodal (text path implemented; vision stub not required for
this entry) [hf:meta-llama/Llama-4-Scout-17B-16E]."""
from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="llama4-scout-17b-a16e",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    moe=MoEConfig(n_experts=16, top_k=1, n_shared_experts=1,
                  d_ff_expert=8192, capacity_factor=1.25),
    sliding_window=8192,
    global_attn_every=4,
    rope_theta=500_000.0,
    max_seq_len=524_288,
)
