"""qwen1.5-32b [dense] — QKV bias, MHA (kv=40) [hf:Qwen/Qwen1.5-0.5B family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen1.5-32b",
    family="dense",
    source="hf:Qwen/Qwen1.5-0.5B",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    max_seq_len=32_768,
)
