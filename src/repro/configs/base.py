"""Configuration system for the FedADC reproduction framework.

Every architecture from the assigned pool is expressed as a ``ModelConfig``;
the federated-learning algorithm (the paper's contribution) is configured by
``FedConfig``; the mesh / sharding by ``RunConfig``.  Configs are plain frozen
dataclasses so they hash, compare, and can be used as jit static args.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Block kinds used to assemble heterogeneous stacks (hybrid / ssm / enc-dec).
# ---------------------------------------------------------------------------
ATTN = "attn"          # self-attention transformer block
MOE = "moe"            # transformer block with MoE FFN
MAMBA2 = "mamba2"      # Mamba2 (SSD) block
SLSTM = "slstm"        # xLSTM sLSTM block (scalar memory, sequential)
MLSTM = "mlstm"        # xLSTM mLSTM block (matrix memory, parallel)
SHARED_ATTN = "shared_attn"  # Zamba2-style globally shared attention block


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0            # routed experts
    top_k: int = 1
    n_shared_experts: int = 0     # always-on shared experts
    d_ff_expert: int = 0          # per-expert hidden dim
    router_aux_coef: float = 0.001  # load-balance auxiliary loss
    first_k_dense: int = 0        # leading layers that stay dense (DeepSeek)
    capacity_factor: float = 1.25  # per-expert token capacity (dropless if <=0)


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 Multi-head Latent Attention."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / xLSTM recurrent block parameters."""
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    n_groups: int = 1
    head_dim: int = 64
    chunk_size: int = 256         # SSD chunk length (TPU matmul-friendly)


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str = "unnamed"
    family: str = "dense"         # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""              # citation for the config

    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 32000
    head_dim: int = 0             # 0 => d_model // n_heads
    max_seq_len: int = 8192

    # attention variants
    qk_norm: bool = False         # Qwen3-style per-head RMSNorm on q,k
    qkv_bias: bool = False        # Qwen1.5-style bias on qkv projections
    rope_theta: float = 10000.0
    sliding_window: int = 0       # 0 => full attention
    # iRoPE-style interleave: every `global_attn_every`-th layer uses full
    # attention, the rest use `sliding_window` (Llama-4 chunked attention).
    global_attn_every: int = 0
    mla: Optional[MLAConfig] = None

    # MoE / SSM
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None

    # stack layout.  If `block_pattern` is empty it defaults to n_layers of
    # ATTN (or MOE for moe family).  For hybrids it lists one entry per layer.
    block_pattern: Tuple[str, ...] = ()
    shared_attn_every: int = 0    # Zamba2: shared block after every k blocks

    # enc-dec (audio): encoder consumes stub frame embeddings.
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq_scale: int = 1    # encoder frames per decoder token budget

    # vlm: prefix of precomputed patch embeddings (stub vision tower).
    n_patch_tokens: int = 0

    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # which mesh axis the MoE dispatch buffers live on: "model" for the
    # training regime (FSDP over data), "data" for the serving regime
    # (expert-parallel over data, no param gathers) — §Perf iteration 6
    moe_dispatch_axis: str = "model"

    # --- derived ----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        # explicit 0-sentinel comparison, not truthiness (truthiness-on-config)
        return self.head_dim if self.head_dim > 0 \
            else self.d_model // self.n_heads

    def blocks(self) -> Tuple[str, ...]:
        if self.block_pattern:
            return self.block_pattern
        if self.moe is not None:
            pat = []
            for i in range(self.n_layers):
                pat.append(ATTN if i < self.moe.first_k_dense else MOE)
            return tuple(pat)
        return (ATTN,) * self.n_layers

    def layer_uses_window(self, layer_idx: int) -> bool:
        """True when this attention layer is sliding-window (sub-quadratic)."""
        if self.sliding_window <= 0:
            return False
        if self.global_attn_every > 0:
            return (layer_idx + 1) % self.global_attn_every != 0
        return True

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode possible (SSM/hybrid, or windowed attention
        on every layer that would otherwise be quadratic)."""
        kinds = set(self.blocks())
        attn_kinds = {ATTN, MOE, SHARED_ATTN}
        if not (kinds & attn_kinds):
            return True                           # pure SSM
        if self.is_encoder_decoder:
            return False
        if MAMBA2 in kinds or MLSTM in kinds or SLSTM in kinds:
            # hybrid: the SSM backbone carries long-range state; the few
            # (shared) attention layers decode linearly against the cache
            return True
        if self.mla is not None:
            return False                          # full-attention MLA cache
        if self.sliding_window > 0:
            # hybrids: the few attention layers are windowed; dense: every
            # layer must be windowed unless interleaved global layers use
            # attention-sink truncation (we do not), so require no globals
            # or an SSM backbone carrying the long-range state.
            if self.global_attn_every == 0:
                return True
            return MAMBA2 in kinds or MLSTM in kinds or self.family == "moe"
        return False

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        from repro.models.registry import count_params  # lazy, avoids cycle
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.registry import count_params
        return count_params(self, active_only=True)

    def reduced(self) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        kw = dict(
            n_layers=2, d_model=min(self.d_model, 256),
            n_heads=min(self.n_heads, 4),
            d_ff=min(self.d_ff, 512) if self.d_ff > 0 else 0,
            vocab_size=min(self.vocab_size, 512),
            max_seq_len=256,
            head_dim=64 if self.head_dim > 0 else 0,
        )
        kw["n_kv_heads"] = min(self.n_kv_heads, kw["n_heads"])
        if self.moe is not None:
            kw["moe"] = replace(
                self.moe, n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=min(self.moe.d_ff_expert, 256),
                first_k_dense=min(self.moe.first_k_dense, 1))
        if self.ssm is not None:
            kw["ssm"] = replace(self.ssm, d_state=min(self.ssm.d_state, 16),
                                head_dim=32, chunk_size=64)
        if self.mla is not None:
            kw["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=48,
                                  qk_nope_head_dim=32, qk_rope_head_dim=16,
                                  v_head_dim=32)
        if self.block_pattern:
            kw["block_pattern"] = self.block_pattern[:2]
        if self.is_encoder_decoder:
            kw["n_encoder_layers"] = 2
        if self.n_patch_tokens > 0:
            kw["n_patch_tokens"] = 16
        if self.sliding_window > 0:
            kw["sliding_window"] = min(self.sliding_window, 128)
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Federated-learning (the paper's algorithm) configuration.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class FedConfig:
    strategy: str = "fedadc"       # fedadc|fedadc_double|slowmo|fedavg|fedprox|
                                   # feddyn|scaffold|moon|fedgkd|fedntd|fedrs
    variant: str = "nesterov"      # fedadc: nesterov (red) | heavyball (blue)
    local_steps: int = 8           # H
    clients_per_round: int = 8     # |S_t|
    n_clients: int = 100           # N
    participation: float = 0.2     # c  (used by samplers)
    eta: float = 0.05              # local lr
    alpha: float = 1.0             # server lr multiplier
    beta_global: float = 0.8       # SlowMo / FedADC global momentum
    beta_local: float = 0.8        # FedADC embedding discount
    phi: float = 0.9               # double-momentum local EMA
    mu_prox: float = 0.01          # FedProx proximal coefficient
    feddyn_alpha: float = 0.01     # FedDyn regularization
    # self knowledge distillation (FedADC+)
    distill: bool = False
    distill_lambda: float = 0.35
    distill_tau: float = 1.0
    weight_decay: float = 0.0
    grad_clip: float = 0.0
    use_pallas: bool = False       # fused Pallas update kernels (TPU target)
    # server-side aggregation (shared server_aggregate hook, DESIGN.md
    # §Heterogeneity): uniform | examples | drag
    aggregator: str = "uniform"
    drag_lambda: float = 4.0       # DRAG divergence temperature
    # semi-async engine (repro.federated.async_engine)
    buffer_k: int = 0              # server update after K deltas; 0 =>
                                   # clients_per_round (synchronous barrier)
    staleness_mode: str = "poly"   # none | poly ((1+s)^-a) | exp (a^s)
    staleness_factor: float = 0.5  # `a` in the discount above
    # uplink delta compression (repro.federated.compression, driven through
    # repro.federated.transport.Transport): none bypasses the codec entirely;
    # identity goes through it losslessly (bit-identity tested); topk/qsgd
    # are lossy with per-client error feedback
    compressor: str = "none"       # none | identity | topk | qsgd
    topk_frac: float = 0.1         # fraction of entries kept per leaf
    qsgd_bits: int = 8             # magnitude bits (sign sent separately)
    error_feedback: bool = True    # re-inject round-t residual at t+1
    # true sparse (value, index) top-k wire representation inside jit —
    # the server decodes one scatter per client instead of re-running the
    # dense threshold pass (DESIGN.md §Transport); reconstruction equals
    # the dense path exactly (oracle-tested)
    sparse_uplink: bool = False
    # sparse-NATIVE server aggregation (kernels/sparse_reduce.py): with the
    # sparse uplink on, the engines segment-sum the (value, index) wires
    # straight into the aggregate at K·k cost — per-client dense trees are
    # never materialised.  False forces the dense-decode path (one scatter
    # per client, then the dense weighted reduce); the two are the CI
    # sparse-parity axis.  Ignored unless sparse_uplink selects the
    # SparseLeaf wire.
    sparse_aggregate: bool = True
    # downlink broadcast compression (Transport.broadcast): the server
    # compresses (θ_t, ctx) once per round, clients train on the wire
    # reconstruction.  none/identity are bit-exact.  The delta family is
    # the momentum-aware reference-coded broadcast (DESIGN.md §Transport):
    # the server keeps the last broadcast reconstruction (θ_{t−1}, m̄_{t−1})
    # in its round state and ships deltas against it — "delta" (=
    # "delta+identity") transports the residual losslessly, "delta+topk" /
    # "delta+qsgd" compose a lossy codec on the delta, where compression
    # actually bites.  For FedADC the ctx is an exact scalar image of the
    # θ-delta (Δθ_t = −αη·m_t, m̄_t = β_l/H·m_t), so the delta-coded ctx
    # costs 0 wire bytes and the broadcast recovers the paper's 1× load.
    downlink_compressor: str = "none"   # none | identity | topk | qsgd |
                                        # delta[+identity|+topk|+qsgd]
    # per-direction knobs: the downlink codec falls back to the uplink
    # topk_frac / qsgd_bits when these are None
    downlink_topk_frac: Optional[float] = None
    downlink_qsgd_bits: Optional[int] = None
    # per-client unicast downlink (repro.federated.reference): instead of
    # the one-multicast-payload model, every dispatched client is charged
    # individually against the version it last received — fresh clients
    # cost 0 measured bytes, clients ≤ resync_horizon versions stale get
    # the chained delta against THEIR version at steady-state delta bytes,
    # and anything staler (or never seen) pays the full-θ resync.  Needs
    # the lossless delta downlink family (the reconstruction must be exact
    # θ_t for every staleness level so the in-jit program stays one tree;
    # Transport validates).  Accounting/bookkeeping only: trajectories are
    # bit-identical to multicast (CI engine-parity Unicast axis).
    downlink_unicast: bool = False
    resync_horizon: int = 4
    # two-tier fleet topology (repro.federated.fleet, DESIGN.md §Fleet):
    # 0 = flat aggregation (the server reduces all K deltas directly);
    # R >= 1 = hierarchical — the round's deltas chunk into R contiguous
    # regional cohorts, each reduced by a regional aggregator, and the
    # global server combines the R partials with fp32 cast-on-write.
    # R = 1 is the identity configuration: bit-identical to flat (tested
    # per engine in the CI Hierarchical parity axis).
    fleet_regions: int = 0


# ---------------------------------------------------------------------------
# Client system heterogeneity (repro.federated.hetero).  Describes the *fleet*
# — per-client compute speed, availability, variable local work — as opposed
# to FedConfig, which describes the *algorithm*.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class HeteroConfig:
    enabled: bool = False
    # compute-speed distribution over clients:
    #   constant  — all clients speed 1 (the synchronous idealisation)
    #   lognormal — exp(sigma·N(0,1)), long right tail of slow clients
    #   uniform   — U[speed_range]
    #   bimodal   — straggler_frac of clients run straggler_slowdown× slower
    speed_dist: str = "constant"
    speed_sigma: float = 0.5
    speed_range: Tuple[float, float] = (0.25, 1.0)
    straggler_frac: float = 0.25
    straggler_slowdown: float = 4.0
    # per-client local work H_i sampled uniformly from this set; () => every
    # client runs fed.local_steps (homogeneous work).
    local_steps_choices: Tuple[int, ...] = ()
    # FedNova-style normalisation: rescale Δ_i by H_ref/H_i so heterogeneous
    # local work aggregates without objective inconsistency.
    fednova: bool = True
    availability: float = 1.0      # P(client reachable at dispatch time)
    drop_prob: float = 0.0         # P(in-flight client drops; delta lost)
    time_jitter: float = 0.0       # multiplicative jitter on round times
    seed: int = 0


@dataclass(frozen=True)
class ShapeConfig:
    name: str = "train_4k"
    seq_len: int = 4096
    global_batch: int = 256
    kind: str = "train"            # train | prefill | decode


# The four assigned input shapes.
SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


@dataclass(frozen=True)
class RunConfig:
    mesh_shape: Tuple[int, ...] = (16, 16)
    mesh_axes: Tuple[str, ...] = ("data", "model")
    multi_pod: bool = False
    remat: str = "none"            # none | full | selective
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    seed: int = 0
