"""xlstm-350m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].  Every 4th
block is sLSTM (true recurrence), the rest mLSTM (parallel matrix memory)."""
from repro.configs.base import MLSTM, SLSTM, ModelConfig, SSMConfig


def _pattern(n: int):
    return tuple(SLSTM if (i + 1) % 4 == 0 else MLSTM for i in range(n))


CONFIG = ModelConfig(
    arch_id="xlstm-350m",
    family="ssm",
    source="arXiv:2405.04517",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                   # projections live inside the xLSTM blocks
    vocab_size=50304,
    ssm=SSMConfig(d_state=0, d_conv=4, expand=2, head_dim=256),
    block_pattern=_pattern(24),
    max_seq_len=524_288,
    tie_embeddings=True,
)
