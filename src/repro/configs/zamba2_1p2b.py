"""zamba2-1.2b [hybrid] — Mamba2 backbone with a single globally-shared
attention block applied after every 6 Mamba blocks [arXiv:2411.15242]."""
from repro.configs.base import MAMBA2, SHARED_ATTN, ModelConfig, SSMConfig


def _pattern(n_mamba: int, every: int):
    pat = []
    for i in range(n_mamba):
        pat.append(MAMBA2)
        if (i + 1) % every == 0:
            pat.append(SHARED_ATTN)
    return tuple(pat)


CONFIG = ModelConfig(
    arch_id="zamba2-1.2b",
    family="hybrid",
    source="arXiv:2411.15242",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    head_dim=64,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, n_groups=1, head_dim=64,
                  chunk_size=256),
    block_pattern=_pattern(38, 6),
    shared_attn_every=6,
    max_seq_len=524_288,
    tie_embeddings=True,
)
