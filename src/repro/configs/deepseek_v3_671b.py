"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8 experts, first 3
layers dense [arXiv:2412.19437].  MTP (multi-token prediction) head is out of
scope for the FL reproduction (noted in DESIGN.md)."""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v3-671b",
    family="moe",
    source="arXiv:2412.19437",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,               # dense layers (first 3)
    vocab_size=129280,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, n_shared_experts=1,
                  d_ff_expert=2048, first_k_dense=3, capacity_factor=1.25),
    rope_theta=10_000.0,
    max_seq_len=131_072,
)
