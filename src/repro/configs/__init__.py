"""Architecture registry.  ``--arch`` takes the exact assigned id (which may
contain dots/dashes); module files use sanitised names."""
from __future__ import annotations

from dataclasses import replace

from repro.configs.base import (SHAPES, FedConfig, HeteroConfig, ModelConfig,
                                RunConfig, ShapeConfig)
from repro.configs import (deepseek_v3_671b, internvl2_26b,
                           llama4_scout_17b_a16e, mistral_large_123b,
                           qwen1p5_32b, qwen3_14b, qwen3_4b, whisper_small,
                           xlstm_350m, zamba2_1p2b)

ARCHS = {
    c.CONFIG.arch_id: c.CONFIG
    for c in (zamba2_1p2b, internvl2_26b, whisper_small, mistral_large_123b,
              deepseek_v3_671b, qwen3_14b, qwen1p5_32b, qwen3_4b, xlstm_350m,
              llama4_scout_17b_a16e)
}

# Dense archs that get the beyond-paper sliding-window serving variant for
# the long_500k shape (documented in DESIGN.md §Arch-applicability).
_LONG_CTX_WINDOW_VARIANT = {"qwen3-4b": 8192, "qwen3-14b": 8192}


def get_arch(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def long_context_variant(cfg: ModelConfig):
    """Config used for the long_500k shape, or None if the arch skips it."""
    if cfg.supports_long_context:
        return cfg
    if cfg.arch_id in _LONG_CTX_WINDOW_VARIANT:
        return replace(cfg, sliding_window=_LONG_CTX_WINDOW_VARIANT[cfg.arch_id])
    return None


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return long_context_variant(cfg) is not None
    return True


__all__ = ["ARCHS", "SHAPES", "get_arch", "long_context_variant",
           "shape_applicable", "ModelConfig", "FedConfig", "HeteroConfig",
           "RunConfig", "ShapeConfig"]
