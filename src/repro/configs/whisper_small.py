"""whisper-small [audio] — encoder-decoder; the mel-spectrogram + conv
frontend is a STUB (``input_specs`` yields frame embeddings) per the
assignment [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-small",
    family="audio",
    source="arXiv:2212.04356",
    n_layers=12,              # decoder layers
    n_encoder_layers=12,
    is_encoder_decoder=True,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    max_seq_len=4096,         # decoder positions (learned); frames unbounded
    tie_embeddings=True,
)
