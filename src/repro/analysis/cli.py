"""``python -m repro.analysis`` — run the repo-contract analysis pass.

Exit codes: 0 clean (no unsuppressed findings; with ``--require-clean``
also no stale baseline entries), 1 findings or stale suppressions, 2 usage
error.  ``--jsonl`` writes every finding (suppressed included, flagged) as
telemetry-envelope JSONL for the CI artifact.
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.analysis import ast_rules, trace_audit
from repro.analysis.baseline import load_baseline, write_baseline
from repro.analysis.findings import findings_to_jsonl, sort_findings


def repo_root() -> str:
    """src/repro/analysis/cli.py -> the repo checkout root."""
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.abspath(os.path.join(here, "..", "..", ".."))


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-contract static analysis "
                    "(AST lints + trace-time jaxpr audits)")
    p.add_argument("--root", default=None,
                   help="repo root (default: derived from the package path)")
    p.add_argument("--baseline", default=None,
                   help="suppression file (default: ROOT/analysis_baseline"
                        ".json)")
    p.add_argument("--jsonl", default=None,
                   help="write all findings as telemetry-envelope JSONL")
    p.add_argument("--require-clean", action="store_true",
                   help="exit 1 on any unsuppressed finding OR stale "
                        "baseline entry (the CI gate)")
    p.add_argument("--update-baseline", action="store_true",
                   help="rewrite the baseline to cover current findings "
                        "(reasons must then be filled in by hand)")
    p.add_argument("--skip-trace", action="store_true",
                   help="AST layer only (fast; no engine builds)")
    p.add_argument("--skip-retrace", action="store_true",
                   help="skip the (slowest) retrace audit, keep the jaxpr "
                        "and kernel-coverage audits")
    p.add_argument("--rules", default=None,
                   help="comma-separated AST rule subset")
    p.add_argument("--list-rules", action="store_true")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_rules:
        for rid in sorted(ast_rules.RULES):
            print(rid)
        for rid in ("trace-retrace", "trace-accumulation-dtype",
                    "trace-kernel-coverage"):
            print(rid)
        return 0

    root = os.path.abspath(args.root) if args.root else repo_root()
    baseline_path = args.baseline or os.path.join(root,
                                                  "analysis_baseline.json")

    rules = None
    if args.rules:
        wanted = set(args.rules.split(","))
        unknown = wanted - set(ast_rules.RULES)
        if unknown:
            print(f"unknown rules: {', '.join(sorted(unknown))}",
                  file=sys.stderr)
            return 2
        rules = {k: v for k, v in ast_rules.RULES.items() if k in wanted}

    findings = ast_rules.run_ast_rules(root, rules=rules)
    if not args.skip_trace:
        findings.extend(trace_audit.run_trace_audits(
            root, include_retrace=not args.skip_retrace))
    findings = sort_findings(findings)

    if args.update_baseline:
        write_baseline(baseline_path, findings)
        print(f"wrote {len(findings)} entries to {baseline_path} — fill in "
              f"the reasons before committing")
        return 0

    try:
        baseline = load_baseline(baseline_path)
    except ValueError as e:
        print(f"invalid baseline: {e}", file=sys.stderr)
        return 2
    new, suppressed, stale = baseline.apply(findings)

    everything = sort_findings(new + suppressed)
    for f in everything:
        print(f.format())
    if args.jsonl:
        findings_to_jsonl(everything, args.jsonl)
        print(f"wrote {len(everything)} findings to {args.jsonl}")

    for e in stale:
        print(f"STALE baseline entry (no longer fires): "
              f"{e['rule']} @ {e['path']} :: {e['snippet']!r}",
              file=sys.stderr)

    print(f"{len(new)} finding(s), {len(suppressed)} suppressed, "
          f"{len(stale)} stale baseline entr{'y' if len(stale) == 1 else 'ies'}")
    if new:
        return 1
    if args.require_clean and stale:
        return 1
    return 0
