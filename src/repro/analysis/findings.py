"""Finding objects and their JSONL wire format.

A finding is one rule violation at one source location.  Its *identity* for
baseline-suppression purposes is ``(rule, path, context, snippet)`` — the
enclosing def/class chain plus the normalised source line, NOT the line
number, so unrelated edits above a suppressed site don't resurrect it and
moving the offending line doesn't silently un-suppress a new copy.

The JSONL export reuses the telemetry event envelope (``ts``/``kind``/
``engine`` with ``kind="finding"``, ``engine="analysis"``) so the CI
artifact validates under ``python -m repro.telemetry.schema`` like every
other event stream in the repo.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Iterable, List, Tuple


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str            # rule id, e.g. "truthiness-on-config"
    path: str            # repo-relative posix path
    line: int            # 1-based line number (display only, not identity)
    message: str         # human-readable defect statement
    context: str = ""    # enclosing Class.func dotted chain ("" = module)
    snippet: str = ""    # stripped source line at `line`
    suppressed: bool = False  # True once matched against the baseline

    def key(self) -> Tuple[str, str, str, str]:
        """Baseline-identity key (line-number free)."""
        return (self.rule, self.path, self.context, self.snippet)

    def to_event(self, ts: float) -> dict:
        return {
            "ts": ts,
            "kind": "finding",
            "engine": "analysis",
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "message": self.message,
            "context": self.context,
            "snippet": self.snippet,
            "suppressed": self.suppressed,
        }

    def format(self) -> str:
        tag = " [baseline]" if self.suppressed else ""
        return f"{self.path}:{self.line}: {self.rule}: {self.message}{tag}"


def findings_to_jsonl(findings: Iterable[Finding], path: str,
                      ts: float | None = None) -> int:
    """Write findings as schema-valid telemetry JSONL; returns the count."""
    from repro.telemetry.schema import validate_event

    ts = time.time() if ts is None else ts
    n = 0
    with open(path, "w") as f:
        for fi in findings:
            event = fi.to_event(ts)
            validate_event(event)
            f.write(json.dumps(event) + "\n")
            n += 1
    return n


def sort_findings(findings: Iterable[Finding]) -> List[Finding]:
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))
