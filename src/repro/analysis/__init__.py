"""repro.analysis — repo-contract static analysis (DESIGN.md §Static analysis).

Two layers, zero dependencies beyond the stdlib + jax already in the tree:

* Layer 1 — AST checkers (`ast_rules.py`) encode the repo's shipped bug
  classes as named rules over ``src/``, ``benchmarks/`` and ``examples/``.
* Layer 2 — trace-time audits (`trace_audit.py`) trace the three round
  engines and the aggregation kernels on shaped zeros (no real data) and
  walk the jaxprs for retrace and accumulation-precision contracts.

Findings are emitted as JSONL reusing the telemetry event envelope
(``kind="finding"``), suppressed only via the committed
``analysis_baseline.json``, and gate CI through
``python -m repro.analysis --require-clean``.
"""
from repro.analysis.findings import Finding, findings_to_jsonl
from repro.analysis.baseline import Baseline, load_baseline
from repro.analysis.ast_rules import RULES, run_ast_rules
from repro.analysis.trace_audit import run_trace_audits

__all__ = [
    "Finding",
    "findings_to_jsonl",
    "Baseline",
    "load_baseline",
    "RULES",
    "run_ast_rules",
    "run_trace_audits",
]
