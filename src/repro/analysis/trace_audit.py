"""Layer 2 — trace-time audits (shaped zeros and synthetic shapes only; no
real data is read anywhere).

Three audits, each returning ``Finding``s on the same envelope as the AST
rules so one baseline and one CLI cover both layers:

* **retrace** (``trace-retrace``) — build each round engine at a tiny
  synthetic size, run two identically-shaped rounds, and assert the jit
  cache holds exactly one trace per compiled function
  (``_cache_size()``).  A second trace means a config object or weak type
  leaked into the traced signature — the PR 6 retrace contract, checked
  across an engine × codec matrix instead of two hand-written tests.
* **accumulation dtype** (``trace-accumulation-dtype``) —
  ``jax.make_jaxpr`` over the weighted reductions (``ref`` oracle + Pallas
  wrapper), the pod engine's client-serial scan, and the FedADC momentum
  update, then walk every eqn (recursing into scan/pjit/cond/pallas_call
  sub-jaxprs) and flag reductions that consume AND produce below-fp32
  floats, scans-of-scans whose outer (aggregation) carry holds no ≥fp32
  accumulator, and momentum leaves carried below fp32 — the PR 5 fp32
  cast-on-write contract, proven on the jaxpr rather than sampled by
  parity tests.
* **kernel coverage** (``trace-kernel-coverage``) — every Pallas-backed
  export in ``kernels/ops.py`` (identified by its ``interpret=`` lowering
  switch) must have a ``ref.py`` oracle and a parity test in
  ``tests/test_kernels.py``.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Sequence, Set

import numpy as np

from repro.analysis.findings import Finding

# --------------------------------------------------------------------------
# shared: jaxpr walking
# --------------------------------------------------------------------------

_LOW_FLOATS = ("bfloat16", "float16")
# eqn primitives that accumulate across elements (a low-precision output
# here means the accumulator itself is low-precision)
_REDUCE_PRIMS = {"reduce_sum", "add_any", "cumsum", "dot_general",
                 "scatter-add", "segment_sum"}


def _is_low_float(dtype) -> bool:
    s = str(dtype)
    return s in _LOW_FLOATS or s.startswith("float8")


def _sub_jaxprs(eqn):
    """Sub-jaxprs of one eqn (scan/while/cond/pjit/remat/pallas_call...)."""
    out = []
    for v in eqn.params.values():
        vs = v if isinstance(v, (list, tuple)) else [v]
        for item in vs:
            if hasattr(item, "jaxpr") and hasattr(item.jaxpr, "eqns"):
                out.append(item.jaxpr)          # ClosedJaxpr
            elif hasattr(item, "eqns"):
                out.append(item)                # Jaxpr
    return out


def _float_dtypes(vars_):
    out = []
    for v in vars_:
        aval = getattr(v, "aval", None)
        dt = getattr(aval, "dtype", None)
        if dt is not None and np.issubdtype(dt, np.floating):
            out.append(dt)
    return out


def walk_jaxpr_reductions(jaxpr, where: str) -> List[str]:
    """One violation string per reduction eqn whose output stays below fp32
    while consuming float inputs (integer reductions are fine)."""
    violations: List[str] = []

    def visit(jx):
        for eqn in jx.eqns:
            name = eqn.primitive.name
            if name in _REDUCE_PRIMS:
                ins = _float_dtypes(eqn.invars)
                outs = _float_dtypes(eqn.outvars)
                if ins and outs and any(_is_low_float(d) for d in ins) \
                        and all(_is_low_float(d) for d in outs):
                    violations.append(
                        f"{where}: `{name}` accumulates in {outs[0]} "
                        f"(inputs {[str(d) for d in ins]})")
            for sub in _sub_jaxprs(eqn):
                visit(sub)

    visit(jaxpr)
    return violations


def scan_carry_dtype_violations(jaxpr, where: str,
                                min_size: int = 2) -> List[str]:
    """For scans *containing* another scan (the client-serial aggregation
    loop wraps the local-training loop), the outer carry must hold at least
    one ≥fp32 multi-element float leaf — the Σw·Δ accumulator.  An
    all-low-precision outer carry means the fp32 cast-on-write contract
    regressed."""
    violations: List[str] = []

    def has_scan(jx) -> bool:
        for eqn in jx.eqns:
            if eqn.primitive.name == "scan":
                return True
            if any(has_scan(sub) for sub in _sub_jaxprs(eqn)):
                return True
        return False

    def visit(jx):
        for eqn in jx.eqns:
            if eqn.primitive.name == "scan":
                body = eqn.params["jaxpr"].jaxpr
                if has_scan(body):
                    num_carry = eqn.params["num_carry"]
                    carry = [getattr(v, "aval", None)
                             for v in body.invars[:num_carry]]
                    big = [a for a in carry
                           if a is not None
                           and getattr(a, "size", 0) >= min_size
                           and hasattr(a, "dtype")
                           and np.issubdtype(a.dtype, np.floating)]
                    if big and all(_is_low_float(a.dtype) for a in big):
                        violations.append(
                            f"{where}: outer (client-serial) scan carry "
                            f"holds no ≥fp32 accumulator leaf (float "
                            f"carries: "
                            f"{[f'{a.dtype}{a.shape}' for a in big[:4]]})")
            for sub in _sub_jaxprs(eqn):
                visit(sub)

    visit(jaxpr)
    return violations


# --------------------------------------------------------------------------
# findings plumbing + tiny synthetic fixtures
# --------------------------------------------------------------------------

def _finding(rule: str, path: str, message: str, context: str) -> Finding:
    return Finding(rule=rule, path=path, line=1, message=message,
                   context=context, snippet=f"<trace:{context}>")


_IMG = 16          # must survive the CNN's 4 pools (image_size // 16 >= 1)
_NCLASS = 4


def _synthetic_dataset(n: int = 48):
    x = np.zeros((n, _IMG, _IMG, 3), np.float32)
    y = (np.arange(n) % _NCLASS).astype(np.int32)
    return x, y


def _partitions(n: int, n_clients: int) -> List[np.ndarray]:
    return [np.arange(i, n, n_clients) for i in range(n_clients)]


def _sim_config():
    from repro.federated.simulator import SimConfig
    return SimConfig(rounds=2, n_classes=_NCLASS, batch_size=4,
                     eval_every=100, eval_batch=8, cnn_width=4, seed=0)


def _build_sync(fed_kwargs: Dict):
    from repro.configs.base import FedConfig
    from repro.federated.simulator import FederatedSimulator

    fed = FedConfig(strategy="fedadc", local_steps=2, clients_per_round=4,
                    n_clients=8, **fed_kwargs)
    x, y = _synthetic_dataset()
    return FederatedSimulator(fed, _sim_config(), x, y, x[:8], y[:8],
                              _partitions(len(x), fed.n_clients))


def _build_async(fed_kwargs: Dict):
    from repro.configs.base import FedConfig, HeteroConfig
    from repro.federated.async_engine import AsyncFederatedSimulator

    fed = FedConfig(strategy="fedadc", local_steps=2, clients_per_round=4,
                    n_clients=8, buffer_k=2, **fed_kwargs)
    hetero = HeteroConfig(enabled=True, speed_dist="uniform",
                          speed_range=(0.5, 1.0), seed=0)
    x, y = _synthetic_dataset()
    return AsyncFederatedSimulator(fed, _sim_config(), hetero, x, y,
                                   x[:8], y[:8],
                                   _partitions(len(x), fed.n_clients))


def _pod_configs():
    from repro.configs import ARCHS
    from repro.configs.base import FedConfig, RunConfig

    mcfg = ARCHS["qwen3-4b"].reduced()
    fed = FedConfig(strategy="fedadc", clients_per_round=2, local_steps=2,
                    eta=0.05)
    run = RunConfig(remat="none", param_dtype="float32",
                    compute_dtype="bfloat16")
    return mcfg, fed, run


# --------------------------------------------------------------------------
# audit: accumulation dtype
# --------------------------------------------------------------------------

def audit_accumulation_dtype() -> List[Finding]:
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops, ref

    findings: List[Finding] = []
    K, D = 8, 16
    deltas = jax.ShapeDtypeStruct((K, D), jnp.bfloat16)
    weights = jax.ShapeDtypeStruct((K,), jnp.float32)

    # 1. the weighted reduction: oracle and Pallas wrapper on a bf16 stack
    for name, fn, path in (
            ("ref.weighted_delta_reduce", ref.weighted_delta_reduce,
             "src/repro/kernels/ref.py"),
            ("ops.weighted_delta_reduce", ops.weighted_delta_reduce,
             "src/repro/kernels/ops.py")):
        jaxpr = jax.make_jaxpr(fn)(deltas, weights).jaxpr
        for v in walk_jaxpr_reductions(jaxpr, name):
            findings.append(_finding("trace-accumulation-dtype", path, v,
                                     name))

    # 1b. the sparse scatter-accumulate aggregate on a bf16 wire: the
    # segment-sum / scatter-add must accumulate ≥fp32 even when the leaf
    # dtype (the final cast-on-write target) is bf16
    kk = 8
    svals = jax.ShapeDtypeStruct((K, kk), jnp.bfloat16)
    sidx = jax.ShapeDtypeStruct((K, kk), jnp.int32)
    for name, fn, path in (
            ("ref.sparse_weighted_delta_reduce",
             ref.sparse_weighted_delta_reduce,
             "src/repro/kernels/ref.py"),
            ("ops.sparse_weighted_delta_reduce",
             ops.sparse_weighted_delta_reduce,
             "src/repro/kernels/ops.py")):
        jaxpr = jax.make_jaxpr(
            lambda v, i, w, f=fn: f(v, i, w, (D,), jnp.bfloat16))(
                svals, sidx, weights).jaxpr
        for v in walk_jaxpr_reductions(jaxpr, name):
            findings.append(_finding("trace-accumulation-dtype", path, v,
                                     name))

    # 2. the FedADC momentum recursion, in both wire regimes: the momentum
    # leaves must come back ≥fp32 (a bf16 m accumulates Δ̄ across rounds in
    # bf16 — the PR 5 class on the server side) and no reduction inside the
    # update may accumulate low
    from repro.configs.base import FedConfig
    from repro.core.strategies import get_strategy

    fed = FedConfig(strategy="fedadc")
    strat = get_strategy(fed.strategy)
    for regime, pdt in (("fp32-params", jnp.float32),
                        ("bf16-params", jnp.bfloat16)):
        params = {"w": jax.ShapeDtypeStruct((16,), pdt)}
        mean_delta = {"w": jax.ShapeDtypeStruct((16,), pdt)}
        server_state = jax.eval_shape(strat.server_init, params)

        def upd(ss, p, md):
            return strat.server_update(ss, p, md, fed)

        jaxpr = jax.make_jaxpr(upd)(server_state, params, mean_delta).jaxpr
        ctxname = f"fedadc.server_update[{regime}]"
        for v in walk_jaxpr_reductions(jaxpr, ctxname):
            findings.append(_finding(
                "trace-accumulation-dtype", "src/repro/core/strategies.py",
                v, ctxname))
        theta_out, ss_out = jax.eval_shape(upd, server_state, params,
                                           mean_delta)
        for leaf in jax.tree.leaves(ss_out):
            if hasattr(leaf, "dtype") and _is_low_float(leaf.dtype):
                findings.append(_finding(
                    "trace-accumulation-dtype",
                    "src/repro/core/strategies.py",
                    f"{ctxname}: server momentum leaf carried in "
                    f"{leaf.dtype} — cross-round accumulation below fp32",
                    ctxname))
        for leaf in jax.tree.leaves(theta_out):
            if leaf.dtype != pdt:
                findings.append(_finding(
                    "trace-accumulation-dtype",
                    "src/repro/core/strategies.py",
                    f"{ctxname}: θ update changed the parameter dtype to "
                    f"{leaf.dtype} (expected {pdt})", ctxname))

    # 3. the pod engine's client-serial scan under the mixed-precision
    # round: the outer (aggregation) scan carry must hold the fp32 Σw·Δ
    # accumulator even though local training runs bf16
    findings.extend(_audit_pod_scan())
    return findings


def _audit_pod_scan() -> List[Finding]:
    import jax
    from repro.configs.base import ShapeConfig
    from repro.launch import inputs as I
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import make_train_step

    findings: List[Finding] = []
    mcfg, fed, run = _pod_configs()
    shape = ShapeConfig("train_audit", seq_len=32, global_batch=8,
                        kind="train")
    try:
        mesh = make_host_mesh()
        with mesh:
            state_sds = I.state_inputs(mcfg, fed, run, mesh)
            batch_sds = I.train_inputs(mcfg, shape, fed, mesh, False)
            step = make_train_step(mcfg, fed, run)
            jaxpr = jax.make_jaxpr(step)(state_sds, batch_sds).jaxpr
    except Exception as e:                       # pragma: no cover
        return [_finding("trace-accumulation-dtype",
                         "src/repro/launch/train.py",
                         f"pod engine audit could not trace: {e!r}",
                         "pod.train_step")]
    for v in scan_carry_dtype_violations(jaxpr, "pod.train_step"):
        findings.append(_finding(
            "trace-accumulation-dtype", "src/repro/launch/train.py", v,
            "pod.train_step"))
    return findings


# --------------------------------------------------------------------------
# audit: retrace
# --------------------------------------------------------------------------

# The engine round matrix the retrace audit covers: the uplink codec
# families × the downlink codec families that exercise distinct trace
# paths.  Kept small enough for the CI job; the full bit-parity
# cross-product is the engine-parity matrix's job.
RETRACE_MATRIX = (
    ("sync", {}),
    ("sync", {"compressor": "topk", "topk_frac": 0.5,
              "error_feedback": True}),
    ("sync", {"compressor": "topk", "topk_frac": 0.5,
              "error_feedback": True, "sparse_uplink": True}),
    ("sync", {"downlink_compressor": "delta"}),
    # stateful (lossy) downlink: the sync engine's broadcast runs through
    # the ReferenceStore's jit'd bcast_fn — one trace, like the async one
    ("sync", {"downlink_compressor": "delta+qsgd", "downlink_qsgd_bits": 8}),
    ("async", {}),
    ("async", {"downlink_compressor": "delta", "compressor": "qsgd",
               "qsgd_bits": 4}),
)


def audit_retrace(matrix: Sequence = RETRACE_MATRIX,
                  include_pod: bool = True) -> List[Finding]:
    """Run two+ rounds per engine config; every jit'd round-path function
    must hold exactly one trace afterwards."""
    findings: List[Finding] = []
    for engine, fed_kwargs in matrix:
        kv = ",".join(f"{k}={v}" for k, v in sorted(fed_kwargs.items()))
        ctxname = f"{engine}:{kv or 'default'}"
        try:
            if engine == "sync":
                s = _build_sync(fed_kwargs)
                s.run(rounds=2)
                # bcast_fn only traces for the stateful (lossy) downlink —
                # a stateless config leaves its cache empty, which the ≤1
                # check accepts
                jit_fns = {"round_fn": s._round_fn,
                           "bcast_fn": (s._bcast_fn, 1)}
                path = "src/repro/federated/simulator.py"
            else:
                s = _build_async(fed_kwargs)
                s.run(rounds=2)
                # the vmapped client fn legitimately traces once per
                # DISTINCT dispatch-wave size (the initial in-flight wave
                # vs the buffered-K redispatch); one trace per shape is the
                # contract, one per *call* would be a config leak.  Wave
                # sizes are the maximal runs of consecutive dispatch
                # events sharing (time, version).
                waves, run_key = [], None
                for kind, t, _c, v in s.event_log:
                    if kind != "dispatch":
                        run_key = None
                        continue
                    if (t, v) == run_key:
                        waves[-1] += 1
                    else:
                        waves.append(1)
                        run_key = (t, v)
                jit_fns = {"deltas_fn": (s._deltas_fn, len(set(waves))),
                           "apply_fn": (s._apply_fn, 1),
                           "bcast_fn": (s._bcast_fn, 1)}
                path = "src/repro/federated/async_engine.py"
        except Exception as e:
            findings.append(_finding(
                "trace-retrace", "src/repro/analysis/trace_audit.py",
                f"engine {ctxname} failed to run: {e!r}", ctxname))
            continue
        for name, fn in jit_fns.items():
            fn, allowed = fn if isinstance(fn, tuple) else (fn, 1)
            n = fn._cache_size()
            if n > allowed:
                findings.append(_finding(
                    "trace-retrace", path,
                    f"{name} holds {n} traces after identically-shaped "
                    f"rounds ({ctxname}, {allowed} distinct input shape(s))"
                    f" — a config or weak type leaked into the traced "
                    f"signature", ctxname))
    if include_pod:
        findings.extend(_audit_pod_retrace())
    return findings


def _audit_pod_retrace() -> List[Finding]:
    import jax
    import jax.numpy as jnp
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import init_state, make_train_step

    findings: List[Finding] = []
    mcfg, fed, run = _pod_configs()
    try:
        with make_host_mesh():
            state = init_state(jax.random.PRNGKey(0), mcfg, fed, run)
            toks = jnp.zeros((1, 2, 2, 2, 32), jnp.int32)
            batch = {"tokens": toks, "labels": toks}
            step = jax.jit(make_train_step(mcfg, fed, run))
            state, _ = step(state, batch)
            state, _ = step(state, batch)
            n = step._cache_size()
        if n != 1:
            findings.append(_finding(
                "trace-retrace", "src/repro/launch/train.py",
                f"pod train_step holds {n} traces after 2 identical calls",
                "pod:default"))
    except Exception as e:
        findings.append(_finding(
            "trace-retrace", "src/repro/launch/train.py",
            f"pod retrace audit could not run: {e!r}", "pod:default"))
    return findings


# --------------------------------------------------------------------------
# audit: kernel coverage
# --------------------------------------------------------------------------

def _pallas_exports(ops_path: str) -> Set[str]:
    """Top-level defs in ops.py whose body threads an ``interpret=``
    lowering switch — the Pallas-backed surface."""
    with open(ops_path) as f:
        tree = ast.parse(f.read())
    out: Set[str] = set()
    for node in tree.body:
        if not isinstance(node, ast.FunctionDef):
            continue
        for n in ast.walk(node):
            if isinstance(n, ast.keyword) and n.arg == "interpret":
                out.add(node.name)
                break
    return out


# ops.py export -> the ref.py oracle name when they differ
KERNEL_ORACLE_ALIASES = {
    "qsgd_compress_leaf": "qsgd_quantize",
    "topk_compress_leaf": "topk_threshold_select",
}


def audit_kernel_coverage(root: str) -> List[Finding]:
    findings: List[Finding] = []
    ops_path = os.path.join(root, "src/repro/kernels/ops.py")
    ref_path = os.path.join(root, "src/repro/kernels/ref.py")
    test_path = os.path.join(root, "tests/test_kernels.py")
    if not (os.path.exists(ops_path) and os.path.exists(ref_path)):
        return [_finding("trace-kernel-coverage", "src/repro/kernels/ops.py",
                         "kernels/ops.py or kernels/ref.py missing",
                         "kernel-coverage")]
    with open(ref_path) as f:
        ref_names = {n.name for n in ast.parse(f.read()).body
                     if isinstance(n, ast.FunctionDef)}
    test_src = ""
    if os.path.exists(test_path):
        with open(test_path) as f:
            test_src = f.read()
    for name in sorted(_pallas_exports(ops_path)):
        oracle = KERNEL_ORACLE_ALIASES.get(name, name)
        if oracle not in ref_names:
            findings.append(_finding(
                "trace-kernel-coverage", "src/repro/kernels/ref.py",
                f"Pallas export ops.{name} has no ref.py oracle "
                f"(expected `{oracle}`)", name))
        if name not in test_src and oracle not in test_src:
            findings.append(_finding(
                "trace-kernel-coverage", "tests/test_kernels.py",
                f"Pallas export ops.{name} has no parity test in "
                f"tests/test_kernels.py", name))
    return findings


# --------------------------------------------------------------------------
# entry point
# --------------------------------------------------------------------------

def run_trace_audits(root: str, include_retrace: bool = True
                     ) -> List[Finding]:
    findings: List[Finding] = []
    findings.extend(audit_kernel_coverage(root))
    findings.extend(audit_accumulation_dtype())
    if include_retrace:
        findings.extend(audit_retrace())
    return findings
