"""The committed suppression baseline (``analysis_baseline.json``).

The baseline is the ONLY way to suppress a finding — there are no inline
``# noqa`` escapes, so every accepted violation is visible in one reviewed
file with a written reason.  Each entry carries the finding's
line-number-free identity key plus a mandatory ``reason``.

Baselines must stay *minimal*: entries that no longer match any current
finding are "stale" and fail ``--require-clean`` (and a tier-1 test), so
fixed code can't leave ghost suppressions behind.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Tuple

from repro.analysis.findings import Finding

BASELINE_VERSION = 1

Key = Tuple[str, str, str, str]


@dataclasses.dataclass
class Baseline:
    entries: List[dict] = dataclasses.field(default_factory=list)
    path: str = ""

    def keys(self) -> Dict[Key, dict]:
        out: Dict[Key, dict] = {}
        for e in self.entries:
            out[(e["rule"], e["path"], e.get("context", ""),
                 e.get("snippet", ""))] = e
        return out

    def apply(self, findings: List[Finding]):
        """Split findings into (new, suppressed) and report stale entries.

        Returns ``(new, suppressed, stale)`` where ``stale`` is the list of
        baseline entries that matched nothing.
        """
        keymap = self.keys()
        hit = set()
        new, suppressed = [], []
        for f in findings:
            k = f.key()
            if k in keymap:
                hit.add(k)
                suppressed.append(dataclasses.replace(f, suppressed=True))
            else:
                new.append(f)
        stale = [e for k, e in keymap.items() if k not in hit]
        return new, suppressed, stale


def load_baseline(path: str) -> Baseline:
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        return Baseline(entries=[], path=path)
    if data.get("version") != BASELINE_VERSION:
        raise ValueError(f"{path}: unsupported baseline version "
                         f"{data.get('version')!r}")
    entries = data.get("entries", [])
    for i, e in enumerate(entries):
        for field in ("rule", "path", "snippet", "reason"):
            if not isinstance(e.get(field), str) or not e.get(field):
                raise ValueError(
                    f"{path}: entry {i} missing/empty field {field!r} "
                    f"(every suppression needs a written reason)")
    return Baseline(entries=entries, path=path)


def write_baseline(path: str, findings: List[Finding],
                   reason: str = "TODO: justify this suppression") -> None:
    """Emit a baseline file covering ``findings`` (used by ``--update``)."""
    entries = [
        {"rule": f.rule, "path": f.path, "context": f.context,
         "snippet": f.snippet, "reason": reason}
        for f in findings
    ]
    with open(path, "w") as fp:
        json.dump({"version": BASELINE_VERSION, "entries": entries},
                  fp, indent=2, sort_keys=False)
        fp.write("\n")
