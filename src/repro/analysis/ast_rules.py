"""Layer 1 — AST checkers encoding the repo's shipped bug classes.

Each rule is a pure function over one parsed module; the registry maps rule
ids to checkers.  Rules (the PR that shipped each bug class in brackets):

* ``truthiness-on-config`` [PR 2] — ``if cfg.x:`` / ``x or default`` where
  ``x`` is a *numeric* config field: 0 / 0.0 are valid values (``rounds=0``,
  ``buffer_k=0``, ``topk_frac=0.0``) and truthiness silently rewrites them.
* ``low-precision-accumulation`` [PR 5] — ``jnp.sum``/``dot``/``tensordot``/
  ``einsum``/``scan`` consuming bf16/fp8 operands with no fp32 cast,
  ``preferred_element_type`` or fp32 ``dtype=`` in sight: bf16 sums lose
  late clients once ``Σw`` grows past the mantissa.
* ``unkeyed-config-cache`` [PR 5] — ``lru_cache`` on a function whose
  parameters are not all hashable scalars or frozen config dataclasses:
  the cache key silently under- or over-keys the trace.
* ``host-sync-in-jit`` [PR 2/6] — ``float()``/``.item()``/``np.*``/
  ``device_get``/``print`` reachable from a jit-traced body (decorated,
  or returned by a ``_make_*`` factory that is ``jax.jit``-ed).
* ``timer-without-barrier`` [PR 6] — a ``time.time()``/``perf_counter()``
  interval in ``benchmarks/`` with no ``block_until_ready``/``device_get``
  between start and stop: on an async backend the clock stops before the
  device finishes.
* ``unbounded-host-accumulator`` [PR 6] — a ``self.x = [] / {}`` attribute
  that is appended to in engine loops and never reset/bounded (the async
  engine's ``staleness_seen`` class).
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Callable, Dict, List, Optional, Set, Tuple

from repro.analysis.findings import Finding

# --------------------------------------------------------------------------
# Repo context: config-field and frozen-config introspection
# --------------------------------------------------------------------------

# fallback sets, used only if the configs package cannot be imported (the
# live introspection below is the source of truth)
_FALLBACK_NUMERIC_FIELDS = frozenset({
    "rounds", "eval_every", "local_steps", "buffer_k", "head_dim",
    "sliding_window", "topk_frac", "qsgd_bits", "n_patch_tokens", "d_ff",
})
_FALLBACK_FROZEN_CONFIGS = frozenset({
    "ModelConfig", "FedConfig", "HeteroConfig", "ShapeConfig", "RunConfig",
    "MoEConfig", "MLAConfig", "SSMConfig", "SimConfig",
})

_SCALAR_ANNOTATIONS = {
    "int", "float", "str", "bool", "bytes", "None", "Optional",
    "Tuple", "tuple", "FrozenSet", "frozenset",
}


def _numeric_annotation(ann: str) -> bool:
    ann = ann.strip()
    return ann in ("int", "float", "Optional[int]", "Optional[float]")


@dataclasses.dataclass(frozen=True)
class RepoContext:
    """Facts about the repo the rules key on (field names, frozen configs)."""
    numeric_fields: frozenset
    frozen_configs: frozenset


def build_context() -> RepoContext:
    """Introspect the live config dataclasses for numeric field names and
    frozen (hashable, jit-static-safe) config class names."""
    numeric: Set[str] = set()
    frozen: Set[str] = set()
    try:
        import repro.configs.base as cfgmod
        from repro.federated.simulator import SimConfig
        candidates = [getattr(cfgmod, n) for n in dir(cfgmod)
                      if isinstance(getattr(cfgmod, n), type)]
        candidates.append(SimConfig)
        for cls in candidates:
            if not dataclasses.is_dataclass(cls):
                continue
            if getattr(cls, "__dataclass_params__").frozen:
                frozen.add(cls.__name__)
            for f in dataclasses.fields(cls):
                ann = f.type if isinstance(f.type, str) else getattr(
                    f.type, "__name__", str(f.type))
                if _numeric_annotation(str(ann)):
                    numeric.add(f.name)
    except Exception:
        return RepoContext(_FALLBACK_NUMERIC_FIELDS, _FALLBACK_FROZEN_CONFIGS)
    return RepoContext(frozenset(numeric), frozenset(frozen))


# --------------------------------------------------------------------------
# Shared AST plumbing
# --------------------------------------------------------------------------

class _Scoped(ast.NodeVisitor):
    """Visitor that tracks the enclosing Class.func dotted chain."""

    def __init__(self):
        self.stack: List[str] = []

    @property
    def context(self) -> str:
        return ".".join(self.stack)

    def visit_ClassDef(self, node):
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    def visit_FunctionDef(self, node):
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef


def _snippet(src_lines: List[str], lineno: int) -> str:
    if 1 <= lineno <= len(src_lines):
        return src_lines[lineno - 1].strip()
    return ""


def _mk(rule: str, path: str, node: ast.AST, message: str, context: str,
        src_lines: List[str]) -> Finding:
    line = getattr(node, "lineno", 1)
    return Finding(rule=rule, path=path, line=line, message=message,
                   context=context, snippet=_snippet(src_lines, line))


def _attr_tokens(node: ast.AST) -> Set[str]:
    """All Name ids and Attribute attrs in a subtree."""
    out: Set[str] = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            out.add(n.id)
        elif isinstance(n, ast.Attribute):
            out.add(n.attr)
        elif isinstance(n, ast.Constant) and isinstance(n.value, str):
            out.add(n.value)
    return out


def _dotted(node: ast.AST) -> str:
    """'a.b.c' for Attribute/Name chains, '' otherwise."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


# --------------------------------------------------------------------------
# Rule registry
# --------------------------------------------------------------------------

RULES: Dict[str, Callable] = {}


def rule(rule_id: str):
    def deco(fn):
        RULES[rule_id] = fn
        return fn
    return deco


# --------------------------------------------------------------------------
# truthiness-on-config
# --------------------------------------------------------------------------

@rule("truthiness-on-config")
def check_truthiness(tree: ast.Module, path: str, src_lines: List[str],
                     ctx: RepoContext) -> List[Finding]:
    fields = ctx.numeric_fields
    findings: List[Finding] = []

    class V(_Scoped):
        def _flag(self, expr, how):
            name = expr.attr if isinstance(expr, ast.Attribute) else expr.id
            findings.append(_mk(
                "truthiness-on-config", path, expr,
                f"truthiness on numeric config field {name!r} ({how}): "
                f"0/0.0 are valid values — compare explicitly "
                f"(`is None` / `> 0`)", self.context, src_lines))

        def _bool_ctx(self, expr):
            # `a or default` — only the tested (non-final) operands of an
            # `or`, every operand of an `and`, plain attr/name tests
            if isinstance(expr, ast.BoolOp):
                vals = expr.values
                tested = vals[:-1] if isinstance(expr.op, ast.Or) else vals
                for v in tested:
                    self._bool_ctx(v)
                return
            if isinstance(expr, ast.UnaryOp) and isinstance(expr.op, ast.Not):
                self._bool_ctx(expr.operand)
                return
            if isinstance(expr, ast.Attribute) and expr.attr in fields:
                self._flag(expr, "boolean test")
            elif isinstance(expr, ast.Name) and expr.id in fields:
                self._flag(expr, "boolean test")

        def visit_If(self, node):
            self._bool_ctx(node.test)
            self.generic_visit(node)

        def visit_While(self, node):
            self._bool_ctx(node.test)
            self.generic_visit(node)

        def visit_IfExp(self, node):
            self._bool_ctx(node.test)
            self.generic_visit(node)

        def visit_Assert(self, node):
            self._bool_ctx(node.test)
            self.generic_visit(node)

        def visit_comprehension(self, node):
            for if_ in node.ifs:
                self._bool_ctx(if_)
            self.generic_visit(node)

        def visit_BoolOp(self, node):
            # value-position `x or default` (assignments, call args, ...)
            if isinstance(node.op, ast.Or):
                for v in node.values[:-1]:
                    if isinstance(v, ast.Attribute) and v.attr in fields:
                        self._flag(v, "`or` default")
                    elif isinstance(v, ast.Name) and v.id in fields:
                        self._flag(v, "`or` default")
            self.generic_visit(node)

    V().visit(tree)
    return findings


# --------------------------------------------------------------------------
# low-precision-accumulation
# --------------------------------------------------------------------------

_LOW_TOKENS = {"bfloat16", "bf16", "float16", "fp16", "float8_e4m3",
               "float8_e4m3fn", "float8_e5m2", "fp8", "int8"}
_SAFE_TOKENS = {"float32", "float64", "f32", "promote_types",
                "preferred_element_type", "result_type"}
_REDUCE_ATTRS = {"sum", "dot", "matmul", "tensordot", "einsum", "vdot",
                 "cumsum", "scan"}


@rule("low-precision-accumulation")
def check_low_precision(tree: ast.Module, path: str, src_lines: List[str],
                        ctx: RepoContext) -> List[Finding]:
    findings: List[Finding] = []

    class V(_Scoped):
        def __init__(self):
            super().__init__()
            # one-level local-name resolution: x = <expr> lets the checker
            # see through `acc_t = jnp.promote_types(...)` then
            # `.astype(acc_t)`
            self.local_tokens: Dict[str, Set[str]] = {}

        def visit_Assign(self, node):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    self.local_tokens[t.id] = _attr_tokens(node.value)
            self.generic_visit(node)

        def visit_Call(self, node):
            attr = node.func.attr if isinstance(node.func, ast.Attribute) \
                else (node.func.id if isinstance(node.func, ast.Name)
                      else "")
            if attr in _REDUCE_ATTRS:
                tokens = _attr_tokens(node)
                # resolve one level of local assignments
                for t in list(tokens):
                    tokens |= self.local_tokens.get(t, set())
                if tokens & _LOW_TOKENS and not tokens & _SAFE_TOKENS:
                    low = ", ".join(sorted(tokens & _LOW_TOKENS))
                    findings.append(_mk(
                        "low-precision-accumulation", path, node,
                        f"`{attr}` accumulates over {low} operands with no "
                        f"fp32 cast / preferred_element_type — low-precision "
                        f"sums lose small contributions (PR 5 class)",
                        self.context, src_lines))
            self.generic_visit(node)

    V().visit(tree)
    return findings


# --------------------------------------------------------------------------
# unkeyed-config-cache
# --------------------------------------------------------------------------

_CONFIGISH = ("cfg", "config", "params", "tree", "state", "fed", "template")


def _is_cache_decorator(dec: ast.AST) -> bool:
    target = dec.func if isinstance(dec, ast.Call) else dec
    name = _dotted(target)
    return name.split(".")[-1] in ("lru_cache", "cache")


@rule("unkeyed-config-cache")
def check_unkeyed_cache(tree: ast.Module, path: str, src_lines: List[str],
                        ctx: RepoContext) -> List[Finding]:
    findings: List[Finding] = []
    frozen = ctx.frozen_configs

    class V(_Scoped):
        def visit_FunctionDef(self, node):
            if any(_is_cache_decorator(d) for d in node.decorator_list):
                args = list(node.args.posonlyargs) + list(node.args.args) \
                    + list(node.args.kwonlyargs)
                for a in args:
                    if a.arg in ("self", "cls"):
                        continue
                    if a.annotation is not None:
                        tokens = _attr_tokens(a.annotation)
                        if tokens & frozen:
                            continue        # frozen config: a sound key
                        if tokens <= _SCALAR_ANNOTATIONS:
                            continue        # hashable scalar key
                        findings.append(_mk(
                            "unkeyed-config-cache", path, node,
                            f"lru_cache parameter {a.arg!r} annotated "
                            f"{ast.unparse(a.annotation)!r} is not a frozen "
                            f"config or hashable scalar — the cache key may "
                            f"not cover the wire-relevant fields (PR 5 "
                            f"class)", self.context, src_lines))
                    elif a.arg.endswith(_CONFIGISH):
                        findings.append(_mk(
                            "unkeyed-config-cache", path, node,
                            f"lru_cache parameter {a.arg!r} is unannotated "
                            f"and config-like — key the cache on explicit "
                            f"frozen scalars (PR 5 class)",
                            self.context, src_lines))
            _Scoped.visit_FunctionDef(self, node)

    V().visit(tree)
    return findings


# --------------------------------------------------------------------------
# host-sync-in-jit
# --------------------------------------------------------------------------

_SYNC_CALLS = {"item", "block_until_ready", "device_get", "asarray",
               "array", "print"}
_HOST_MODULES = {"np", "numpy", "time"}


def _is_jit_expr(node: ast.AST) -> bool:
    """`jax.jit`, `jit`, or functools.partial(jax.jit, ...)."""
    name = _dotted(node)
    if name.split(".")[-1] == "jit":
        return True
    if isinstance(node, ast.Call) and _dotted(node.func).endswith("partial"):
        return any(_is_jit_expr(a) for a in node.args)
    return False


def _returned_inner_defs(maker: ast.FunctionDef) -> List[ast.FunctionDef]:
    """Nested defs a factory returns (`def f(): ... ; return f`)."""
    inner = {n.name: n for n in maker.body
             if isinstance(n, ast.FunctionDef)}
    out = []
    for n in ast.walk(maker):
        if isinstance(n, ast.Return) and isinstance(n.value, ast.Name) \
                and n.value.id in inner:
            out.append(inner[n.value.id])
    return out


@rule("host-sync-in-jit")
def check_host_sync(tree: ast.Module, path: str, src_lines: List[str],
                    ctx: RepoContext) -> List[Finding]:
    findings: List[Finding] = []

    # every function node with its enclosing maker (if nested)
    makers: Dict[str, ast.FunctionDef] = {}
    for n in ast.walk(tree):
        if isinstance(n, ast.FunctionDef):
            makers[n.name] = n

    traced: Set[ast.FunctionDef] = set()

    # (a) jit-decorated defs
    for fn in makers.values():
        if any(_is_jit_expr(d if not isinstance(d, ast.Call) else d)
               for d in fn.decorator_list):
            traced.add(fn)

    # (b) jax.jit(<maker>()) / jax.jit(<fn>) anywhere in the module
    def _mark_jit_arg(arg: ast.AST):
        if isinstance(arg, ast.Name) and arg.id in makers:
            traced.add(makers[arg.id])
        elif isinstance(arg, ast.Call):
            mname = _dotted(arg.func).split(".")[-1]
            if mname in makers:
                traced.update(_returned_inner_defs(makers[mname]))

    for n in ast.walk(tree):
        if isinstance(n, ast.Call) and _is_jit_expr(n.func):
            for a in n.args:
                _mark_jit_arg(a)

    # (c) fixpoint over cross-maker closures: inside a maker whose inner def
    # is traced, `f = self._make_x()` binds f to _make_x's returned def; if
    # the traced def calls f, that returned def is traced too
    changed = True
    while changed:
        changed = False
        for maker in makers.values():
            inner_traced = [d for d in maker.body
                            if isinstance(d, ast.FunctionDef) and d in traced]
            if not inner_traced:
                continue
            bindings: Dict[str, str] = {}
            for st in maker.body:
                if isinstance(st, ast.Assign) and len(st.targets) == 1 \
                        and isinstance(st.targets[0], ast.Name) \
                        and isinstance(st.value, ast.Call):
                    mname = _dotted(st.value.func).split(".")[-1]
                    if mname in makers:
                        bindings[st.targets[0].id] = mname
            for tfn in inner_traced:
                for n in ast.walk(tfn):
                    if isinstance(n, ast.Call) and isinstance(n.func,
                                                              ast.Name) \
                            and n.func.id in bindings:
                        for d in _returned_inner_defs(
                                makers[bindings[n.func.id]]):
                            if d not in traced:
                                traced.add(d)
                                changed = True

    # nested defs inside traced defs are traced
    stack = list(traced)
    while stack:
        fn = stack.pop()
        for n in ast.walk(fn):
            if isinstance(n, ast.FunctionDef) and n is not fn \
                    and n not in traced:
                traced.add(n)
                stack.append(n)

    for fn in sorted(traced, key=lambda f: f.lineno):
        for n in ast.walk(fn):
            if not isinstance(n, ast.Call):
                continue
            dn = _dotted(n.func)
            leaf = dn.split(".")[-1]
            root = dn.split(".")[0] if dn else ""
            bad = None
            if leaf in ("float", "int", "bool") and dn == leaf and n.args \
                    and not isinstance(n.args[0], ast.Constant):
                bad = f"{leaf}() forces a host sync"
            elif leaf == "item":
                bad = ".item() forces a host sync"
            elif root in _HOST_MODULES:
                bad = f"host call {dn}() inside a traced body"
            elif leaf in ("device_get", "block_until_ready", "print"):
                bad = f"{leaf}() inside a traced body"
            if bad:
                findings.append(_mk(
                    "host-sync-in-jit", path, n,
                    f"{bad} in jit-traced `{fn.name}` — move it to the "
                    f"round's single sanctioned device_get",
                    fn.name, src_lines))
    return findings


# --------------------------------------------------------------------------
# timer-without-barrier (benchmarks/ only)
# --------------------------------------------------------------------------

_CLOCKS = {"time.time", "time.perf_counter", "time.monotonic"}
_BARRIER_TOKENS = ("block_until_ready", "device_get")


@rule("timer-without-barrier")
def check_timer_barrier(tree: ast.Module, path: str, src_lines: List[str],
                        ctx: RepoContext) -> List[Finding]:
    if not (path.startswith("benchmarks/") or "/benchmarks/" in path):
        return []
    findings: List[Finding] = []

    class V(_Scoped):
        def visit_FunctionDef(self, node):
            starts: Dict[str, int] = {}
            for n in ast.walk(node):
                if isinstance(n, ast.Assign) and len(n.targets) == 1 \
                        and isinstance(n.targets[0], ast.Name) \
                        and isinstance(n.value, ast.Call) \
                        and _dotted(n.value.func) in _CLOCKS:
                    starts[n.targets[0].id] = n.lineno
            for n in ast.walk(node):
                if isinstance(n, ast.BinOp) and isinstance(n.op, ast.Sub) \
                        and isinstance(n.right, ast.Name) \
                        and n.right.id in starts \
                        and isinstance(n.left, ast.Call) \
                        and _dotted(n.left.func) in _CLOCKS:
                    start, stop = starts[n.right.id], n.lineno
                    window = "\n".join(src_lines[start:stop])
                    if not any(t in window for t in _BARRIER_TOKENS):
                        findings.append(_mk(
                            "timer-without-barrier", path, n,
                            f"timed interval started at line {start} stops "
                            f"with no block_until_ready/device_get between "
                            f"— async dispatch makes the measurement a "
                            f"lower bound", self.context, src_lines))
            _Scoped.visit_FunctionDef(self, node)

    V().visit(tree)
    return findings


# --------------------------------------------------------------------------
# unbounded-host-accumulator
# --------------------------------------------------------------------------

def _is_growable_literal(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set)):
        return True
    if not isinstance(node, ast.Call):
        return False
    name = _dotted(node.func)
    if name in ("list", "dict", "set", "OrderedDict",
                "collections.OrderedDict", "defaultdict",
                "collections.defaultdict"):
        return True
    if name in ("deque", "collections.deque"):
        # deque(maxlen=N) (or the two-positional form) is the bounded
        # structure this rule asks for — only an unbounded deque grows.
        # Fleet-package eviction/spill bookkeeping must be one of: a
        # maxlen deque, or page-table-bounded (popped on eviction/load).
        bounded = any(kw.arg == "maxlen" for kw in node.keywords) \
            or len(node.args) >= 2
        return not bounded
    return False


@rule("unbounded-host-accumulator")
def check_unbounded_accumulator(tree: ast.Module, path: str,
                                src_lines: List[str],
                                ctx: RepoContext) -> List[Finding]:
    findings: List[Finding] = []

    for cls in [n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)]:
        inits: Dict[str, ast.AST] = {}        # attr -> first growable bind
        rebinds: Dict[str, int] = {}          # attr -> rebind count
        appends: Dict[str, ast.AST] = {}      # attr -> first append site
        clears: Set[str] = set()

        for n in ast.walk(cls):
            if isinstance(n, (ast.Assign, ast.AnnAssign)):
                targets = n.targets if isinstance(n, ast.Assign) \
                    else [n.target]
                value = n.value
                for t in targets:
                    if isinstance(t, ast.Attribute) \
                            and isinstance(t.value, ast.Name) \
                            and t.value.id == "self":
                        if value is not None and _is_growable_literal(value):
                            if t.attr in inits:
                                rebinds[t.attr] = rebinds.get(t.attr, 0) + 1
                            else:
                                inits[t.attr] = n
                        elif t.attr in inits:
                            rebinds[t.attr] = rebinds.get(t.attr, 0) + 1
            elif isinstance(n, ast.Call) \
                    and isinstance(n.func, ast.Attribute):
                obj = n.func.value
                if isinstance(obj, ast.Attribute) \
                        and isinstance(obj.value, ast.Name) \
                        and obj.value.id == "self":
                    if n.func.attr in ("append", "extend", "insert",
                                       "setdefault", "update", "add",
                                       "appendleft"):
                        appends.setdefault(obj.attr, n)
                    elif n.func.attr in ("clear", "pop", "popleft",
                                         "popitem", "discard", "remove"):
                        clears.add(obj.attr)

        for attr, site in appends.items():
            if attr in inits and attr not in clears \
                    and rebinds.get(attr, 0) == 0:
                findings.append(_mk(
                    "unbounded-host-accumulator", path, site,
                    f"self.{attr} grows without reset/bound across the "
                    f"{cls.name} lifetime — use a bounded structure or "
                    f"reset per run (PR 6 staleness_seen class)",
                    cls.name, src_lines))
    return findings


# --------------------------------------------------------------------------
# Runner
# --------------------------------------------------------------------------

def run_ast_rules(root: str, targets: Tuple[str, ...] = ("src", "benchmarks",
                                                         "examples"),
                  ctx: Optional[RepoContext] = None,
                  rules: Optional[Dict[str, Callable]] = None
                  ) -> List[Finding]:
    """Run every registered rule over the target trees; returns findings."""
    import os

    ctx = build_context() if ctx is None else ctx
    rules = RULES if rules is None else rules
    findings: List[Finding] = []
    for target in targets:
        base = os.path.join(root, target)
        if not os.path.isdir(base):
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in ("__pycache__", ".git"))
            for fname in sorted(filenames):
                if not fname.endswith(".py"):
                    continue
                full = os.path.join(dirpath, fname)
                rel = os.path.relpath(full, root).replace(os.sep, "/")
                findings.extend(check_source_file(full, rel, ctx, rules))
    return findings


def check_source_file(full_path: str, rel_path: str,
                      ctx: Optional[RepoContext] = None,
                      rules: Optional[Dict[str, Callable]] = None
                      ) -> List[Finding]:
    ctx = build_context() if ctx is None else ctx
    rules = RULES if rules is None else rules
    with open(full_path) as f:
        src = f.read()
    return check_source(src, rel_path, ctx, rules)


def check_source(src: str, rel_path: str, ctx: Optional[RepoContext] = None,
                 rules: Optional[Dict[str, Callable]] = None
                 ) -> List[Finding]:
    """Run the rules over one source string (tests feed fixtures here)."""
    ctx = build_context() if ctx is None else ctx
    rules = RULES if rules is None else rules
    tree = ast.parse(src)
    src_lines = src.splitlines()
    findings: List[Finding] = []
    for checker in rules.values():
        findings.extend(checker(tree, rel_path, src_lines, ctx))
    return findings
