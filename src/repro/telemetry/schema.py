"""The telemetry event schema — validated at emit time and by the CI smoke
job (DESIGN.md §Telemetry documents the same schema in prose; this module
is the executable source of truth).

Every event is one JSON object (one JSONL line) with the common envelope

    {"ts": <float unix seconds>, "kind": <str>, "engine": <str>, ...}

and per-kind required fields:

    round    — round (int), metrics (dict[str, number]): the in-jit drift
               diagnostics fetched once per round
    eval     — round (int), acc (number), loss (number)
    request  — rid (int), n_tokens (int), ttft_s/e2e_s (number),
               itl_s (number or null: single-token requests have no
               inter-token gap)
    summary  — counters (dict[str, number]); spans / latency / drift /
               histograms ride as optional structured extras

Unknown extra fields are allowed everywhere (the schema is a floor, not a
ceiling); unknown *kinds* are rejected so producers cannot silently fork
the vocabulary.  ``python -m repro.telemetry.schema file.jsonl ...``
validates emitted files — the CI telemetry-smoke job runs it over the
examples' exports.
"""
from __future__ import annotations

import json
import sys
from typing import Dict

_NUM = (int, float)

# kind -> {field: type tuple (None entry means nullable)}
EVENT_SCHEMA: Dict[str, Dict[str, tuple]] = {
    "round": {"round": (int,), "metrics": (dict,)},
    "eval": {"round": (int,), "acc": _NUM, "loss": _NUM},
    "request": {"rid": (int,), "n_tokens": (int,), "ttft_s": _NUM,
                "itl_s": _NUM + (type(None),), "e2e_s": _NUM},
    "summary": {"counters": (dict,)},
    # static-analysis findings (repro.analysis) ride the same envelope so
    # the CI artifact is consumable by any telemetry JSONL reader
    "finding": {"rule": (str,), "path": (str,), "line": (int,),
                "message": (str,)},
}


def validate_event(event: dict) -> None:
    """Raise ``ValueError`` unless ``event`` satisfies the schema."""
    if not isinstance(event, dict):
        raise ValueError(f"event must be a JSON object, got "
                         f"{type(event).__name__}")
    for field, types in (("ts", _NUM), ("kind", (str,)), ("engine", (str,))):
        if field not in event:
            raise ValueError(f"event missing required field {field!r}: "
                             f"{event!r}")
        if not isinstance(event[field], types) \
                or isinstance(event[field], bool):
            raise ValueError(f"event field {field!r} has wrong type "
                             f"{type(event[field]).__name__}: {event!r}")
    kind = event["kind"]
    if kind not in EVENT_SCHEMA:
        raise ValueError(f"unknown event kind {kind!r}; known: "
                         f"{', '.join(sorted(EVENT_SCHEMA))}")
    for field, types in EVENT_SCHEMA[kind].items():
        if field not in event:
            raise ValueError(f"{kind!r} event missing field {field!r}: "
                             f"{event!r}")
        v = event[field]
        if not isinstance(v, types) or (isinstance(v, bool)
                                        and bool not in types):
            raise ValueError(f"{kind!r} event field {field!r} has wrong "
                             f"type {type(v).__name__}: {event!r}")
    if kind == "round":
        for k, v in event["metrics"].items():
            if not isinstance(v, _NUM) or isinstance(v, bool):
                raise ValueError(f"round metric {k!r} must be numeric, got "
                                 f"{type(v).__name__}")


def validate_jsonl(path: str) -> int:
    """Validate every line of a JSONL export; returns the event count."""
    n = 0
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                event = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{lineno}: not valid JSON: {e}")
            try:
                validate_event(event)
            except ValueError as e:
                raise ValueError(f"{path}:{lineno}: {e}")
            n += 1
    if n == 0:
        raise ValueError(f"{path}: no events (telemetry export was empty)")
    return n


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print("usage: python -m repro.telemetry.schema FILE.jsonl ...",
              file=sys.stderr)
        return 2
    failed = False
    for path in argv:
        try:
            n = validate_jsonl(path)
            print(f"OK {path}: {n} events valid")
        except (OSError, ValueError) as e:
            failed = True
            print(f"INVALID {e}", file=sys.stderr)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
