"""Zero-dependency observability subsystem (DESIGN.md §Telemetry).

Three layers:

* :mod:`repro.telemetry.drift` — in-jit drift diagnostics, scalar
  reductions computed inside the round functions (cost: a few f32 scalars
  and one host fetch per round; disabled path bit-identical);
* :mod:`repro.telemetry.tracer` — host-side span tracing (``Tracer``,
  with ``block_until_ready`` boundaries) plus the ``Counters`` registry
  and bounded ``Histogram``;
* :mod:`repro.telemetry.export` / :mod:`~repro.telemetry.schema` /
  :mod:`~repro.telemetry.latency` — the JSONL sink, Prometheus text dump,
  the validated event schema, and serving latency percentiles.

``Telemetry`` (:mod:`repro.telemetry.core`) composes them; every engine
takes ``telemetry=`` and defaults to ``Telemetry.disabled()``.
"""
from repro.telemetry.core import Telemetry
from repro.telemetry.drift import (delta_dispersion, ef_residual_norm,
                                   momentum_alignment, round_metrics,
                                   streaming_dispersion, streaming_sq_norm,
                                   update_norm)
from repro.telemetry.export import JsonlSink, prometheus_text
from repro.telemetry.latency import latency_summary, request_itl
from repro.telemetry.schema import EVENT_SCHEMA, validate_event, validate_jsonl
from repro.telemetry.tracer import Counters, Histogram, Span, Tracer

__all__ = [
    "Telemetry",
    "Tracer", "Span", "Counters", "Histogram",
    "JsonlSink", "prometheus_text",
    "latency_summary", "request_itl",
    "EVENT_SCHEMA", "validate_event", "validate_jsonl",
    "round_metrics", "delta_dispersion", "momentum_alignment",
    "ef_residual_norm", "update_norm",
    "streaming_sq_norm", "streaming_dispersion",
]
