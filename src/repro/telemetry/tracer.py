"""Host-side span tracing + the unified counter registry (DESIGN.md
§Telemetry).

``Tracer`` times nested host-side phases with ``perf_counter`` around
explicit device-sync boundaries: a span is only meaningful where the host
actually waits for the device, so ``span(..., sync=tree)`` calls
``jax.block_until_ready`` on exit before the clock stops.  Spans attach at
the engines' real dispatch boundaries — ``round`` (one fused jit call in
the sync/pod engines), ``local_train`` / ``aggregate`` / ``transport.encode``
(the async engine's separate dispatch-group, flush, and broadcast calls),
``prefill_chunk`` / ``decode_step`` (the serving engine) — phases fused
inside one jit call cannot be separated without adding dispatches, and the
tracer never does.

``Counters`` is the one registry every byte/count statistic lives behind:
``Transport`` accounts its four wire counters straight into it (the
engines' pre-telemetry ad-hoc ints are now views over the registry) and
the serving engine publishes queue/slot gauges the same way.

``Histogram`` is the bounded summary that replaced the async engine's
unbounded ``staleness_seen`` list: fixed integer bins plus an overflow
bucket, with exact count/mean/max tracked alongside — O(bins) memory no
matter how many observations arrive.

Everything here is zero-dependency host Python; the disabled tracer's
``span`` is a shared no-op context manager, so telemetry-off engines pay
one attribute lookup per span site and touch no device state.
"""
from __future__ import annotations

import contextlib
import time
from collections import deque
from typing import Dict, Iterable, Optional


class _NullSpan:
    """Shared no-op span for the disabled tracer."""

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class Span:
    """One timed host-side phase.  ``sync`` (any pytree of jax arrays) is
    blocked on before the clock stops, so the duration covers the device
    work the phase dispatched, not just the Python that launched it."""

    __slots__ = ("tracer", "name", "sync", "t0")

    def __init__(self, tracer: "Tracer", name: str, sync=None):
        self.tracer = tracer
        self.name = name
        self.sync = sync
        self.t0 = 0.0

    def __enter__(self):
        self.tracer._stack.append(self.name)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        if self.sync is not None:
            import jax
            jax.block_until_ready(self.sync)
        dur = time.perf_counter() - self.t0
        self.tracer._stack.pop()
        self.tracer._record(self.name, dur)
        return False


class Tracer:
    """Nested span timing with bounded per-name duration reservoirs.

    Span names nest with ``/`` (a span opened inside another records as
    ``outer/inner``), and per-name statistics keep the most recent
    ``maxlen`` durations for percentiles plus exact count/total.
    """

    def __init__(self, enabled: bool = True, maxlen: int = 4096):
        self.enabled = enabled
        self.maxlen = maxlen
        self._stack: list = []
        self._durs: Dict[str, deque] = {}
        self._count: Dict[str, int] = {}
        self._total: Dict[str, float] = {}

    def span(self, name: str, sync=None):
        """Context manager timing one phase; no-op when disabled."""
        if not self.enabled:
            return _NULL_SPAN
        if self._stack:
            name = f"{self._stack[-1]}/{name}"
        return Span(self, name, sync)

    def _record(self, name: str, dur: float) -> None:
        if name not in self._durs:
            self._durs[name] = deque(maxlen=self.maxlen)
            self._count[name] = 0
            self._total[name] = 0.0
        self._durs[name].append(dur)
        self._count[name] += 1
        self._total[name] += dur

    def timings(self, name: str) -> list:
        """The retained durations (seconds) for one span name."""
        return list(self._durs.get(name, ()))

    def summary(self) -> Dict[str, Dict[str, float]]:
        """Per-span count/total and p50/p95 over the retained reservoir."""
        out = {}
        for name, durs in self._durs.items():
            s = sorted(durs)
            n = len(s)
            out[name] = {
                "count": self._count[name],
                "total_s": round(self._total[name], 6),
                "p50_s": round(s[n // 2], 6),
                "p95_s": round(s[min(n - 1, int(0.95 * n))], 6),
            }
        return out


class Counters:
    """Named monotonic counters and gauges — one snapshot-able registry.

    ``inc`` is the counter path (transport bytes, event counts); ``set``
    the gauge path (queue depth, slot occupancy).  Missing names read 0,
    so call sites never pre-register.
    """

    def __init__(self):
        self._c: Dict[str, float] = {}

    def inc(self, name: str, value: float = 1) -> None:
        self._c[name] = self._c.get(name, 0) + value

    def set(self, name: str, value: float) -> None:
        self._c[name] = value

    def get(self, name: str, default: float = 0):
        return self._c.get(name, default)

    def snapshot(self) -> Dict[str, float]:
        return dict(self._c)

    def __contains__(self, name: str) -> bool:
        return name in self._c


class Histogram:
    """Bounded integer histogram: bins ``0..n_bins-1`` plus an overflow
    bucket, with exact count / total / max tracked alongside so summary
    statistics stay exact even past the bound.  O(n_bins) memory for any
    number of observations — the replacement for keeping raw lists."""

    def __init__(self, n_bins: int = 32):
        if n_bins < 1:
            raise ValueError("Histogram needs at least one bin")
        self.n_bins = n_bins
        self.bins = [0] * n_bins
        self.overflow = 0
        self.count = 0
        self.total = 0
        self.max = 0

    def observe(self, value: int) -> None:
        v = int(value)
        if v < 0:
            raise ValueError(f"Histogram observes non-negative ints, got {v}")
        if v < self.n_bins:
            self.bins[v] += 1
        else:
            self.overflow += 1
        self.count += 1
        self.total += v
        if v > self.max:
            self.max = v

    def observe_many(self, values: Iterable[int]) -> None:
        for v in values:
            self.observe(v)

    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def reset(self) -> None:
        self.bins = [0] * self.n_bins
        self.overflow = 0
        self.count = 0
        self.total = 0
        self.max = 0

    def to_dict(self) -> Dict[str, object]:
        # trailing all-zero bins are trimmed so the export stays compact
        last = max((i for i, b in enumerate(self.bins) if b), default=-1)
        return {"bins": self.bins[:last + 1], "overflow": self.overflow,
                "count": self.count, "mean": round(self.mean(), 4),
                "max": self.max}
