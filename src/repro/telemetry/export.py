"""Telemetry exporters: JSONL event sink and Prometheus-style text dump
(DESIGN.md §Telemetry).

``JsonlSink`` writes one schema-validated JSON object per line — append-only,
flushed per event so a crashed run keeps everything emitted before the
crash.  ``prometheus_text`` renders a ``Counters`` snapshot (plus optional
histograms) in the Prometheus exposition text format, with metric names
sanitised to the ``[a-zA-Z_][a-zA-Z0-9_]*`` charset (dots become
underscores).  Both are zero-dependency.
"""
from __future__ import annotations

import json
import re
from typing import Dict, Optional

from repro.telemetry.schema import validate_event
from repro.telemetry.tracer import Counters, Histogram

_NAME_OK = re.compile(r"[^a-zA-Z0-9_]")


class JsonlSink:
    """Append-only JSONL event sink.  Accepts a path (opened/owned) or any
    object with ``write`` (borrowed — not closed)."""

    def __init__(self, target):
        if hasattr(target, "write"):
            self._f, self._owns = target, False
        else:
            self._f, self._owns = open(target, "a"), True
        self.n_events = 0

    def emit(self, event: dict) -> None:
        validate_event(event)
        self._f.write(json.dumps(event, sort_keys=True) + "\n")
        self._f.flush()
        self.n_events += 1

    def close(self) -> None:
        if self._owns and not self._f.closed:
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def _sanitize(name: str) -> str:
    name = _NAME_OK.sub("_", name)
    if not name or name[0].isdigit():
        name = "_" + name
    return name


def prometheus_text(counters: Counters,
                    histograms: Optional[Dict[str, Histogram]] = None,
                    prefix: str = "repro") -> str:
    """A ``Counters`` snapshot (+ histograms) in Prometheus text format.

    Counter semantics are not tracked per name, so everything is exposed as
    an untyped gauge — the dump is for scraping/diffing, not for a real
    Prometheus server's rate() math.  Histograms expose the standard
    ``_bucket{le=...}`` / ``_sum`` / ``_count`` triple (cumulative buckets,
    closing ``+Inf``).
    """
    lines = []
    for name, value in sorted(counters.snapshot().items()):
        metric = _sanitize(f"{prefix}_{name}")
        lines.append(f"# TYPE {metric} gauge")
        lines.append(f"{metric} {value}")
    for name, hist in sorted((histograms or {}).items()):
        metric = _sanitize(f"{prefix}_{name}")
        lines.append(f"# TYPE {metric} histogram")
        cum = 0
        for i, b in enumerate(hist.bins):
            cum += b
            lines.append(f'{metric}_bucket{{le="{i}"}} {cum}')
        lines.append(f'{metric}_bucket{{le="+Inf"}} {hist.count}')
        lines.append(f"{metric}_sum {hist.total}")
        lines.append(f"{metric}_count {hist.count}")
    return "\n".join(lines) + ("\n" if lines else "")
