"""The ``Telemetry`` facade — one object per engine composing the tracer,
the counter registry, bounded histograms, the drift-curve buffer, and the
JSONL sink (DESIGN.md §Telemetry).

Contract with the engines:

* **disabled is free and bit-identical** — ``Telemetry.disabled()`` is the
  engines' default; its ``enabled`` flag is a *static* Python fact the
  round builders branch on at trace time, so the disabled round function
  contains not one extra op and the enabled one compiles once (no
  retrace: the metric keys are fixed by static config, never by values).
* **one fetch per round** — engines hand ``record_round`` the
  already-host-side metric dict (they ``device_get`` the whole tree in one
  transfer); the facade never touches device arrays itself.
* **history absorption** — the engines' old ad-hoc ``history`` lists live
  here (``record_eval``/``history``), and ``Transport`` accounts its byte
  counters directly into ``self.counters`` when the engine wires the
  protocol with this telemetry — one registry instead of four ints plus a
  list per engine.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Dict, List, Optional

from repro.telemetry.export import JsonlSink, prometheus_text
from repro.telemetry.latency import latency_summary, request_itl
from repro.telemetry.tracer import Counters, Histogram, Tracer

DRIFT_CURVE_MAXLEN = 4096


class Telemetry:
    def __init__(self, enabled: bool = True, jsonl=None, engine: str = ""):
        self.enabled = enabled
        self.engine = engine
        self.tracer = Tracer(enabled)
        self.counters = Counters()
        self.histograms: Dict[str, Histogram] = {}
        self.history: List[dict] = []     # the engines' eval history
        # bounded per-round drift record: {"round": t, <metric>: float, ...}
        self.drift_curve: deque = deque(maxlen=DRIFT_CURVE_MAXLEN)
        self._sink: Optional[JsonlSink] = None
        if jsonl is not None:
            if not enabled:
                raise ValueError("a JSONL sink on disabled telemetry would "
                                 "silently record nothing; pass enabled=True")
            self._sink = JsonlSink(jsonl)

    @classmethod
    def disabled(cls, engine: str = "") -> "Telemetry":
        return cls(enabled=False, engine=engine)

    # ------------------------------------------------------------------
    def histogram(self, name: str, n_bins: int = 32) -> Histogram:
        """Get-or-create a named bounded histogram."""
        if name not in self.histograms:
            self.histograms[name] = Histogram(n_bins)
        return self.histograms[name]

    def emit(self, kind: str, **fields) -> None:
        """Emit one schema-validated event to the JSONL sink (no-op when
        disabled or sink-less; counters/curves update regardless through
        the record_* helpers)."""
        if not self.enabled or self._sink is None:
            return
        self._sink.emit({"ts": time.time(), "kind": kind,
                         "engine": self.engine, **fields})

    # ------------------------------------------------------------------
    def record_round(self, round_idx: int, metrics: Dict[str, float],
                     **extra) -> None:
        """One round's drift diagnostics (already fetched to host)."""
        if not self.enabled:
            return
        metrics = {k: float(v) for k, v in metrics.items()}
        self.drift_curve.append({"round": int(round_idx), **metrics})
        self.counters.inc("rounds")
        self.emit("round", round=int(round_idx), metrics=metrics, **extra)

    def record_eval(self, entry: dict) -> None:
        """One eval-history entry — appended even when disabled (this IS
        the engines' ``history`` list; observability must not change what
        the engine returns)."""
        self.history.append(entry)
        if self.enabled:
            self.emit("eval", **{k: (float(v) if isinstance(v, float)
                                     else v) for k, v in entry.items()})

    def record_request(self, output, **extra) -> None:
        """One finished serving request: TTFT/ITL/e2e from its raw
        timestamps."""
        if not self.enabled:
            return
        self.counters.inc("serving.requests_finished")
        self.counters.inc("serving.tokens_generated", len(output.tokens))
        self.emit("request", rid=int(output.rid),
                  n_tokens=len(output.tokens),
                  ttft_s=float(output.first_token_t - output.arrival_t),
                  itl_s=request_itl(output),
                  e2e_s=float(output.finish_t - output.arrival_t), **extra)

    # ------------------------------------------------------------------
    def drift_summary(self) -> Dict[str, object]:
        """First/last points of each drift metric seen this run."""
        if not self.drift_curve:
            return {}
        first, last = self.drift_curve[0], self.drift_curve[-1]
        keys = [k for k in last if k != "round"]
        return {k: {"first": first.get(k), "last": last[k]} for k in keys}

    def summary(self, outputs=None) -> Dict[str, object]:
        """End-of-run summary: counters, span percentiles, histograms,
        drift curve endpoints, and (if serving outputs are passed) the
        TTFT/ITL/e2e latency summary."""
        s: Dict[str, object] = {
            "engine": self.engine,
            "counters": self.counters.snapshot(),
            "spans": self.tracer.summary(),
            "histograms": {k: h.to_dict()
                           for k, h in self.histograms.items()},
            "drift": self.drift_summary(),
        }
        if outputs:
            s["latency"] = latency_summary(outputs)
        return s

    def emit_summary(self, outputs=None, **extra) -> Dict[str, object]:
        s = self.summary(outputs)
        self.emit("summary", **{k: v for k, v in s.items()
                                if k != "engine"}, **extra)
        return s

    def prometheus(self) -> str:
        return prometheus_text(self.counters, self.histograms)

    def close(self) -> None:
        if self._sink is not None:
            self._sink.close()
