"""Serving latency summarisation (DESIGN.md §Telemetry).

``RequestOutput`` carries raw timestamps (arrival, first token, finish);
this is the one place they are turned into the serving headline numbers —
TTFT, ITL (mean inter-token gap, ``(finish − first_token)/(n_tokens − 1)``,
undefined for single-token requests), and end-to-end latency, each as
p50/p95/mean percentiles over a request set.  ``serving_bench.py`` and the
telemetry summary exporter both consume this instead of re-deriving
percentiles ad hoc.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence


def _percentiles(values: Sequence[float]) -> Dict[str, float]:
    s = sorted(values)
    n = len(s)

    def pct(q: float) -> float:
        # nearest-rank on the sorted sample; exact at the edges, no numpy
        # dependency so the helper also runs host-only
        return s[min(n - 1, int(q * n))]

    return {"p50": round(pct(0.50), 6), "p95": round(pct(0.95), 6),
            "mean": round(sum(s) / n, 6)}


def request_itl(output) -> Optional[float]:
    """Mean inter-token latency of one request; None when only one token
    was generated (no gap exists)."""
    n = len(output.tokens)
    if n < 2:
        return None
    return (output.finish_t - output.first_token_t) / (n - 1)


def latency_summary(outputs) -> Dict[str, object]:
    """TTFT / ITL / e2e percentile summary over finished request outputs.

    Any object with ``arrival_t`` / ``first_token_t`` / ``finish_t`` /
    ``tokens`` works (``RequestOutput`` does).  Requests that generated a
    single token contribute to TTFT/e2e but not ITL; ``n_itl_requests``
    records how many did contribute.
    """
    outs = list(outputs)
    if not outs:
        raise ValueError("latency_summary needs at least one finished "
                         "request")
    ttfts = [o.first_token_t - o.arrival_t for o in outs]
    e2es = [o.finish_t - o.arrival_t for o in outs]
    itls = [itl for itl in (request_itl(o) for o in outs) if itl is not None]
    summary: Dict[str, object] = {
        "n_requests": len(outs),
        "n_tokens": sum(len(o.tokens) for o in outs),
        "ttft_s": _percentiles(ttfts),
        "e2e_s": _percentiles(e2es),
        "n_itl_requests": len(itls),
    }
    summary["itl_s"] = _percentiles(itls) if itls else None
    return summary
