"""In-jit drift diagnostics (DESIGN.md §Telemetry).

FedADC's claim is that local momentum *controls drift*; these are the cheap
scalar reductions that make drift observable every round without leaving
the jit'd round function:

* ``delta_dispersion`` — client-delta divergence
  ``mean_i ||Δ_i − Δ̄||² / ||Δ̄||²`` (DRAG's divergence signal, arXiv
  2309.01779, computed as a diagnostic rather than a weighting);
* ``momentum_alignment`` — ``cos(m̄, Δ̄)`` between the server momentum and
  the round aggregate: +1 when clients push where the momentum already
  points, ≤0 when the aggregate fights the acceleration;
* ``ef_residual_norm`` — mean per-client ``||e_i||`` of the uplink
  error-feedback residuals (how much signal the lossy wire is deferring);
* ``update_norm`` — ``||Δ̄||``.

Everything reduces to a handful of f32 scalars inside the round function,
so the per-round cost is a few tree reductions and the host fetches the
whole metric dict in ONE transfer after the round — no per-metric
device↔host chatter.  The key set is decided at trace time from static
facts (does the strategy keep a momentum? is EF on?), so the round
function compiles once and never retraces on the metric path.

The ``streaming_*`` helpers are the pod engine's client-serial form: the
scan accumulates ``Σ w_i·||Δ_i||²`` (one f32 scalar in the carry) and the
weighted dispersion follows from the variance identity
``E_w||Δ − Δ̄||² = E_w||Δ||² − ||Δ̄||²`` with ``Δ̄ = E_w[Δ]`` — no stacked
delta tree is ever materialised.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import tree as T

EPS = 1e-12


def _is_sparse_stack(deltas) -> bool:
    # lazy: telemetry must stay importable without the federated package
    from repro.federated.compression import is_sparse_tree
    return is_sparse_tree(deltas)


def delta_dispersion(deltas, mean_delta):
    """``mean_i ||Δ_i − Δ̄||² / ||Δ̄||²`` over a stacked (leading-axis
    clients) delta pytree — dense arrays or SparseLeaf wires (the
    sparse-native aggregate's input; dispatched at trace time)."""
    if _is_sparse_stack(deltas):
        return sparse_delta_dispersion(deltas, mean_delta)
    nbar = T.sq_norm(mean_delta)
    per = jax.vmap(lambda d: T.sq_norm(T.sub(d, mean_delta)))(deltas)
    return (jnp.mean(per) / (nbar + EPS)).astype(jnp.float32)


def sparse_delta_dispersion(wire, mean_delta):
    """Dispersion from the stacked SparseLeaf wire without densifying any
    client: ``||Δ_i − Δ̄||² = ||Δ_i||² − 2⟨Δ_i, Δ̄⟩ + ||Δ̄||²`` where the
    norm is Σv² off the wire and the dot is a k-cost gather against the
    (already dense) round aggregate.  Clamped at 0 — the identity can go
    epsilon-negative in fp32 where the vmapped dense form cannot."""
    from repro.federated import aggregation as A
    nbar = T.sq_norm(mean_delta).astype(jnp.float32)
    per = (A.sparse_sq_norms(wire)
           - 2.0 * A.sparse_dot_dense(wire, mean_delta) + nbar)
    per = jnp.maximum(per, 0.0)
    return (jnp.mean(per) / (nbar + EPS)).astype(jnp.float32)


def momentum_alignment(momentum, mean_delta):
    """``cos(m̄, Δ̄)``; 0 while either side is (numerically) zero, e.g. the
    round-0 momentum."""
    num = T.dot(momentum, mean_delta)
    den = jnp.sqrt(T.sq_norm(momentum) * T.sq_norm(mean_delta) + EPS)
    return (num / den).astype(jnp.float32)


def ef_residual_norm(efs):
    """Mean per-client ``||e_i||`` over a stacked EF-residual pytree."""
    per = jax.vmap(lambda e: jnp.sqrt(T.sq_norm(e)))(efs)
    return jnp.mean(per).astype(jnp.float32)


def update_norm(mean_delta):
    return jnp.sqrt(T.sq_norm(mean_delta)).astype(jnp.float32)


def round_metrics(deltas, mean_delta, momentum=None, efs=None):
    """The per-round drift tree for engines that hold the stacked deltas
    (sync simulator round, async flush).  Keys are static in (momentum is
    None, efs is None) — both trace-time facts."""
    m = {
        "delta_dispersion": delta_dispersion(deltas, mean_delta),
        "update_norm": update_norm(mean_delta),
    }
    if momentum is not None:
        m["momentum_alignment"] = momentum_alignment(momentum, mean_delta)
    if efs is not None:
        m["ef_residual_norm"] = ef_residual_norm(efs)
    return m


# ---------------------------------------------------------------------------
# streaming (client-serial) form — the pod engine's scan accumulates one
# scalar second moment instead of materialising the per-client deltas
# ---------------------------------------------------------------------------
def streaming_sq_norm(delta, weight):
    """One scan step's contribution to ``Σ w_i·||Δ_i||²`` (f32); reads the
    norm straight off a SparseLeaf wire when the pod engine streams the
    sparse-native uplink."""
    if _is_sparse_stack(delta):
        from repro.federated import aggregation as A
        return weight * A.sparse_sq_norms(delta)
    return weight * T.sq_norm(delta)


def streaming_dispersion(sum_w_sq_norm, weight_sum, mean_delta):
    """Weighted dispersion ``E_w||Δ_i − Δ̄||² / ||Δ̄||²`` from the
    accumulated moments: ``E_w||Δ||² − ||Δ̄||²`` over ``||Δ̄||²``.  Equals
    :func:`delta_dispersion` exactly under uniform weights."""
    nbar = T.sq_norm(mean_delta)
    second = sum_w_sq_norm / (weight_sum + EPS)
    return (jnp.maximum(second - nbar, 0.0) / (nbar + EPS)).astype(
        jnp.float32)
