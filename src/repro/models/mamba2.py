"""Mamba2 (SSD) block — Zamba2's backbone.

TPU adaptation: training/prefill uses the *chunked* SSD formulation (intra-
chunk work is pure matmul → MXU; inter-chunk recurrence is a length/chunk
lax.scan), instead of the CUDA selective-scan kernel.  Chunk size is
MXU-aligned (256 by default).  Decode is the O(1) state recurrence.
A Pallas kernel for the intra-chunk matmuls lives in kernels/ssd_scan.py
with this module's ``ssd_chunked`` as its oracle counterpart.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


def mamba2_init(key, cfg, dtype=jnp.float32):
    s = cfg.ssm
    d = cfg.d_model
    d_inner = s.expand * d
    H = d_inner // s.head_dim
    G, N = s.n_groups, s.d_state
    conv_ch = d_inner + 2 * G * N
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "in_proj": L.linear_init(k1, d, 2 * d_inner + 2 * G * N + H, dtype=dtype),
        "conv_w": (jax.random.normal(k2, (s.d_conv, conv_ch)) * 0.02).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": L.rmsnorm_init(d_inner, dtype),
        "out_proj": L.linear_init(k3, d_inner, d, dtype=dtype),
    }


def _split_proj(cfg, zxbcdt):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    G, N = s.n_groups, s.d_state
    H = d_inner // s.head_dim
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * G * N], axis=-1)
    return z, xbc, dt, d_inner, G, N, H


def _causal_conv(xbc, w, b):
    """Depthwise causal conv over (B, L, C)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for k in range(K):
        out = out + pad[:, k:k + xbc.shape[1]].astype(jnp.float32) * w[k].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(xbc.dtype)


def ssd_chunked(x, dt, A_log, Bmat, Cmat, D, chunk: int):
    """Chunked SSD.  x (B,L,H,P); dt (B,L,H); Bmat/Cmat (B,L,H,N); returns
    y (B,L,H,P).  All in float32 internally."""
    Bsz, Lq, H, P = x.shape
    N = Bmat.shape[-1]
    nc = Lq // chunk
    assert nc * chunk == Lq, "seq len must be divisible by chunk"
    f32 = jnp.float32
    x = x.astype(f32) * dt[..., None].astype(f32)                # pre-scale by dt
    a = -jnp.exp(A_log.astype(f32))[None, None] * dt.astype(f32)  # (B,L,H) log decay
    xc = x.reshape(Bsz, nc, chunk, H, P)
    ac = a.reshape(Bsz, nc, chunk, H)
    Bc = Bmat.astype(f32).reshape(Bsz, nc, chunk, H, N)
    Cc = Cmat.astype(f32).reshape(Bsz, nc, chunk, H, N)

    acum = jnp.cumsum(ac, axis=2)                                # (B,nc,Q,H)
    # intra-chunk: scores (B,nc,H,Q,Q)
    scores = jnp.einsum("bnqhd,bnkhd->bnhqk", Cc, Bc)
    decay = acum[..., :, None, :] - acum[..., None, :, :]        # (B,nc,Q,Q,H)
    decay = jnp.transpose(decay, (0, 1, 4, 2, 3))
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask BEFORE exp: exp of the (positive, unbounded) upper triangle
    # overflows and poisons gradients through the where
    gate = jnp.exp(jnp.where(causal, decay, -1e30))
    y_intra = jnp.einsum("bnhqk,bnkhp->bnqhp", scores * gate, xc)

    # chunk states (B,nc,H,N,P)
    a_end = acum[:, :, -1]                                       # (B,nc,H)
    rem = a_end[:, :, None] - acum                               # decay to chunk end
    S = jnp.einsum("bnkhd,bnkh,bnkhp->bnhdp", Bc, jnp.exp(rem), xc)

    def step(h, inp):
        dec, s = inp                                             # (B,H),(B,H,N,P)
        h_new = h * jnp.exp(dec)[..., None, None] + s
        return h_new, h                                          # emit state BEFORE chunk
    h0 = jnp.zeros((Bsz, H, N, P), f32)
    _, h_prev = jax.lax.scan(step, h0,
                             (jnp.moveaxis(a_end, 1, 0), jnp.moveaxis(S, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                          # (B,nc,H,N,P)

    y_inter = jnp.einsum("bnqhd,bnqh,bnhdp->bnqhp", Cc, jnp.exp(acum), h_prev)
    y = (y_intra + y_inter).reshape(Bsz, Lq, H, P)
    y = y + D.astype(f32)[None, None, :, None] * x
    return y


def mamba2_forward(p, x, cfg, use_pallas: bool = False):
    s = cfg.ssm
    B, Lq, _ = x.shape
    zxbcdt = L.linear(p["in_proj"], x)
    z, xbc, dt, d_inner, G, N, H = _split_proj(cfg, zxbcdt)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + G * N], axis=-1)
    xs = xs.reshape(B, Lq, H, s.head_dim)
    rep = H // G
    Bm = jnp.repeat(Bm.reshape(B, Lq, G, N), rep, axis=2)
    Cm = jnp.repeat(Cm.reshape(B, Lq, G, N), rep, axis=2)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    chunk = min(s.chunk_size, Lq)
    if use_pallas:
        from repro.kernels import ops
        y = ops.ssd_scan(xs, dt, p["A_log"], Bm, Cm, p["D"], chunk)
    else:
        y = ssd_chunked(xs, dt, p["A_log"], Bm, Cm, p["D"], chunk)
    y = y.reshape(B, Lq, d_inner).astype(x.dtype)
    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return L.linear(p["out_proj"], y)


# ---------------------------------------------------------------------------
# O(1) decode
# ---------------------------------------------------------------------------
def mamba2_init_cache(cfg, batch: int, dtype):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.n_groups * s.d_state
    return {
        "h": jnp.zeros((batch, H, s.d_state, s.head_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_ch), dtype),
    }


def mamba2_decode(p, x, cache, cfg):
    """x (B,1,d) -> (y (B,1,d), cache)."""
    s = cfg.ssm
    B = x.shape[0]
    zxbcdt = L.linear(p["in_proj"], x)[:, 0]                     # (B, *)
    z, xbc, dt, d_inner, G, N, H = _split_proj(cfg, zxbcdt)
    hist = jnp.concatenate([cache["conv"], xbc[:, None]], axis=1)  # (B,K,C)
    w = p["conv_w"].astype(jnp.float32)
    conv = jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32), w)
    xbc = jax.nn.silu(conv + p["conv_b"].astype(jnp.float32)).astype(x.dtype)
    xs, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + G * N], axis=-1)
    xs = xs.reshape(B, H, s.head_dim).astype(jnp.float32)
    rep = H // G
    Bm = jnp.repeat(Bm.reshape(B, G, N), rep, axis=1).astype(jnp.float32)
    Cm = jnp.repeat(Cm.reshape(B, G, N), rep, axis=1).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    decay = jnp.exp(-jnp.exp(p["A_log"])[None] * dt)             # (B,H)
    xdt = xs * dt[..., None]
    h = cache["h"] * decay[..., None, None] + jnp.einsum("bhd,bhp->bhdp", Bm, xdt)
    y = jnp.einsum("bhd,bhdp->bhp", Cm, h) + p["D"][None, :, None] * xdt
    y = y.reshape(B, 1, d_inner).astype(x.dtype)
    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z[:, None]), cfg.norm_eps)
    out = L.linear(p["out_proj"], y)
    new_cache = {"h": h, "conv": hist[:, 1:]}
    return out, new_cache
