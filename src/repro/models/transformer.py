"""Decoder-only stack assembler.

Supports heterogeneous block patterns (dense attention, MoE, Mamba2, m/sLSTM,
Zamba2-style shared attention).  Consecutive blocks of the same signature are
stacked and executed with ``lax.scan`` so an 88-layer model traces one block
body per run, not 88 — this keeps multi-pod ``lower()/compile()`` tractable.

Window sizes are per-layer *static* (they decide cache shapes), so runs are
partitioned by (kind, window).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, MOE, MAMBA2, SLSTM, MLSTM, SHARED_ATTN,
                                ModelConfig)
from repro.models import attention as A
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import moe as MOE_MOD
from repro.models import xlstm as XL

VIS_EMBED_DIM = 1024   # stub vision tower output dim (InternViT projector in)


# ---------------------------------------------------------------------------
# Run partitioning
# ---------------------------------------------------------------------------
def layer_window(cfg: ModelConfig, block_idx: int) -> int:
    return cfg.sliding_window if cfg.layer_uses_window(block_idx) else 0


def partition_runs(cfg: ModelConfig) -> List[Tuple[str, int, List[int]]]:
    """-> [(kind, window, [block indices])] preserving order."""
    runs: List[Tuple[str, int, List[int]]] = []
    for i, kind in enumerate(cfg.blocks()):
        win = layer_window(cfg, i) if kind in (ATTN, MOE, SHARED_ATTN) else 0
        if runs and runs[-1][0] == kind and runs[-1][1] == win \
                and kind != SHARED_ATTN:
            runs[-1][2].append(i)
        else:
            runs.append((kind, win, [i]))
    return runs


# ---------------------------------------------------------------------------
# Per-block init / apply
# ---------------------------------------------------------------------------
def _block_init(kind: str, key, cfg: ModelConfig, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if kind in (ATTN, SHARED_ATTN):
        p = {"ln1": L.rmsnorm_init(cfg.d_model, dtype),
             "ln2": L.rmsnorm_init(cfg.d_model, dtype)}
        if cfg.mla is not None:
            p["attn"] = A.mla_init(k1, cfg, dtype)
        else:
            p["attn"] = A.gqa_init(k1, cfg, dtype)
        d_ff = cfg.d_ff if cfg.d_ff > 0 else 4 * cfg.d_model
        p["mlp"] = L.mlp_init(k2, cfg.d_model, d_ff, dtype)
        return p
    if kind == MOE:
        p = {"ln1": L.rmsnorm_init(cfg.d_model, dtype),
             "ln2": L.rmsnorm_init(cfg.d_model, dtype)}
        p["attn"] = (A.mla_init(k1, cfg, dtype) if cfg.mla is not None
                     else A.gqa_init(k1, cfg, dtype))
        p["moe"] = MOE_MOD.moe_init(k2, cfg, dtype)
        return p
    if kind == MAMBA2:
        return {"ln": L.rmsnorm_init(cfg.d_model, dtype),
                "mix": M2.mamba2_init(k1, cfg, dtype)}
    if kind == MLSTM:
        return {"ln": L.rmsnorm_init(cfg.d_model, dtype),
                "mix": XL.mlstm_init(k1, cfg, dtype)}
    if kind == SLSTM:
        return {"ln": L.rmsnorm_init(cfg.d_model, dtype),
                "mix": XL.slstm_init(k1, cfg, dtype)}
    raise ValueError(kind)


def _attn_fwd(p, x, cfg, window, use_pallas):
    if cfg.mla is not None:
        return A.mla_forward(p, x, cfg, use_pallas=use_pallas)
    B, Lq, _ = x.shape
    positions = jnp.arange(Lq)[None, :]
    q, k, v = A._gqa_qkv(p, x, cfg, positions)
    out = A.sdpa_auto(q, k, v, causal=True, window=window,
                      use_pallas=use_pallas)
    return L.linear(p["wo"], out.reshape(B, Lq, -1))


def _block_fwd(kind: str, p, x, cfg: ModelConfig, window: int,
               use_pallas: bool):
    """-> (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in (ATTN, SHARED_ATTN):
        x = x + _attn_fwd(p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps),
                          cfg, window, use_pallas)
        x = x + L.mlp(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps))
        return x, aux
    if kind == MOE:
        x = x + _attn_fwd(p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps),
                          cfg, window, use_pallas)
        y, aux = MOE_MOD.moe_apply(p["moe"], L.rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
        return x + y, aux
    if kind == MAMBA2:
        return x + M2.mamba2_forward(p["mix"], L.rmsnorm(p["ln"], x, cfg.norm_eps),
                                     cfg, use_pallas), aux
    if kind == MLSTM:
        return x + XL.mlstm_forward(p["mix"], L.rmsnorm(p["ln"], x, cfg.norm_eps),
                                    cfg), aux
    if kind == SLSTM:
        return x + XL.slstm_forward(p["mix"], L.rmsnorm(p["ln"], x, cfg.norm_eps),
                                    cfg), aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------
def init(rng, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    runs = partition_runs(cfg)
    n_keys = len(runs) + 4
    keys = jax.random.split(rng, n_keys)
    params: Dict = {"embed": L.embedding_init(keys[0], cfg.vocab_size,
                                              cfg.d_model, dtype)}
    shared_done = False
    run_params = {}
    for ri, (kind, win, idxs) in enumerate(runs):
        if kind == SHARED_ATTN:
            if not shared_done:
                params["shared_attn"] = _block_init(SHARED_ATTN, keys[1], cfg, dtype)
                shared_done = True
            continue
        layer_keys = jax.random.split(keys[ri + 4], len(idxs))
        run_params[str(ri)] = jax.vmap(
            lambda k: _block_init(kind, k, cfg, dtype))(layer_keys)
    params["runs"] = run_params
    params["final_norm"] = L.rmsnorm_init(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.linear_init(keys[2], cfg.d_model,
                                          cfg.vocab_size, dtype=dtype)
    if cfg.n_patch_tokens > 0:
        params["vis_proj"] = L.linear_init(keys[3], VIS_EMBED_DIM,
                                           cfg.d_model, bias=True, dtype=dtype)
    return params


def _embed_inputs(params, batch, cfg):
    x = L.embed(params["embed"], batch["tokens"])
    if cfg.n_patch_tokens > 0 and "patch_embeds" in batch:
        vis = L.linear(params["vis_proj"], batch["patch_embeds"].astype(x.dtype))
        x = jnp.concatenate([vis, x], axis=1)
    return x


def _unembed(params, x, cfg):
    if cfg.tie_embeddings:
        return x @ params["embed"]["emb"].T.astype(x.dtype)
    return L.linear(params["lm_head"], x)


def forward(params, batch, cfg: ModelConfig, use_pallas: bool = False,
            remat: str = "none", logits_slice: str = "all"):
    """-> (logits (B, L[, +patch], V), aux_loss).  logits_slice="last"
    unembeds only the final position (serving prefill: skips the (L, V)
    vocab matmul for every non-final token — §Perf iteration 2)."""
    x = _embed_inputs(params, batch, cfg)
    aux = jnp.zeros((), jnp.float32)
    runs = partition_runs(cfg)
    for ri, (kind, win, idxs) in enumerate(runs):
        if kind == SHARED_ATTN:
            x, a = _block_fwd(SHARED_ATTN, params["shared_attn"], x, cfg,
                              win, use_pallas)
            aux = aux + a
            continue
        stacked = params["runs"][str(ri)]

        def body(carry, lp, _kind=kind, _win=win):
            h, acc = carry
            h, a = _block_fwd(_kind, lp, h, cfg, _win, use_pallas)
            return (h, acc + a), None
        if remat != "none":
            body = jax.checkpoint(body, prevent_cse=False)
        (x, aux), _ = jax.lax.scan(body, (x, aux), stacked)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if logits_slice == "last":
        x = x[:, -1:]
    return _unembed(params, x, cfg), aux


def loss_fn(params, batch, cfg: ModelConfig, use_pallas: bool = False,
            remat: str = "none"):
    """Next-token cross-entropy; positions with label<0 are masked.
    -> (loss, dict)."""
    logits, aux = forward(params, batch, cfg, use_pallas, remat)
    labels = batch["labels"]
    if cfg.n_patch_tokens > 0 and "patch_embeds" in batch:
        logits = logits[:, batch["patch_embeds"].shape[1]:]
    logits = logits[:, :-1]
    targets = labels[:, 1:]
    mask = (targets >= 0).astype(jnp.float32)
    tgt = jnp.clip(targets, 0)
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               tgt[..., None], axis=-1)[..., 0]
    ce = jnp.sum((lse - gold) * mask) / jnp.maximum(mask.sum(), 1.0)
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Decode (serve_step): one token against a cache.
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Per-slot caches: every sequence in the batch carries its own write
    position (``kpos`` is (batch, S)), so the serving engine can decode
    requests at different depths in one batched step."""
    runs = partition_runs(cfg)
    cache: Dict = {}
    for ri, (kind, win, idxs) in enumerate(runs):
        if kind in (ATTN, MOE, SHARED_ATTN):
            if cfg.mla is not None:
                one = lambda: A.mla_init_cache(cfg, batch, max_len, dtype)
            else:
                S = min(max_len, win) if win > 0 else max_len
                one = lambda S=S: {
                    "k": jnp.zeros((batch, S, cfg.n_kv_heads,
                                    cfg.resolved_head_dim), dtype),
                    "v": jnp.zeros((batch, S, cfg.n_kv_heads,
                                    cfg.resolved_head_dim), dtype),
                    "kpos": jnp.full((batch, S), -1, jnp.int32)}
        elif kind == MAMBA2:
            one = lambda: M2.mamba2_init_cache(cfg, batch, dtype)
        elif kind == MLSTM:
            one = lambda: XL.mlstm_init_cache(cfg, batch, dtype)
        elif kind == SLSTM:
            one = lambda: XL.slstm_init_cache(cfg, batch, dtype)
        else:
            raise ValueError(kind)
        layers = [one() for _ in idxs]
        cache[str(ri)] = jax.tree.map(lambda *xs: jnp.stack(xs), *layers) \
            if len(layers) > 1 else jax.tree.map(lambda v: v[None], layers[0])
    return cache


def _block_decode(kind, p, x, c, cfg, cur_pos):
    if kind in (ATTN, MOE, SHARED_ATTN):
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        if cfg.mla is not None:
            y, c = A.mla_decode(p["attn"], h, c, cfg, cur_pos)
        else:
            # window handled via cache size (ring buffer) + kpos mask;
            # cur_pos is (B,) so every slot writes its own ring position
            B = x.shape[0]
            positions = cur_pos[:, None]
            q, k, v = A._gqa_qkv(p["attn"], h, cfg, positions)
            S = c["k"].shape[1]
            slot = jnp.mod(cur_pos, S)
            rows = jnp.arange(B)
            ck = c["k"].at[rows, slot].set(k[:, 0].astype(c["k"].dtype))
            cv = c["v"].at[rows, slot].set(v[:, 0].astype(c["v"].dtype))
            kpos = c["kpos"].at[rows, slot].set(cur_pos)
            valid = (kpos >= 0) & (kpos <= cur_pos[:, None])
            out = A._sdpa(q, ck, cv, valid[:, None, None, :])
            y = L.linear(p["attn"]["wo"], out.reshape(B, 1, -1))
            c = {"k": ck, "v": cv, "kpos": kpos}
        x = x + y
        h2 = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if kind == MOE:
            y2, _ = MOE_MOD.moe_apply(p["moe"], h2, cfg)
        else:
            y2 = L.mlp(p["mlp"], h2)
        return x + y2, c
    if kind == MAMBA2:
        y, c = M2.mamba2_decode(p["mix"], L.rmsnorm(p["ln"], x, cfg.norm_eps),
                                c, cfg)
        return x + y, c
    if kind == MLSTM:
        y, c = XL.mlstm_decode(p["mix"], L.rmsnorm(p["ln"], x, cfg.norm_eps),
                               c, cfg)
        return x + y, c
    if kind == SLSTM:
        y, c = XL.slstm_decode(p["mix"], L.rmsnorm(p["ln"], x, cfg.norm_eps),
                               c, cfg)
        return x + y, c
    raise ValueError(kind)


def decode_step(params, cache, tokens, cur_pos, cfg: ModelConfig,
                active=None):
    """tokens (B,1) int32; cur_pos scalar or (B,) int32 -> (logits (B,V),
    cache).  A scalar cur_pos broadcasts (all sequences at the same depth);
    a (B,) vector decodes per-slot positions — the continuous-batching
    serving path.  ``active`` (B,) bool, when given, masks the cache write
    per slot: inactive slots keep their prior cache bit-exactly, so a slot
    mid-prefill is not corrupted by interleaved batched decode steps."""
    B = tokens.shape[0]
    cur_pos = jnp.broadcast_to(jnp.asarray(cur_pos, jnp.int32).reshape(-1),
                               (B,))
    x = L.embed(params["embed"], tokens)
    runs = partition_runs(cfg)
    new_cache: Dict = {}
    for ri, (kind, win, idxs) in enumerate(runs):
        c = cache[str(ri)]
        p = (params["shared_attn"] if kind == SHARED_ATTN
             else params["runs"][str(ri)])

        def body(h, xs, _kind=kind, _shared=(kind == SHARED_ATTN), _p=p):
            if _shared:
                lc = xs
                lp = _p
            else:
                lp, lc = xs
            h, lc = _block_decode(_kind, lp, h, lc, cfg, cur_pos)
            return h, lc
        if kind == SHARED_ATTN:
            x, nc = jax.lax.scan(body, x, c)
        else:
            x, nc = jax.lax.scan(body, x, (p, c))
        new_cache[str(ri)] = nc
    if active is not None:
        # every cache leaf is (n_layers, B, ...): mask axis 1
        new_cache = jax.tree.map(
            lambda new, old: jnp.where(
                active.reshape((1, B) + (1,) * (new.ndim - 2)), new, old),
            new_cache, cache)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _unembed(params, x, cfg)
    return logits[:, 0], new_cache
