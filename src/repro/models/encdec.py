"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, frames, d_model).  This module implements the
transformer encoder (bidirectional) + decoder (causal self-attn + cross-attn),
which is the assigned backbone.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L


def _enc_block_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.layernorm_init(cfg.d_model, dtype),
        "attn": A.gqa_init(k1, cfg, dtype),
        "ln2": L.layernorm_init(cfg.d_model, dtype),
        "mlp": L.gelu_mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _dec_block_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": L.layernorm_init(cfg.d_model, dtype),
        "attn": A.gqa_init(k1, cfg, dtype),
        "ln_x": L.layernorm_init(cfg.d_model, dtype),
        "xattn": A.cross_attn_init(k2, cfg, dtype),
        "ln2": L.layernorm_init(cfg.d_model, dtype),
        "mlp": L.gelu_mlp_init(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def init(rng, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    ke, kd, kt, kp = jax.random.split(rng, 4)
    enc_keys = jax.random.split(ke, cfg.n_encoder_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    return {
        "embed": L.embedding_init(kt, cfg.vocab_size, cfg.d_model, dtype),
        "pos_dec": (jax.random.normal(kp, (cfg.max_seq_len, cfg.d_model))
                    * 0.01).astype(dtype),
        "enc": jax.vmap(lambda k: _enc_block_init(k, cfg, dtype))(enc_keys),
        "enc_norm": L.layernorm_init(cfg.d_model, dtype),
        "dec": jax.vmap(lambda k: _dec_block_init(k, cfg, dtype))(dec_keys),
        "dec_norm": L.layernorm_init(cfg.d_model, dtype),
    }


def encode(params, frames, cfg: ModelConfig):
    """frames (B, F, d_model) — stub conv-frontend output."""
    x = frames

    def body(h, lp):
        return _enc_self(lp, h, cfg), None
    x, _ = jax.lax.scan(body, x, params["enc"])
    return L.layernorm(params["enc_norm"], x, cfg.norm_eps)


def _enc_self(lp, h, cfg):
    B, Lq, _ = h.shape
    hd = cfg.resolved_head_dim
    hn = L.layernorm(lp["ln1"], h, cfg.norm_eps)
    positions = jnp.arange(Lq)[None, :]
    q, k, v = A._gqa_qkv(lp["attn"], hn, cfg, positions)
    out = A.sdpa_auto(q, k, v, causal=False)    # bidirectional
    h = h + L.linear(lp["attn"]["wo"], out.reshape(B, Lq, -1))
    h = h + L.gelu_mlp(lp["mlp"], L.layernorm(lp["ln2"], h, cfg.norm_eps))
    return h


def _dec_block(lp, h, enc_out, cfg, mask):
    B, Lq, _ = h.shape
    hn = L.layernorm(lp["ln1"], h, cfg.norm_eps)
    positions = jnp.arange(Lq)[None, :]
    q, k, v = A._gqa_qkv(lp["attn"], hn, cfg, positions)
    out = A.sdpa_auto(q, k, v, causal=True)
    h = h + L.linear(lp["attn"]["wo"], out.reshape(B, Lq, -1))
    h = h + A.cross_attn(lp["xattn"], L.layernorm(lp["ln_x"], h, cfg.norm_eps),
                         enc_out, cfg)
    h = h + L.gelu_mlp(lp["mlp"], L.layernorm(lp["ln2"], h, cfg.norm_eps))
    return h


def forward(params, batch, cfg: ModelConfig, use_pallas: bool = False,
            remat: str = "none", logits_slice: str = "all"):
    """batch: frames (B,F,d), tokens (B,L) -> (logits, aux)."""
    enc_out = encode(params, batch["frames"], cfg)
    x = L.embed(params["embed"], batch["tokens"])
    x = x + params["pos_dec"][: x.shape[1]].astype(x.dtype)
    mask = A.causal_window_mask(x.shape[1], x.shape[1], 0)

    def body(h, lp):
        return _dec_block(lp, h, enc_out, cfg, mask), None
    if remat != "none":
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec"])
    x = L.layernorm(params["dec_norm"], x, cfg.norm_eps)
    if logits_slice == "last":
        x = x[:, -1:]
    logits = x @ params["embed"]["emb"].T.astype(x.dtype)   # tied
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(params, batch, cfg: ModelConfig, use_pallas: bool = False,
            remat: str = "none"):
    logits, aux = forward(params, batch, cfg, use_pallas, remat)
    targets = batch["labels"][:, 1:]
    logits = logits[:, :-1]
    mask = (targets >= 0).astype(jnp.float32)
    tgt = jnp.clip(targets, 0)
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32), tgt[..., None],
                               axis=-1)[..., 0]
    ce = jnp.sum((lse - gold) * mask) / jnp.maximum(mask.sum(), 1.0)
    return ce, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Decode: self-attn KV cache + precomputed cross K/V.
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    nl = cfg.n_layers
    dec_len = min(max_len, cfg.max_seq_len)
    return {
        "k": jnp.zeros((nl, batch, dec_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((nl, batch, dec_len, cfg.n_kv_heads, hd), dtype),
        "kpos": jnp.full((nl, dec_len), -1, jnp.int32),
        # cross-attention K/V over encoder frames (computed at prefill)
        "xk": jnp.zeros((nl, batch, max_len, cfg.n_kv_heads, hd), dtype),
        "xv": jnp.zeros((nl, batch, max_len, cfg.n_kv_heads, hd), dtype),
    }


def prefill_cross(params, enc_out, cfg, cache):
    """Populate cross K/V from encoder output."""
    B, F, _ = enc_out.shape
    hd = cfg.resolved_head_dim

    def one(lp):
        k = L.linear(lp["xattn"]["wk"], enc_out).reshape(B, F, cfg.n_kv_heads, hd)
        v = L.linear(lp["xattn"]["wv"], enc_out).reshape(B, F, cfg.n_kv_heads, hd)
        return k, v
    xk, xv = jax.vmap(one)(params["dec"])
    return dict(cache, xk=xk.astype(cache["xk"].dtype),
                xv=xv.astype(cache["xv"].dtype))


def decode_step(params, cache, tokens, cur_pos, cfg: ModelConfig,
                active=None):
    """cur_pos stays scalar here (all sequences at the same depth): the
    decoder's kpos is shared across the batch, so whisper serves via the
    batch-synchronous path, not the continuous-batching engine.  For the
    same reason a per-slot ``active`` mask cannot be honoured consistently
    (kpos would advance for masked rows) and is rejected."""
    if active is not None:
        raise NotImplementedError(
            "enc-dec decode has a batch-shared kpos; per-slot active "
            "masking is unsupported — serve whisper batch-synchronously")
    B = tokens.shape[0]
    hd = cfg.resolved_head_dim
    x = L.embed(params["embed"], tokens)
    pos_emb = jax.lax.dynamic_slice_in_dim(
        params["pos_dec"], jnp.minimum(cur_pos, cfg.max_seq_len - 1), 1)
    x = x + pos_emb[None].astype(x.dtype)

    def body(h, xs):
        lp, ck, cv, ckpos, xk, xv = xs
        hn = L.layernorm(lp["ln1"], h, cfg.norm_eps)
        positions = jnp.full((B, 1), cur_pos, jnp.int32)
        q, k, v = A._gqa_qkv(lp["attn"], hn, cfg, positions)
        S = ck.shape[1]
        slot = jnp.mod(cur_pos, S)
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, slot, 0, 0))
        ckpos = jax.lax.dynamic_update_slice(ckpos,
                                             cur_pos[None].astype(jnp.int32),
                                             (slot,))
        valid = (ckpos >= 0) & (ckpos <= cur_pos)
        out = A._sdpa(q, ck, cv, valid[None, None, None, :])
        h = h + L.linear(lp["attn"]["wo"], out.reshape(B, 1, -1))
        # cross attention against precomputed K/V
        hx = L.layernorm(lp["ln_x"], h, cfg.norm_eps)
        qx = L.linear(lp["xattn"]["wq"], hx).reshape(B, 1, cfg.n_heads, hd)
        outx = A._sdpa(qx, xk, xv, None)
        h = h + L.linear(lp["xattn"]["wo"], outx.reshape(B, 1, -1))
        h = h + L.gelu_mlp(lp["mlp"], L.layernorm(lp["ln2"], h, cfg.norm_eps))
        return h, (ck, cv, ckpos)
    x, (nk, nv, nkpos) = jax.lax.scan(
        body, x, (params["dec"], cache["k"], cache["v"], cache["kpos"],
                  cache["xk"], cache["xv"]))
    x = L.layernorm(params["dec_norm"], x, cfg.norm_eps)
    logits = x @ params["embed"]["emb"].T.astype(x.dtype)
    return logits[:, 0], dict(cache, k=nk, v=nv, kpos=nkpos)
