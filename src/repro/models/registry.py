"""Model registry: uniform (init, forward, loss, cache, decode) bundle per
architecture family, plus analytic parameter counting for the roofline."""
from __future__ import annotations

from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer


def get_model(cfg: ModelConfig) -> SimpleNamespace:
    mod = encdec if cfg.is_encoder_decoder else transformer
    return SimpleNamespace(
        init=mod.init,
        forward=mod.forward,
        loss_fn=mod.loss_fn,
        init_cache=mod.init_cache,
        decode_step=mod.decode_step,
    )


def _param_shapes(cfg: ModelConfig):
    mod = encdec if cfg.is_encoder_decoder else transformer
    rng = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda r: mod.init(r, cfg), rng)


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    shapes = _param_shapes(cfg)
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    total = 0
    for path, leaf in flat:
        n = int(np.prod(leaf.shape))
        keys = "/".join(str(getattr(p, "key", p)) for p in path)
        if active_only and cfg.moe is not None and "experts" in keys:
            n = n * cfg.moe.top_k // max(cfg.moe.n_experts, 1)
        total += n
    return total
