"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelisable —
computed here in its attention-like parallel form, MXU friendly) and sLSTM
(scalar memory with recurrent gate connections — a true sequential
recurrence, lowered as lax.scan; this is the part with no parallel form).

Stack layout for xlstm-350m: every 4th block is sLSTM, the rest mLSTM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def mlstm_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    s = cfg.ssm
    d_inner = s.expand * d
    H = cfg.n_heads
    ks = jax.random.split(key, 9)
    return {
        "wu": L.linear_init(ks[0], d, d_inner, dtype=dtype),
        "wz": L.linear_init(ks[8], d, d_inner, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, d_inner)) * 0.02).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "wq": L.linear_init(ks[2], d_inner, d_inner, dtype=dtype),
        "wk": L.linear_init(ks[3], d_inner, d_inner, dtype=dtype),
        "wv": L.linear_init(ks[4], d_inner, d_inner, dtype=dtype),
        "w_if": L.linear_init(ks[5], d_inner, 2 * H, bias=True, dtype=dtype),
        "norm": L.rmsnorm_init(d_inner, dtype),
        "down": L.linear_init(ks[6], d_inner, d, dtype=dtype),
    }


def _mlstm_parallel(q, k, v, logi, logf):
    """Stabilised parallel mLSTM.  q,k,v (B,L,H,P); logi/logf (B,L,H)."""
    B, Lq, H, P = q.shape
    f32 = jnp.float32
    q, k, v = q.astype(f32), k.astype(f32), v.astype(f32)
    logf = jax.nn.log_sigmoid(logf.astype(f32))                 # (B,L,H)
    F = jnp.cumsum(logf, axis=1)
    # D[i,j] = F_i - F_j + logi_j   for j <= i
    Dmat = F[:, :, None] - F[:, None] + logi.astype(f32)[:, None]  # (B,Li,Lj,H)
    causal = jnp.tril(jnp.ones((Lq, Lq), bool))
    Dmat = jnp.where(causal[None, :, :, None], Dmat, -jnp.inf)
    m = jnp.max(Dmat, axis=2, keepdims=True)                    # stabiliser
    Dstab = jnp.exp(Dmat - m)
    scores = jnp.einsum("bihp,bjhp->bijh", q, k) * (P ** -0.5)
    w = scores * Dstab
    denom = jnp.maximum(jnp.abs(jnp.sum(w, axis=2, keepdims=True)),
                        jnp.exp(-m))
    y = jnp.einsum("bijh,bjhp->bihp", w / denom, v)
    return y


def mlstm_forward(p, x, cfg):
    B, Lq, _ = x.shape
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = cfg.n_heads
    P = d_inner // H
    u = L.linear(p["wu"], x)
    z = L.linear(p["wz"], x)
    # causal conv front
    K = p["conv_w"].shape[0]
    pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    c = sum(pad[:, k:k + Lq].astype(jnp.float32) * p["conv_w"][k].astype(jnp.float32)
            for k in range(K))
    c = jax.nn.silu(c + p["conv_b"].astype(jnp.float32)).astype(x.dtype)
    q = L.linear(p["wq"], c).reshape(B, Lq, H, P)
    k = L.linear(p["wk"], c).reshape(B, Lq, H, P)
    v = L.linear(p["wv"], u).reshape(B, Lq, H, P)
    gates = L.linear(p["w_if"], u).astype(jnp.float32)
    logi, logf = jnp.split(gates, 2, axis=-1)                   # (B,L,H)
    y = _mlstm_parallel(q, k, v, logi, logf).reshape(B, Lq, d_inner)
    y = L.rmsnorm(p["norm"], y.astype(x.dtype), cfg.norm_eps)
    y = y * jax.nn.silu(z)
    return L.linear(p["down"], y)


def mlstm_init_cache(cfg, batch, dtype):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = cfg.n_heads
    P = d_inner // H
    return {
        "C": jnp.zeros((batch, H, P, P), jnp.float32),          # matrix memory
        "n": jnp.zeros((batch, H, P), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, d_inner), dtype),
    }


def mlstm_decode(p, x, cache, cfg):
    B = x.shape[0]
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = cfg.n_heads
    P = d_inner // H
    u = L.linear(p["wu"], x)[:, 0]                              # (B, d_inner)
    z = L.linear(p["wz"], x)[:, 0]
    hist = jnp.concatenate([cache["conv"], u[:, None]], axis=1)
    c = jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32),
                   p["conv_w"].astype(jnp.float32))
    c = jax.nn.silu(c + p["conv_b"].astype(jnp.float32)).astype(x.dtype)
    q = L.linear(p["wq"], c).reshape(B, H, P).astype(jnp.float32)
    k = L.linear(p["wk"], c).reshape(B, H, P).astype(jnp.float32)
    v = L.linear(p["wv"], u).reshape(B, H, P).astype(jnp.float32)
    gates = L.linear(p["w_if"], u).astype(jnp.float32)
    logi, logf = jnp.split(gates, 2, axis=-1)                   # (B,H)
    logf = jax.nn.log_sigmoid(logf)
    m_new = jnp.maximum(logf + cache["m"], logi)
    fi = jnp.exp(logf + cache["m"] - m_new)
    ii = jnp.exp(logi - m_new)
    k = k * (P ** -0.5)
    C = cache["C"] * fi[..., None, None] + ii[..., None, None] * \
        jnp.einsum("bhp,bhr->bhpr", v, k)
    n = cache["n"] * fi[..., None] + ii[..., None] * k
    num = jnp.einsum("bhpr,bhr->bhp", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhr,bhr->bh", n, q)),
                      jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(B, 1, d_inner).astype(x.dtype)
    y = L.rmsnorm(p["norm"], y, cfg.norm_eps) * jax.nn.silu(z[:, None])
    out = L.linear(p["down"], y)
    return out, {"C": C, "n": n, "m": m_new, "conv": hist[:, 1:]}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    H = cfg.n_heads
    P = d // H
    ks = jax.random.split(key, 7)
    d_ff = int(d * 4 / 3)
    return {
        "wx_z": L.linear_init(ks[0], d, d, bias=True, dtype=dtype),
        "wx_i": L.linear_init(ks[4], d, d, bias=True, dtype=dtype),
        "wx_f": L.linear_init(ks[5], d, d, bias=True, dtype=dtype),
        "wx_o": L.linear_init(ks[6], d, d, bias=True, dtype=dtype),
        "r": (jax.random.normal(ks[1], (4, H, P, P)) * (P ** -0.5)).astype(dtype),
        "norm": L.groupnorm_init(d, dtype),
        "ffn": L.mlp_init(ks[2], d, d_ff, dtype=dtype),
        "ffn_norm": L.rmsnorm_init(d, dtype),
    }


def _slstm_cell(p, xg, state, H, P):
    """One step.  xg = (z_in, i_in, f_in, o_in) pre-computed projections,
    each (B, d); state = (c, n, h, m) each (B, H, P) except m (B, H)."""
    c, n, h, m = state
    f32 = jnp.float32
    z_in, i_in, f_in, o_in = (g.astype(f32) for g in xg)
    B = z_in.shape[0]
    hz = h.reshape(B, H, P)
    rec = jnp.einsum("ghpq,bhq->gbhp", p["r"].astype(f32), hz)   # (4,B,H,P)
    shp = (B, H, P)
    z = jnp.tanh(z_in.reshape(shp) + rec[0])
    logi = i_in.reshape(shp) + rec[1]
    logf = jax.nn.log_sigmoid(f_in.reshape(shp) + rec[2])
    o = jax.nn.sigmoid(o_in.reshape(shp) + rec[3])
    m_new = jnp.maximum(logf + m[..., None], logi).max(-1)       # (B,H) shared stabiliser
    fi = jnp.exp(logf + m[..., None] - m_new[..., None])
    ii = jnp.exp(logi - m_new[..., None])
    c_new = fi * c + ii * z
    n_new = fi * n + ii
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new)


def slstm_forward(p, x, cfg):
    B, Lq, d = x.shape
    H = cfg.n_heads
    P = d // H
    xg = tuple(L.linear(p[k], x) for k in ("wx_z", "wx_i", "wx_f", "wx_o"))
    zeros = jnp.zeros((B, H, P), jnp.float32)
    state0 = (zeros, zeros, zeros, jnp.full((B, H), -1e30, jnp.float32))

    def step(state, xt):
        new = _slstm_cell(p, xt, state, H, P)
        return new, new[2]                                       # emit h
    _, hs = jax.lax.scan(step, state0,
                         tuple(jnp.moveaxis(g, 1, 0) for g in xg))
    y = jnp.moveaxis(hs, 0, 1).reshape(B, Lq, d).astype(x.dtype)
    y = L.groupnorm(p["norm"], y, groups=H, eps=cfg.norm_eps)
    y = y + L.mlp(p["ffn"], L.rmsnorm(p["ffn_norm"], y, cfg.norm_eps))
    return y


def slstm_init_cache(cfg, batch, dtype):
    d = cfg.d_model
    H = cfg.n_heads
    P = d // H
    z = jnp.zeros((batch, H, P), jnp.float32)
    return {"c": z, "n": z, "h": z,
            "m": jnp.full((batch, H), -1e30, jnp.float32)}


def slstm_decode(p, x, cache, cfg):
    B = x.shape[0]
    d = cfg.d_model
    H = cfg.n_heads
    P = d // H
    xg = tuple(L.linear(p[k], x)[:, 0] for k in ("wx_z", "wx_i", "wx_f",
                                                 "wx_o"))
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    c, n, h, m = _slstm_cell(p, xg, state, H, P)
    y = h.reshape(B, 1, d).astype(x.dtype)
    y = L.groupnorm(p["norm"], y, groups=H, eps=cfg.norm_eps)
    y = y + L.mlp(p["ffn"], L.rmsnorm(p["ffn_norm"], y, cfg.norm_eps))
    return y, {"c": c, "n": n, "h": h, "m": m}
