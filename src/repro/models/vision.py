"""The paper's own experiment models.

* ``cnn``   — 4 conv + 4 FC, no batch norm, maxpool (Sec. IV-B1, CIFAR-10).
* ``resnet18`` — ResNet-18 with GroupNorm(32) after convs (Sec. IV-C1,
  CIFAR-100), since BN statistics break under federated non-iid clients.

Inputs are NHWC images.  These are small enough to run the full federated
simulator on CPU, which is how the paper's tables/figures are reproduced.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models import layers as L


def he_linear_init(key, d_in, d_out, dtype=jnp.float32):
    """Kaiming-normal init for ReLU stacks (the vision nets are 8 layers
    deep with no normalisation — the transformer-style uniform init makes
    activations vanish)."""
    w = jax.random.normal(key, (d_in, d_out)) * math.sqrt(2.0 / d_in)
    return {"w": w.astype(dtype), "b": jnp.zeros((d_out,), dtype)}


def conv_init(key, kh, kw, cin, cout, dtype=jnp.float32):
    fan_in = kh * kw * cin
    kw_, kb = jax.random.split(key)
    w = jax.random.normal(kw_, (kh, kw, cin, cout)) * math.sqrt(2.0 / fan_in)
    return {"w": w.astype(dtype),
            "b": jnp.zeros((cout,), dtype)}


def conv(p, x, stride=1, padding="SAME"):
    y = jax.lax.conv_general_dilated(
        x, p["w"].astype(x.dtype), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"].astype(x.dtype)


def maxpool(x, k=2):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, k, k, 1), (1, k, k, 1), "VALID")


# ---------------------------------------------------------------------------
# Paper CNN: 4 conv + 4 FC.
# ---------------------------------------------------------------------------
def cnn_init(rng, n_classes=10, dtype=jnp.float32, width=32, image_size=32):
    ks = jax.random.split(rng, 8)
    w = width
    spatial = max(image_size // 16, 1) ** 2   # after 4 maxpools
    return {
        "c1": conv_init(ks[0], 3, 3, 3, w, dtype),
        "c2": conv_init(ks[1], 3, 3, w, 2 * w, dtype),
        "c3": conv_init(ks[2], 3, 3, 2 * w, 4 * w, dtype),
        "c4": conv_init(ks[3], 3, 3, 4 * w, 4 * w, dtype),
        "f1": he_linear_init(ks[4], 4 * w * spatial, 512, dtype=dtype),
        "f2": he_linear_init(ks[5], 512, 256, dtype=dtype),
        "f3": he_linear_init(ks[6], 256, 128, dtype=dtype),
        "head": he_linear_init(ks[7], 128, n_classes, dtype=dtype),
    }


def cnn_features(params, x):
    """x (B,32,32,3) -> penultimate features (B,128)."""
    x = maxpool(jax.nn.relu(conv(params["c1"], x)))          # 16
    x = maxpool(jax.nn.relu(conv(params["c2"], x)))          # 8
    x = maxpool(jax.nn.relu(conv(params["c3"], x)))          # 4
    x = maxpool(jax.nn.relu(conv(params["c4"], x)))          # 2
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(L.linear(params["f1"], x))
    x = jax.nn.relu(L.linear(params["f2"], x))
    x = jax.nn.relu(L.linear(params["f3"], x))
    return x


def cnn_apply(params, x):
    return L.linear(params["head"], cnn_features(params, x))


# ---------------------------------------------------------------------------
# ResNet-18 (GroupNorm).
# ---------------------------------------------------------------------------
def _basic_block_init(key, cin, cout, stride, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"conv1": conv_init(k1, 3, 3, cin, cout, dtype),
         "gn1": L.groupnorm_init(cout, dtype),
         "conv2": conv_init(k2, 3, 3, cout, cout, dtype),
         "gn2": L.groupnorm_init(cout, dtype)}
    if stride != 1 or cin != cout:
        p["proj"] = conv_init(k3, 1, 1, cin, cout, dtype)
    return p


def _basic_block(p, x, stride):
    y = jax.nn.relu(L.groupnorm(p["gn1"], conv(p["conv1"], x, stride)))
    y = L.groupnorm(p["gn2"], conv(p["conv2"], y))
    sc = conv(p["proj"], x, stride) if "proj" in p else x
    return jax.nn.relu(y + sc)


RESNET18_STAGES = [(64, 1), (128, 2), (256, 2), (512, 2)]


def resnet18_init(rng, n_classes=100, dtype=jnp.float32):
    ks = jax.random.split(rng, 11)
    p: Dict = {"stem": conv_init(ks[0], 3, 3, 3, 64, dtype),
               "gn0": L.groupnorm_init(64, dtype)}
    cin = 64
    i = 1
    for si, (cout, stride) in enumerate(RESNET18_STAGES):
        for bi in range(2):
            st = stride if bi == 0 else 1
            p[f"s{si}b{bi}"] = _basic_block_init(ks[i], cin, cout, st, dtype)
            cin = cout
            i += 1
    p["head"] = he_linear_init(ks[i], 512, n_classes, dtype=dtype)
    return p


def resnet18_features(params, x):
    x = jax.nn.relu(L.groupnorm(params["gn0"], conv(params["stem"], x)))
    for si, (cout, stride) in enumerate(RESNET18_STAGES):
        for bi in range(2):
            st = stride if bi == 0 else 1
            x = _basic_block(params[f"s{si}b{bi}"], x, st)
    return jnp.mean(x, axis=(1, 2))                          # GAP (B,512)


def resnet18_apply(params, x):
    return L.linear(params["head"], resnet18_features(params, x))


# ---------------------------------------------------------------------------
# Uniform interface used by the federated simulator.
# ---------------------------------------------------------------------------
VISION_MODELS = {
    "cnn": (cnn_init, cnn_apply, cnn_features, "head"),
    "resnet18": (resnet18_init, resnet18_apply, resnet18_features, "head"),
}
