"""Mixture-of-Experts FFN (DeepSeek-V3 256e top-8 + shared; Llama-4 16e top-1
+ shared).

TPU adaptation: dispatch uses the capacity-based scatter/gather formulation —
``expert_inputs (E, C, d) = scatter(x)`` followed by a batched expert einsum
``ecd,edf->ecf``.  The expert dimension E shards cleanly over the "model"
mesh axis (expert parallelism); under pjit the scatter/gather lowers to an
all-to-all pair, which is exactly the communication pattern the roofline
analysis tracks.  No (T, E, C) one-hot dispatch tensor is ever materialised.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


def _constrain(x, *spec):
    """Best-effort sharding constraint: binds to the ambient mesh under the
    dry-run / pod engine, no-op on meshless CPU tests."""
    try:
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


def moe_init(key, cfg, dtype=jnp.float32):
    m = cfg.moe
    d = cfg.d_model
    kr, ke, ks = jax.random.split(key, 3)
    keg, keu, ked = jax.random.split(ke, 3)
    p = {
        "router": L.linear_init(kr, d, m.n_experts, dtype=jnp.float32),
        "experts": {
            "gate": L._dense_init(keg, (m.n_experts, d, m.d_ff_expert), in_axis=1, dtype=dtype),
            "up": L._dense_init(keu, (m.n_experts, d, m.d_ff_expert), in_axis=1, dtype=dtype),
            "down": L._dense_init(ked, (m.n_experts, m.d_ff_expert, d), in_axis=1, dtype=dtype),
        },
    }
    if m.n_shared_experts > 0:
        p["shared"] = L.mlp_init(ks, d, m.d_ff_expert * m.n_shared_experts,
                                 dtype=dtype)
    return p


def moe_apply(p, x, cfg):
    """x (B, L, d) -> (y, aux_loss)."""
    m = cfg.moe
    B, Lq, d = x.shape
    T = B * Lq
    xt = x.reshape(T, d)

    logits = L.linear(p["router"], xt.astype(jnp.float32))      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, m.top_k)                  # (T, k)
    topw = topw / jnp.clip(topw.sum(-1, keepdims=True), 1e-9)

    # flatten (token, k-choice) assignments
    flat_e = topi.reshape(-1)                                    # (T*k,)
    flat_w = topw.reshape(-1)
    cap = int(max(1, (T * m.top_k * m.capacity_factor) // m.n_experts))

    # per-expert counts — also feeds the load-balance aux loss without ever
    # materialising a (T·k, E) one-hot (§Perf iteration 5: the cumsum-based
    # position assignment read/wrote a (T·k, E) int tensor per MoE layer;
    # the sort-based ranking below is O(T·k) memory)
    counts = jnp.zeros((m.n_experts,), jnp.int32).at[flat_e].add(1)
    me = probs.mean(0)                                           # (E,)
    ce = counts.astype(jnp.float32) / (T * m.top_k)
    aux = m.router_aux_coef * m.n_experts * jnp.sum(me * ce)

    # position of each assignment within its expert via stable sort:
    # identical ordering to the cumsum formulation (token order preserved)
    starts = jnp.cumsum(counts) - counts                         # exclusive
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    pos_sorted = jnp.arange(flat_e.shape[0], dtype=jnp.int32) \
        - starts[sorted_e].astype(jnp.int32)
    pos_in_e = jnp.zeros_like(flat_e).at[order].set(pos_sorted)
    keep = pos_in_e < cap
    pos_in_e = jnp.where(keep, pos_in_e, cap)                    # overflow slot

    xin = jnp.repeat(xt, m.top_k, axis=0)                        # (T*k, d)
    xin = _constrain(xin, "data", None)
    # dispatch: scatter into the expert-parallel buffer.  The constraints
    # pin token tensors to "data" and expert buffers to "model" so GSPMD
    # lowers the dispatch/return as data↔expert all-to-alls instead of
    # replicating the (E, C, d) buffers (§Perf iteration 3: 17.4 TB → see
    # EXPERIMENTS.md).
    buf = jnp.zeros((m.n_experts, cap + 1, d), x.dtype)
    buf = buf.at[flat_e, pos_in_e].add(xin * keep[:, None].astype(x.dtype))
    buf = _constrain(buf[:, :cap], cfg.moe_dispatch_axis, None, None)

    ew = p["experts"]
    h = jnp.einsum("ecd,edf->ecf", buf, ew["gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, ew["up"].astype(x.dtype))
    out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u,
                     ew["down"].astype(x.dtype))                 # (E, C, d)
    out = _constrain(out, cfg.moe_dispatch_axis, None, None)

    out = jnp.pad(out, ((0, 0), (0, 1), (0, 0)))                 # overflow row
    gathered = out[flat_e, pos_in_e]                             # (T*k, d)
    gathered = _constrain(gathered, "data", None)
    gathered = gathered * (flat_w * keep)[:, None].astype(x.dtype)
    y = gathered.reshape(T, m.top_k, d).sum(1)

    if "shared" in p:
        y = y + L.mlp(p["shared"], xt)
    return y.reshape(B, Lq, d), aux
