"""Attention variants covering the assigned pool:

* GQA with optional qk-norm (Qwen3), qkv bias (Qwen1.5), sliding window
  (Llama-4 chunked / long-context variants), full causal (Mistral).
* MLA (DeepSeek-V3 multi-head latent attention) with compressed-latent KV
  cache and weight-absorbed decode — the TPU-friendly formulation (two
  matmuls against the latent cache instead of materialising per-head K/V).

Each variant exposes ``init`` and ``forward`` (full sequence, causal);
single-token decode against a cache lives in ``mla_decode`` here and, for
GQA, inline in ``transformer._block_decode`` (which owns the window /
cache-size coupling for stacked runs).  Caches are dicts of arrays so
they shard like any other pytree.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L

NEG_INF = -1e30


def _sdpa(q, k, v, mask, use_pallas: bool = False):
    """q (B,Lq,H,D), k/v (B,Lk,Hk,D[v]), mask (B,1,Lq,Lk) bool."""
    if use_pallas and mask is None:
        from repro.kernels import ops
        return ops.flash_attention(q, k, v, causal=True)
    B, Lq, H, D = q.shape
    Hk = k.shape[2]
    g = H // Hk
    qf = q.astype(jnp.float32) * (D ** -0.5)
    qf = qf.reshape(B, Lq, Hk, g, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    if mask is not None:
        scores = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask,
                           scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return out.reshape(B, Lq, H, v.shape[-1]).astype(q.dtype)


CHUNK_THRESHOLD = 8192     # sequences at/above this use q-block chunking
CHUNK_BLOCK_Q = 1024


def sdpa_auto(q, k, v, causal=True, window=0, use_pallas=False):
    """Full-sequence attention that q-block-chunks long sequences so the
    live score tensor is (H, block_q, Lk) instead of (H, Lq, Lk) — the
    difference between a 32k-token prefill fitting in HBM or not
    (EXPERIMENTS.md §Perf iteration 1)."""
    B, Lq, H, D = q.shape
    if use_pallas and window == 0 and causal:
        return _sdpa(q, k, v, None, use_pallas=True)
    if Lq < CHUNK_THRESHOLD or Lq % CHUNK_BLOCK_Q != 0:
        mask = causal_window_mask(Lq, Lq, window) if (causal or window) \
            else None
        return _sdpa(q, k, v, mask)
    nb = Lq // CHUNK_BLOCK_Q
    qb = q.reshape(B, nb, CHUNK_BLOCK_Q, H, D)
    bq = CHUNK_BLOCK_Q
    # sliding window: each q block only sees a (window + bq) K/V band —
    # slice it instead of masking the full row (§Perf iteration 4)
    band = min(window + bq, Lq) if window > 0 else Lq

    def body(carry, inp):
        i, qblk = inp
        off = i * bq
        if window > 0 and band < Lq:
            start = jnp.clip(off + bq - band, 0, Lq - band)
            kb = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            qpos = off + jnp.arange(bq)[:, None]
            kpos = start + jnp.arange(band)[None, :]
            mask = ((kpos <= qpos) & (kpos > qpos - window))[None, None]
            out = _sdpa(qblk, kb, vb, mask)
        else:
            if causal or window:
                mask = causal_window_mask(bq, Lq, window, q_offset=off)
            else:
                mask = None
            out = _sdpa(qblk, k, v, mask)
        return carry, out
    _, outs = jax.lax.scan(body, 0, (jnp.arange(nb), jnp.moveaxis(qb, 1, 0)))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Lq, H, v.shape[-1])


def causal_window_mask(lq: int, lk: int, window: int, q_offset: int = 0):
    """(1,1,lq,lk) bool mask; window<=0 means full causal."""
    qpos = jnp.arange(lq)[:, None] + q_offset
    kpos = jnp.arange(lk)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m[None, None]


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------
def gqa_init(key, cfg, dtype=jnp.float32):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko, kn = jax.random.split(key, 5)
    p = {
        "wq": L.linear_init(kq, d, cfg.n_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": L.linear_init(kk, d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": L.linear_init(kv, d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": L.linear_init(ko, cfg.n_heads * hd, d, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = L.rmsnorm_init(hd, dtype)
        p["k_norm"] = L.rmsnorm_init(hd, dtype)
    return p


def _gqa_qkv(p, x, cfg, positions):
    B, Lq, _ = x.shape
    hd = cfg.resolved_head_dim
    q = L.linear(p["wq"], x).reshape(B, Lq, cfg.n_heads, hd)
    k = L.linear(p["wk"], x).reshape(B, Lq, cfg.n_kv_heads, hd)
    v = L.linear(p["wv"], x).reshape(B, Lq, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = L.rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = L.rmsnorm(p["k_norm"], k, cfg.norm_eps)
    cos, sin = L.rope_freqs(hd, cfg.rope_theta, positions)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    return q, k, v


def gqa_forward(p, x, cfg, layer_idx: int, use_pallas: bool = False):
    B, Lq, _ = x.shape
    positions = jnp.arange(Lq)[None, :]
    q, k, v = _gqa_qkv(p, x, cfg, positions)
    window = cfg.sliding_window if cfg.layer_uses_window(layer_idx) else 0
    out = sdpa_auto(q, k, v, causal=True, window=window,
                    use_pallas=use_pallas)
    return L.linear(p["wo"], out.reshape(B, Lq, -1))


# The GQA single-token decode path (per-slot ring-buffer write + kpos
# mask) lives inline in ``transformer._block_decode``, which owns the
# window/cache-size coupling for stacked runs.

# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# ---------------------------------------------------------------------------
def mla_init(key, cfg, dtype=jnp.float32):
    m = cfg.mla
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wdq": L.linear_init(ks[0], d, m.q_lora_rank, dtype=dtype),
        "q_norm": L.rmsnorm_init(m.q_lora_rank, dtype),
        "wuq": L.linear_init(ks[1], m.q_lora_rank, cfg.n_heads * qk_dim, dtype=dtype),
        "wdkv": L.linear_init(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dtype=dtype),
        "kv_norm": L.rmsnorm_init(m.kv_lora_rank, dtype),
        "wuk": L.linear_init(ks[3], m.kv_lora_rank,
                             cfg.n_heads * m.qk_nope_head_dim, dtype=dtype),
        "wuv": L.linear_init(ks[4], m.kv_lora_rank,
                             cfg.n_heads * m.v_head_dim, dtype=dtype),
        "wo": L.linear_init(ks[5], cfg.n_heads * m.v_head_dim, d, dtype=dtype),
    }


def _mla_q(p, x, cfg, positions):
    m = cfg.mla
    B, Lq, _ = x.shape
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = L.linear(p["wuq"], L.rmsnorm(p["q_norm"], L.linear(p["wdq"], x),
                                     cfg.norm_eps))
    q = q.reshape(B, Lq, cfg.n_heads, qk_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    cos, sin = L.rope_freqs(m.qk_rope_head_dim, cfg.rope_theta, positions)
    q_rope = L.apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def _mla_latent(p, x, cfg, positions):
    m = cfg.mla
    ckv = L.linear(p["wdkv"], x)
    c_kv, k_rope = jnp.split(ckv, [m.kv_lora_rank], axis=-1)
    c_kv = L.rmsnorm(p["kv_norm"], c_kv, cfg.norm_eps)
    cos, sin = L.rope_freqs(m.qk_rope_head_dim, cfg.rope_theta, positions)
    k_rope = L.apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    return c_kv, k_rope


def mla_forward(p, x, cfg, layer_idx: int = 0, use_pallas: bool = False):
    """Naive (expanded) formulation for train / prefill."""
    m = cfg.mla
    B, Lq, _ = x.shape
    positions = jnp.arange(Lq)[None, :]
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c_kv, k_rope = _mla_latent(p, x, cfg, positions)
    k_nope = L.linear(p["wuk"], c_kv).reshape(B, Lq, cfg.n_heads, m.qk_nope_head_dim)
    v = L.linear(p["wuv"], c_kv).reshape(B, Lq, cfg.n_heads, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope[:, :, None, :],
                                          (B, Lq, cfg.n_heads,
                                           m.qk_rope_head_dim))], -1)
    out = sdpa_auto(q, k, v, causal=True)
    return L.linear(p["wo"], out.reshape(B, Lq, -1))


def mla_init_cache(cfg, batch: int, max_len: int, dtype):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
        "kpos": jnp.full((batch, max_len), -1, jnp.int32),
    }


def mla_decode(p, x, cache, cfg, cur_pos):
    """Weight-absorbed decode: scores and values are matmuls against the
    compressed latent cache — per-head K/V never materialise.  cur_pos is
    (B,): each slot writes/masks its own position."""
    m = cfg.mla
    B = x.shape[0]
    positions = cur_pos[:, None]
    q_nope, q_rope = _mla_q(p, x, cfg, positions)           # (B,1,H,*)
    c_kv, k_rope = _mla_latent(p, x, cfg, positions)        # (B,1,r),(B,1,dr)
    slot = jnp.mod(cur_pos, cache["c_kv"].shape[1])
    rows = jnp.arange(B)
    cc = cache["c_kv"].at[rows, slot].set(
        c_kv[:, 0].astype(cache["c_kv"].dtype))
    cr = cache["k_rope"].at[rows, slot].set(
        k_rope[:, 0].astype(cache["k_rope"].dtype))
    kpos = cache["kpos"].at[rows, slot].set(cur_pos)
    # absorb W_uk into q:  q_abs (B,1,H,r)
    wuk = p["wuk"]["w"].reshape(m.kv_lora_rank, cfg.n_heads, m.qk_nope_head_dim)
    q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                       wuk.astype(jnp.float32))
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    s1 = jnp.einsum("bqhr,bkr->bhqk", q_abs, cc.astype(jnp.float32))
    s2 = jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                    cr.astype(jnp.float32))
    scores = (s1 + s2) * scale
    valid = (kpos >= 0) & (kpos <= cur_pos[:, None])
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bhqk,bkr->bqhr", w, cc.astype(jnp.float32))
    wuv = p["wuv"]["w"].reshape(m.kv_lora_rank, cfg.n_heads, m.v_head_dim)
    out = jnp.einsum("bqhr,rhd->bqhd", out_lat, wuv.astype(jnp.float32))
    y = L.linear(p["wo"], out.reshape(B, 1, -1).astype(x.dtype))
    return y, {"c_kv": cc, "k_rope": cr, "kpos": kpos}


# ---------------------------------------------------------------------------
# Cross attention (Whisper decoder).
# ---------------------------------------------------------------------------
def cross_attn_init(key, cfg, dtype=jnp.float32):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": L.linear_init(kq, d, cfg.n_heads * hd, bias=True, dtype=dtype),
        "wk": L.linear_init(kk, d, cfg.n_kv_heads * hd, dtype=dtype),
        "wv": L.linear_init(kv, d, cfg.n_kv_heads * hd, bias=True, dtype=dtype),
        "wo": L.linear_init(ko, cfg.n_heads * hd, d, bias=True, dtype=dtype),
    }


def cross_attn(p, x, enc_out, cfg):
    B, Lq, _ = x.shape
    Lk = enc_out.shape[1]
    hd = cfg.resolved_head_dim
    q = L.linear(p["wq"], x).reshape(B, Lq, cfg.n_heads, hd)
    k = L.linear(p["wk"], enc_out).reshape(B, Lk, cfg.n_kv_heads, hd)
    v = L.linear(p["wv"], enc_out).reshape(B, Lk, cfg.n_kv_heads, hd)
    out = _sdpa(q, k, v, None)
    return L.linear(p["wo"], out.reshape(B, Lq, -1))
