"""Core functional layers.  Parameters are plain nested dicts of jnp arrays so
they compose with pjit sharding and the FL strategies (which treat the model
as an opaque pytree)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def _dense_init(key, shape, in_axis=-2, dtype=jnp.float32):
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.uniform(key, shape, dtype=jnp.float32, minval=-scale,
                               maxval=scale)).astype(dtype)


def linear_init(key, d_in, d_out, bias=False, dtype=jnp.float32):
    kw, kb = jax.random.split(key)
    p = {"w": _dense_init(kw, (d_in, d_out), in_axis=0, dtype=dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def embedding_init(key, vocab, d_model, dtype=jnp.float32):
    return {"emb": (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)}


def embed(p, ids):
    return jnp.take(p["emb"], ids, axis=0)


def rmsnorm_init(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(p, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(p, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dt)


def groupnorm_init(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def groupnorm(p, x, groups=32, eps=1e-5):
    """Channel-last group norm (used by the paper's ResNet-18 repro)."""
    dt = x.dtype
    *lead, c = x.shape
    g = min(groups, c)
    while c % g:
        g -= 1
    xg = x.astype(jnp.float32).reshape(*lead, g, c // g)
    mu = jnp.mean(xg, axis=(-1,), keepdims=True)
    # normalize over (spatial, channels-in-group): collapse spatial dims
    axes = tuple(range(1, len(lead))) + (len(lead), len(lead) + 1)
    mu = jnp.mean(xg, axis=axes, keepdims=True)
    var = jnp.mean((xg - mu) ** 2, axis=axes, keepdims=True)
    y = ((xg - mu) * jax.lax.rsqrt(var + eps)).reshape(x.shape)
    return (y * p["scale"] + p["bias"]).astype(dt)


# --------------------------------------------------------------------------
# Rotary position embeddings.
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float, positions: jnp.ndarray):
    """positions (...,) -> cos,sin of shape (..., head_dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                           / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., L, H, D); cos/sin broadcastable (..., L, 1, D/2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    while cos.ndim < x1.ndim:
        cos, sin = cos[..., None, :], sin[..., None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# SwiGLU MLP (the standard FFN for every dense arch in the pool).
# --------------------------------------------------------------------------
def mlp_init(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": linear_init(k1, d_model, d_ff, dtype=dtype),
        "up": linear_init(k2, d_model, d_ff, dtype=dtype),
        "down": linear_init(k3, d_ff, d_model, dtype=dtype),
    }


def mlp(p, x):
    return linear(p["down"], jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x))


def gelu_mlp_init(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {"fc1": linear_init(k1, d_model, d_ff, bias=True, dtype=dtype),
            "fc2": linear_init(k2, d_ff, d_model, bias=True, dtype=dtype)}


def gelu_mlp(p, x):
    return linear(p["fc2"], jax.nn.gelu(linear(p["fc1"], x)))
