"""Synthetic datasets.

The container has no CIFAR on disk, so the paper's experiments are reproduced
on a *class-structured Gaussian image* dataset with the same cardinality
interface (n classes, train/test split).  Each class has a smooth random
template plus per-sample mode jitter and pixel noise — enough structure that
(a) the CNN/ResNet learn it, and (b) non-iid partitioning induces the local
drift the paper studies.  The LM engine uses a Zipf-ish Markov token stream.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def make_image_dataset(n_train: int, n_test: int, n_classes: int,
                       image_size: int = 32, n_modes: int = 3,
                       noise: float = 0.35, seed: int = 0
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """-> (x_train (N,H,W,3), y_train, x_test, y_test), float32 in ~[-1,1]."""
    rng = np.random.RandomState(seed)
    H = image_size
    # smooth class templates: low-freq random fields
    freq = rng.randn(n_classes, n_modes, 4, 4, 3).astype(np.float32)
    templates = np.zeros((n_classes, n_modes, H, H, 3), np.float32)
    for c in range(n_classes):
        for m in range(n_modes):
            up = np.kron(freq[c, m], np.ones((H // 4, H // 4, 1), np.float32))
            templates[c, m] = up
    templates /= (np.abs(templates).max() + 1e-6)

    def _sample(n, seed_off):
        r = np.random.RandomState(seed + seed_off)
        y = r.randint(0, n_classes, size=n)
        m = r.randint(0, n_modes, size=n)
        x = templates[y, m] + noise * r.randn(n, H, H, 3).astype(np.float32)
        return x.astype(np.float32), y.astype(np.int32)

    x_tr, y_tr = _sample(n_train, 1)
    x_te, y_te = _sample(n_test, 2)
    return x_tr, y_tr, x_te, y_te


def make_token_dataset(n_docs: int, seq_len: int, vocab: int, seed: int = 0,
                       n_domains: int = 10) -> Tuple[np.ndarray, np.ndarray]:
    """Markov token streams with per-domain transition structure; the domain
    id doubles as the 'class' for non-iid client partitioning.
    -> (tokens (n_docs, seq_len) int32, domain (n_docs,) int32)."""
    rng = np.random.RandomState(seed)
    doms = rng.randint(0, n_domains, size=n_docs)
    # each domain prefers a band of the vocab
    tokens = np.zeros((n_docs, seq_len), np.int32)
    band = max(vocab // n_domains, 8)
    for i in range(n_docs):
        d = doms[i]
        lo = (d * band) % max(vocab - band, 1)
        t = rng.randint(lo, lo + band)
        seq = [t]
        for _ in range(seq_len - 1):
            if rng.rand() < 0.8:   # stay in band, markov-ish walk
                t = lo + (t - lo + rng.randint(-3, 4)) % band
            else:
                t = rng.randint(0, vocab)
            seq.append(t)
        tokens[i] = np.array(seq, np.int32)
    return tokens, doms.astype(np.int32)
