"""Non-iid client partitioners from the paper:

* sort-and-partition(s): sort by label, split into blocks, give each client
  blocks from at most `s` distinct labels (Sec. IV-B2).
* Dirichlet(α): per-class proportions sampled from Dir(α) (Sec. IV-C1).
"""
from __future__ import annotations

from typing import List

import numpy as np


def sort_and_partition(labels: np.ndarray, n_clients: int, s: int,
                       seed: int = 0) -> List[np.ndarray]:
    rng = np.random.RandomState(seed)
    order = np.argsort(labels, kind="stable")
    n_blocks = n_clients * s
    blocks = np.array_split(order, n_blocks)
    perm = rng.permutation(n_blocks)
    parts = [np.concatenate([blocks[perm[c * s + j]] for j in range(s)])
             for c in range(n_clients)]
    return [rng.permutation(p) for p in parts]


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        seed: int = 0, min_size: int = 2) -> List[np.ndarray]:
    rng = np.random.RandomState(seed)
    n_classes = int(labels.max()) + 1
    while True:
        parts = [[] for _ in range(n_clients)]
        for c in range(n_classes):
            idx = np.where(labels == c)[0]
            rng.shuffle(idx)
            props = rng.dirichlet([alpha] * n_clients)
            cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
            for ci, chunk in enumerate(np.split(idx, cuts)):
                parts[ci].append(chunk)
        parts = [np.concatenate(p) for p in parts]
        if min(len(p) for p in parts) >= min_size:
            return [rng.permutation(p) for p in parts]
        seed += 1
        rng = np.random.RandomState(seed)


def class_counts(labels: np.ndarray, parts: List[np.ndarray],
                 n_classes: int) -> np.ndarray:
    """-> (n_clients, n_classes) float32 counts (the γ_{i,k} numerators)."""
    out = np.zeros((len(parts), n_classes), np.float32)
    for i, p in enumerate(parts):
        for c, n in zip(*np.unique(labels[p], return_counts=True)):
            out[i, int(c)] = n
    return out
