"""Roofline term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds:

  compute    = HLO_FLOPs            / (chips × 197e12  bf16 FLOP/s)
  memory     = HLO_bytes_accessed   / (chips × 819e9   B/s HBM)
  collective = wire_bytes           / (chips × 50e9    B/s ICI per link)

``cost_analysis`` provides FLOPs / bytes.  Collective bytes are NOT in
cost_analysis: we parse the optimized HLO and, for every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, take the
result shapes and apply the ring-transfer factor for its replica-group size
g (all-reduce 2(g−1)/g, gather/scatter/a2a (g−1)/g, permute 1).  wire_bytes
is per-device traffic: result shapes in partitioned HLO are already
per-shard.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, List

# TPU v5e-ish hardware constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum bytes over every dtype[shape] occurrence in `text` (handles
    tuple results)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    counts: Dict[str, int]
    result_bytes: Dict[str, int]
    wire_bytes: float

    def total_result_bytes(self) -> int:
        return sum(self.result_bytes.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    rbytes: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    wire = 0.0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)", stripped)
        if not m:
            continue
        rhs = m.group(1)
        kind = None
        for c in _COLLECTIVES:
            if re.search(rf"\b{c}(-start|-done)?\(", rhs):
                kind = c
                break
        if kind is None or "-done(" in rhs:
            continue                      # count start ops once
        # result shape(s) = text before the op name
        shape_part = rhs.split(kind)[0]
        nbytes = _shape_bytes(shape_part)
        g = 1
        gm = re.search(r"replica_groups=\{?\{([\d,]+)\}", rhs)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            gm2 = re.search(r"replica_groups=\[(\d+),(\d+)\]", rhs)
            if gm2:
                g = int(gm2.group(2))
        counts[kind] += 1
        rbytes[kind] += nbytes
        if g <= 1:
            factor = 0.0 if kind != "collective-permute" else 1.0
        elif kind == "all-reduce":
            factor = 2.0 * (g - 1) / g
        elif kind == "collective-permute":
            factor = 1.0
        else:
            factor = (g - 1) / g
        wire += nbytes * factor
    return CollectiveStats(counts, rbytes, wire)


@dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    wire_bytes: float
    chips: int
    collectives: CollectiveStats
    per_device_hbm: float          # from memory_analysis
    model_flops_per_chip: float = 0.0

    @property
    def compute_s(self):
        # XLA's cost_analysis counts while-loop (scan) bodies ONCE, so the
        # HLO count underestimates layer/step-scanned programs; the analytic
        # 6·N·D term is the floor.  Take the max of the two estimates.
        return max(self.flops, self.model_flops_per_chip) / PEAK_FLOPS

    @property
    def compute_s_hlo(self):
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self):
        return self.bytes_accessed / HBM_BW

    @property
    def collective_s(self):
        return self.wire_bytes / ICI_BW

    @property
    def dominant(self):
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def as_dict(self):
        return {
            "flops": self.flops, "bytes": self.bytes_accessed,
            "wire_bytes": self.wire_bytes,
            "compute_s": self.compute_s,
            "compute_s_hlo": self.compute_s_hlo,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "per_device_hbm_gb": self.per_device_hbm / 2**30,
            "collective_counts": self.collectives.counts,
        }


def analyze(compiled, mesh, model_flops_per_chip: float = 0.0) -> Roofline:
    """compiled: jax Compiled object.  Costs reported by XLA for a
    partitioned module are per-device (the module IS the per-device
    program), so terms are already per-chip."""
    chips = mesh.devices.size
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    coll = parse_collectives(hlo)
    ma = compiled.memory_analysis()
    hbm = 0.0
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes"):
        hbm += float(getattr(ma, attr, 0.0) or 0.0)
    # arguments+outputs alias for donated state; report args+temps
    return Roofline(flops=flops, bytes_accessed=nbytes,
                    wire_bytes=coll.wire_bytes, chips=chips,
                    collectives=coll, per_device_hbm=hbm,
                    model_flops_per_chip=model_flops_per_chip)


def model_flops_per_round(mcfg, shape, fed=None) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE); D = tokens
    processed per step (per round for training: 3× for fwd+bwd is already
    the 6 factor; decode processes global_batch tokens)."""
    n = mcfg.active_param_count() if mcfg.moe is not None \
        else mcfg.param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        f = 6.0 * n * tokens
        if fed is not None and fed.distill:
            f *= 4.0 / 3.0               # extra teacher forward
        return f
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    # decode: one token per sequence (attention over the cache is the
    # dominant non-param term; reported separately by the HLO count)
    return 2.0 * n * shape.global_batch
