import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  This module is the ONLY place the 512 placeholder
# devices exist; tests and benches see the single real CPU device.

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (ARCHS, SHAPES, get_arch, long_context_variant,
                           shape_applicable)
from repro.configs.base import FedConfig, RunConfig
from repro.launch import inputs as I
from repro.launch import roofline as R
from repro.launch.mesh import make_production_mesh
from repro.launch.serve import make_prefill_step, make_serve_step
from repro.launch.train import make_train_step


def _fed_for(shape, arch_id):
    """Round decomposition per shape: clients × H × b = global_batch."""
    return FedConfig(strategy="fedadc", clients_per_round=4, local_steps=4,
                     eta=0.05, beta_global=0.8, beta_local=0.8)


def _run_for(arch_id):
    # bf16 params for the huge archs (FL aggregation precision note in
    # DESIGN.md); fp32 otherwise.
    big = {"mistral-large-123b", "deepseek-v3-671b", "llama4-scout-17b-a16e",
           "internvl2-26b", "qwen1.5-32b"}
    return RunConfig(param_dtype="bfloat16" if arch_id in big else "float32",
                     remat="full")


def lower_one(arch_id: str, shape_name: str, multi_pod: bool,
              client_parallel: int = 1, fed=None, run=None,
              donate: bool = True, verbose: bool = True,
              serve_sharding: str = "serve", mesh_override=None,
              fsdp_over_pod: bool = False):
    """Lower + compile one (arch × shape × mesh) combination.
    Returns a result dict with roofline terms."""
    shape = SHAPES[shape_name]
    mcfg = get_arch(arch_id)
    if shape_name == "long_500k":
        mcfg = long_context_variant(mcfg)
        if mcfg is None:
            return {"arch": arch_id, "shape": shape_name,
                    "multi_pod": multi_pod, "status": "skipped",
                    "reason": "no sub-quadratic decode path (DESIGN.md)"}
    fed = fed or _fed_for(shape, arch_id)
    run = run or _run_for(arch_id)
    if mesh_override is not None:
        mesh = jax.make_mesh(tuple(mesh_override),
                             ("data", "model") if len(mesh_override) == 2
                             else ("pod", "data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            # NOTE (§Perf iteration 11, refuted): turning TP off for sub-1B
            # archs idles the model axis at this round decomposition
            # (b=16 ⇒ 1 seq per data shard already) — kept as an explicit
            # knob (tp_off) only.
            state_sds = I.state_inputs(mcfg, fed, run, mesh,
                                       fsdp_over_pod=fsdp_over_pod)
            batch_sds = I.train_inputs(mcfg, shape, fed, mesh, multi_pod)
            cp = mesh.shape.get("pod", 1) if multi_pod else client_parallel
            step = make_train_step(mcfg, fed, run, client_parallel=cp)
            out_sh = jax.tree.map(lambda s: s.sharding, state_sds)
            jitted = jax.jit(step,
                             out_shardings=(out_sh, None),
                             donate_argnums=(0,) if donate else ())
            lowered = jitted.lower(state_sds, batch_sds)
        elif shape.kind == "prefill":
            if serve_sharding == "serve":
                from dataclasses import replace as _rep
                mcfg = _rep(mcfg, moe_dispatch_axis="data")
            state_sds = I.state_inputs(mcfg, _fed_for(shape, arch_id),
                                       run, mesh, mode=serve_sharding)
            batch_sds = I.prefill_inputs(mcfg, shape, mesh, multi_pod)
            step = make_prefill_step(mcfg)
            lowered = jax.jit(step).lower(state_sds["params"], batch_sds)
        else:  # decode
            # decode is HBM-capacity-bound: TP-only (serve) sharding
            # replicates dense params over "data", which blows the budget
            # for the >30B archs — those keep the FSDP layout (§Perf
            # decode note in EXPERIMENTS.md)
            mode = serve_sharding
            if serve_sharding == "serve" and mcfg.param_count() > 30e9:
                mode = "train"
            if mode == "serve":
                from dataclasses import replace as _rep
                mcfg = _rep(mcfg, moe_dispatch_axis="data")
            state_sds = I.state_inputs(mcfg, _fed_for(shape, arch_id),
                                       run, mesh, mode=mode)
            cache_sds, tokens, cur_pos, active = I.decode_inputs(
                mcfg, shape, mesh, multi_pod)
            step = make_serve_step(mcfg)
            cache_sh = jax.tree.map(lambda s: s.sharding, cache_sds)
            jitted = jax.jit(step, out_shardings=(None, cache_sh),
                             donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(state_sds["params"], cache_sds, tokens,
                                   cur_pos, active)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        if verbose:
            print(f"== {arch_id} × {shape_name} × "
                  f"{'multi' if multi_pod else 'single'}-pod ==")
            print(mem)                       # proves it fits
            ca = compiled.cost_analysis()
            print({k: v for k, v in (ca[0] if isinstance(ca, list)
                                     else ca).items()
                   if k in ("flops", "bytes accessed")})
        mf = R.model_flops_per_round(mcfg, shape, fed)
        rl = R.analyze(compiled, mesh, model_flops_per_chip=mf / mesh.devices.size)
        result = {
            "arch": arch_id, "shape": shape_name, "multi_pod": multi_pod,
            "status": "ok", "t_lower_s": round(t_lower, 1),
            "t_compile_s": round(t_compile, 1),
            "model_flops": mf,
            "model_flops_per_chip": mf / rl.chips,
            "useful_flop_frac": (mf / rl.chips) / rl.flops if rl.flops else 0,
            **rl.as_dict(),
        }
        return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="dryrun_results.jsonl")
    ap.add_argument("--serve-sharding", default="serve",
                    choices=["train", "serve"])
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    with open(args.out, "a") as f:
        for arch in archs:
            for shape in shapes:
                for mp in meshes:
                    try:
                        res = lower_one(arch, shape, mp,
                                        serve_sharding=args.serve_sharding)
                    except Exception as e:
                        traceback.print_exc()
                        res = {"arch": arch, "shape": shape, "multi_pod": mp,
                               "status": "error", "error": repr(e)[:500]}
                    print(json.dumps({k: v for k, v in res.items()
                                      if k not in ("flops", "bytes")},
                                     default=str)[:400])
                    f.write(json.dumps(res, default=str) + "\n")
                    f.flush()


if __name__ == "__main__":
    main()
