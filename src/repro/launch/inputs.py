"""ShapeDtypeStruct stand-ins for every model input, with shardings attached
(weak-type correct, shardable, no device allocation) — the dry-run lowers
against these.

Shape semantics per assigned input shape:
* train_4k    — one FedADC round: tokens (CP, CS, H, b, L) with
                CP·CS = clients_per_round, H local steps, b·(CP·CS·H) =
                global_batch sequences per round.
* prefill_32k — serve-side full forward: tokens (B, L).
* decode_32k / long_500k — serve_step: tokens (B, 1), cache of seq_len.

Modality stubs (the assignment's one carve-out): whisper gets frame
embeddings (B, L, d_model) standing in for the conv/mel frontend; the VLM
gets patch embeddings (B, n_patch, 1024) standing in for InternViT.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import FedConfig, ModelConfig, RunConfig, ShapeConfig
from repro.models.transformer import VIS_EMBED_DIM
from repro.sharding import specs as S


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def round_decomposition(shape: ShapeConfig, fed: FedConfig, mesh: Mesh,
                        multi_pod: bool) -> Tuple[int, int, int, int]:
    """global_batch -> (CP, CS, H, b).  The local batch b is kept a multiple
    of the data-axis size so it shards."""
    data = mesh.shape.get("data", 1)
    CP = mesh.shape.get("pod", 1) if multi_pod else 1
    H = fed.local_steps
    R = fed.clients_per_round
    assert R % CP == 0, "clients_per_round must divide over pods"
    CS = R // CP
    b = shape.global_batch // (R * H)
    assert b * R * H == shape.global_batch, (
        f"global_batch {shape.global_batch} != clients {R} × H {H} × b {b}")
    return CP, CS, H, b


def train_inputs(mcfg: ModelConfig, shape: ShapeConfig, fed: FedConfig,
                 mesh: Mesh, multi_pod: bool) -> Dict:
    CP, CS, H, b = round_decomposition(shape, fed, mesh, multi_pod)
    L = shape.seq_len
    lead = "pod" if (multi_pod and "pod" in mesh.shape) else None
    bspec = P(lead, None, None, "data" if b % mesh.shape.get("data", 1) == 0
              else None, None)
    batch = {
        "tokens": _sds((CP, CS, H, b, L), jnp.int32, mesh, bspec),
        "labels": _sds((CP, CS, H, b, L), jnp.int32, mesh, bspec),
    }
    from repro.federated.transport import Transport
    if Transport(fed).ef_enabled:
        # the round's client identities, addressing the sharded EF store
        batch["client_ids"] = _sds((CP, CS), jnp.int32, mesh,
                                   P(lead, None))
    if mcfg.is_encoder_decoder:
        fspec = P(*bspec, None)
        batch["frames"] = _sds((CP, CS, H, b, min(L, mcfg.max_seq_len),
                                mcfg.d_model), jnp.bfloat16, mesh, fspec)
        # decoder tokens bounded by learned positions
        batch["tokens"] = _sds((CP, CS, H, b, min(L, mcfg.max_seq_len)),
                               jnp.int32, mesh, bspec)
        batch["labels"] = batch["tokens"]
    if mcfg.n_patch_tokens > 0:
        pspec = P(*bspec, None)
        batch["patch_embeds"] = _sds((CP, CS, H, b, mcfg.n_patch_tokens,
                                      VIS_EMBED_DIM), jnp.bfloat16, mesh, pspec)
    return batch


def prefill_inputs(mcfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                   multi_pod: bool) -> Dict:
    B, L = shape.global_batch, shape.seq_len
    bspec = S.serve_batch_spec(mesh, B, multi_pod)
    lead = bspec[0]
    batch = {"tokens": _sds((B, L), jnp.int32, mesh, P(lead, None)),
             "labels": _sds((B, L), jnp.int32, mesh, P(lead, None))}
    if mcfg.is_encoder_decoder:
        batch["frames"] = _sds((B, L, mcfg.d_model), jnp.bfloat16, mesh,
                               P(lead, None, None))
        batch["tokens"] = _sds((B, min(L, mcfg.max_seq_len)), jnp.int32,
                               mesh, P(lead, None))
        batch["labels"] = batch["tokens"]
    if mcfg.n_patch_tokens > 0:
        batch["patch_embeds"] = _sds((B, mcfg.n_patch_tokens, VIS_EMBED_DIM),
                                     jnp.bfloat16, mesh, P(lead, None, None))
    return batch


def decode_inputs(mcfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                  multi_pod: bool, cache_dtype=jnp.bfloat16):
    """-> (cache_sds_with_shardings, tokens_sds, cur_pos_sds, active_sds).

    Decoder-only archs lower the continuous-batching inner step: per-slot
    positions (B,) plus an (B,) active mask — exactly what the serving
    scheduler drives.  Enc-dec keeps the batch-synchronous scalar cur_pos
    (active is None)."""
    from repro.launch.serve import cache_shapes
    B, L = shape.global_batch, shape.seq_len
    cache = cache_shapes(mcfg, B, L, cache_dtype)
    shardings = S.cache_shardings(cache, mesh)
    cache_sds = jax.tree.map(
        lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sh),
        cache, shardings)
    bspec = S.serve_batch_spec(mesh, B, multi_pod)
    tokens = _sds((B, 1), jnp.int32, mesh, bspec)
    if mcfg.is_encoder_decoder:
        cur_pos = jax.ShapeDtypeStruct((), jnp.int32,
                                       sharding=NamedSharding(mesh, P()))
        active = None
    else:
        cur_pos = _sds((B,), jnp.int32, mesh, P(bspec[0]))
        active = _sds((B,), jnp.bool_, mesh, P(bspec[0]))
    return cache_sds, tokens, cur_pos, active


def state_inputs(mcfg: ModelConfig, fed: FedConfig, run: RunConfig,
                 mesh: Mesh, mode: str = "train", fsdp_over_pod=False,
                 tp_off=False):
    """FedState ShapeDtypeStructs with parameter shardings attached."""
    from repro.launch.train import state_shapes
    st = state_shapes(mcfg, fed, run)
    p_sh = S.param_shardings(st["params"], mesh, mode=mode,
                             fsdp_over_pod=fsdp_over_pod, tp_off=tp_off)
    s_sh = jax.tree.map(lambda leaf: None, st["server"])
    if st["server"]:
        s_sh = S.param_shardings(st["server"], mesh, mode=mode,
                                 fsdp_over_pod=fsdp_over_pod, tp_off=tp_off)

    def attach(sds, sh):
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sh)
    out = {
        "params": jax.tree.map(attach, st["params"], p_sh),
        "server": jax.tree.map(attach, st["server"], s_sh) if st["server"]
        else {},
        "round": jax.ShapeDtypeStruct((), jnp.int32,
                                      sharding=NamedSharding(mesh, P())),
    }
    if "clients" in st:
        # sharded per-client store: leading n_clients axis replicated, the
        # parameter dims shard like the parameter they mirror
        # (param_shardings pads a leading None for stacked runs)
        c_sh = S.param_shardings(st["clients"], mesh, mode=mode,
                                 fsdp_over_pod=fsdp_over_pod, tp_off=tp_off)
        out["clients"] = jax.tree.map(attach, st["clients"], c_sh)
    if "refs" in st:
        # lossy delta downlink codec reference (θ, ctx): every leaf mirrors
        # a parameter, so it shards exactly like the parameter tree
        r_sh = S.param_shardings(st["refs"], mesh, mode=mode,
                                 fsdp_over_pod=fsdp_over_pod, tp_off=tp_off)
        out["refs"] = jax.tree.map(attach, st["refs"], r_sh)
    return out
