"""Pod-scale federated round engine.

Maps one FedADC communication round onto the production mesh:

* the model is FSDP-sharded over "data" and tensor-parallel over "model";
* each client's H local steps run as an inner ``lax.scan`` (local batch
  sharded over "data");
* clients are processed client-serially per pod (``lax.scan``, delta
  accumulation — linearity of the FedADC aggregation makes waves exact),
  and client-parallel across the "pod" axis (``vmap``; the Δ̄/momentum
  all-reduce over pods is the ONLY cross-pod collective per round, which is
  the FL communication pattern);
* the server update (pseudo-momentum + model update) is sharded pointwise;
* both wire directions ride the round protocol's ``Transport`` (DESIGN.md
  §Transport): the (θ_t, ctx) broadcast through the downlink codec, each
  client delta through the uplink codec inside the client-serial scan;
* per-client error-feedback residuals live in a mesh-resident
  ``sharded_*`` client store inside the train state (``state["clients"]``,
  leading axis ``fed.n_clients``; parameter dims shard like the parameter
  they mirror) — this engine is no longer stateless-client for EF, which
  lifts the old "lossy compression + error_feedback rejected on the pod
  engine" restriction.

``train_step(state, batch)`` is one full communication round:
batch["tokens"]: (CP, CS, H, b, L) where CP·CS = clients_per_round and
H = fed.local_steps.  When the EF store is active, batch["client_ids"]
(CP, CS) int32 names the round's clients; it defaults to slots 0..R−1.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig, ModelConfig, RunConfig
from repro.core import distillation as D
from repro.core import tree as T
from repro.core.strategies import get_strategy
from repro.federated import aggregation as A
from repro.federated import store as CS
from repro.federated.fleet import hierarchy as FH
from repro.federated.reference import ReferenceStore
from repro.federated.transport import Transport
from repro.models.registry import get_model
from repro.telemetry import drift as drift_metrics

POD_SUPPORTED = ("fedavg", "slowmo", "fedadc", "fedadc_double", "fedprox",
                 "fedadc+")


def _wire_dtype(run: RunConfig):
    """The dtype client deltas (and hence EF residuals) live in: the
    compute dtype under the mixed-precision round, else the param dtype."""
    mixed = (jnp.dtype(run.param_dtype) == jnp.float32
             and jnp.dtype(run.compute_dtype) == jnp.bfloat16)
    return jnp.dtype(run.compute_dtype) if mixed else jnp.dtype(run.param_dtype)


def _broadcast_inputs(strategy, params, server, fed: FedConfig,
                      run: RunConfig):
    """(θ_t, server view, ctx) in the wire dtype: the mixed-precision round
    broadcasts bf16 (§Perf iteration 7) — shared by ``init_state`` (the
    delta codec's round-0 reference must match the round-0 broadcast
    bitwise) and ``train_step``."""
    compute_dtype = jnp.dtype(run.compute_dtype)
    mixed = (jnp.dtype(run.param_dtype) == jnp.float32
             and compute_dtype == jnp.bfloat16)
    theta_t = T.cast(params, compute_dtype) if mixed else params
    server_view = server
    if mixed and "m" in server:
        server_view = dict(server, m=T.cast(server["m"], compute_dtype))
    ctx = strategy.client_setup(server_view, theta_t, fed)
    return theta_t, server_view, ctx, mixed


def init_state(rng, mcfg: ModelConfig, fed: FedConfig, run: RunConfig):
    model = get_model(mcfg)
    dtype = jnp.dtype(run.param_dtype)
    params = model.init(rng, mcfg, dtype=dtype)
    strategy = get_strategy(fed.strategy)
    state = {"params": params,
             "server": strategy.server_init(params),
             "round": jnp.zeros((), jnp.int32)}
    transport = Transport(fed)
    if transport.ef_enabled:
        # mesh-resident per-client EF store (leading axis n_clients); dtype
        # matches the wire the residual is the complement of
        ef_template = T.cast(params, _wire_dtype(run))
        state["clients"] = {"ef": CS.sharded_init(ef_template, fed.n_clients)}
    if transport.stateful_downlink:
        # only the *lossy* delta codec is stateful: its broadcast reference
        # lives in the train state (sharded like the parameters it mirrors)
        # so it survives jit and rides the pod mesh; the round-0 reference
        # is the initial sync.  The lossless delta downlink derives its
        # reference from θ_t itself, so the train state carries none.
        theta_w, _, ctx0, _ = _broadcast_inputs(strategy, params,
                                                state["server"], fed, run)
        state["refs"] = {
            "downlink": transport.init_downlink_ref(theta_w, ctx0)}
    return state


def state_shapes(mcfg: ModelConfig, fed: FedConfig, run: RunConfig):
    """abstract state (no allocation) for the dry-run."""
    rng = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda r: init_state(r, mcfg, fed, run), rng)


def _token_histogram(tokens, vocab: int, valid=None):
    """Client token statistics for the FedADC+ ρ vector; positions with
    `valid` False (padding) are excluded."""
    flat = tokens.reshape(-1)
    w = jnp.ones_like(flat, jnp.float32) if valid is None \
        else valid.reshape(-1).astype(jnp.float32)
    return jnp.zeros((vocab,), jnp.float32).at[flat].add(w)


def _local_objective(model, mcfg: ModelConfig, fed: FedConfig,
                     run: RunConfig):
    """Builds loss(theta, step_batch, theta_t, rho) for one local step."""
    use_pallas = fed.use_pallas

    def loss(theta, sb, theta_t, rho):
        if not fed.distill:
            l, aux = model.loss_fn(theta, sb, mcfg, use_pallas, run.remat)
            return l
        # FedADC+ self-confidence KD: teacher = global model θ_t (eq. 7-9),
        # ρ from the client's token statistics.
        s_logits, aux_l = model.forward(theta, sb, mcfg, use_pallas, run.remat)
        t_logits, _ = model.forward(jax.lax.stop_gradient(theta_t), sb, mcfg,
                                    use_pallas, run.remat)
        if mcfg.n_patch_tokens > 0 and "patch_embeds" in sb:
            np_ = sb["patch_embeds"].shape[1]
            s_logits, t_logits = s_logits[:, np_:], t_logits[:, np_:]
        labels = sb["labels"][:, 1:]
        s_l, t_l = s_logits[:, :-1], t_logits[:, :-1]
        mask = (labels >= 0)
        V = s_l.shape[-1]
        flat_s = s_l.reshape(-1, V)
        flat_t = t_l.reshape(-1, V)
        flat_y = jnp.clip(labels.reshape(-1), 0)
        kd, _ = D.masked_self_confidence_kd_loss(
            flat_s, flat_t, flat_y, rho, fed.distill_lambda, fed.distill_tau,
            mask.reshape(-1))
        return kd + 0.0 * aux_l
    return loss


def make_train_step(mcfg: ModelConfig, fed: FedConfig, run: RunConfig,
                    client_parallel: int = 1, telemetry=None):
    """-> train_step(state, batch).  One communication round.

    With an enabled ``telemetry``, the aux dict gains a ``"telemetry"``
    sub-dict of in-jit drift scalars (streaming weighted dispersion,
    ``||Δ̄||``, momentum alignment, EF-residual norm); with telemetry off
    (the default) the returned program is bit-identical to the
    pre-telemetry one — the gate is a static Python fact, never a traced
    value."""
    with_metrics = telemetry is not None and telemetry.enabled
    if fed.strategy not in POD_SUPPORTED:
        raise ValueError(
            f"pod engine supports stateless-client strategies {POD_SUPPORTED};"
            f" use the simulator for {fed.strategy} (per-client state).")
    if fed.aggregator == "drag" and fed.strategy in ("fedavg", "fedprox"):
        raise ValueError(
            "drag aggregation in the pod engine needs a server-momentum "
            "reference (slowmo/fedadc/fedadc_double); the client-serial "
            "scan has no round mean to fall back on.")
    transport = Transport(fed)
    transported = transport.up is not None
    sparse_native = transport.sparse_native
    ef_enabled = transport.ef_enabled
    lossy_down = transport.down is not None and transport.down.lossy
    model = get_model(mcfg)
    strategy = get_strategy(fed.strategy)
    loss_fn = _local_objective(model, mcfg, fed, run)

    def client_delta(theta_t, ctx, cb):
        """cb: dict with leading (H, b) -> (delta, mean loss)."""
        rho = None
        if fed.distill:
            hist = _token_histogram(cb["tokens"], mcfg.vocab_size,
                                    valid=(cb["labels"] >= 0))
            rho = hist / jnp.maximum(hist.max(), 1.0)

        def local(carry, sb):
            theta, extra = carry

            def grad_fn(th, _):
                l, g = jax.value_and_grad(loss_fn)(th, sb, theta_t, rho)
                return g, l
            theta, extra, l = strategy.local_step(theta, ctx, grad_fn, None,
                                                  fed, extra)
            return (theta, extra), l

        extra0 = strategy.init_extra(theta_t, fed)
        (theta_H, _), ls = jax.lax.scan(local, (theta_t, extra0), cb)
        return T.sub(theta_t, theta_H), jnp.mean(ls)

    def per_group(theta_t, ctx, ref, cbs, gkey, efs=None):
        """cbs: dict with leading (CS, H, b) — serial clients, weighted
        Δ-accumulation.  The aggregator weight for each client is computed in
        streaming form (repro.federated.aggregation.streaming_weight) against
        the server-momentum reference direction, so DRAG-style adaptive
        weighting works without materialising the CS deltas.  Each client's
        delta rides the transport's uplink round trip against its gathered
        EF residual (`efs`, leading CS; zeros when EF is off) before
        weighting/accumulation, so the aggregate is built from the server's
        wire reconstructions and the updated residuals flow back out for the
        scatter into the sharded client store.  `efs` is None when the EF
        store is off — each client then compresses against a zero residual
        (the pre-store behaviour) and a scalar dummy rides the scan ys."""
        cs = jax.tree.leaves(cbs)[0].shape[0]
        ckeys = jax.random.split(gkey, cs)

        def serial(carry, inp):
            cb, ck = inp[:2]
            ef = inp[2] if efs is not None else None
            if with_metrics:
                acc, wsum, sqsum = carry
            else:
                acc, wsum = carry
            d, l = client_delta(theta_t, ctx, cb)
            new_ef = ef if efs is not None else jnp.zeros(())
            if transported:
                # sparse-native: encode only — the (values, indices) wire
                # is scatter-accumulated below at k-cost, and the EF
                # residual from encode is the exact complement the
                # roundtrip would return (the scan carry stays
                # dense-output/sparse-input)
                up = transport.uplink_encode if sparse_native \
                    else transport.uplink
                d, new_ef = up(d, T.zeros_like(d) if ef is None else ef, ck)
                if efs is None:
                    new_ef = jnp.zeros(())   # residual not carried
            w = A.streaming_weight(d, ref, fed.aggregator, fed.drag_lambda)
            # Σ w·Δ accumulates in fp32 regardless of the wire dtype: a
            # bf16 running sum loses the late clients to rounding once the
            # partial sum's ulp outgrows the increments; cast on write
            # happens after the cross-pod aggregation below
            if sparse_native:
                # per coordinate this is the same client-ordered fp32 add
                # chain as the dense decode path (whose off-support adds
                # are exact +0.0 no-ops), so the two are bit-identical
                acc = jax.tree.map(
                    lambda wl, a: a.reshape(-1).at[wl.indices].add(
                        w * wl.values.astype(jnp.float32)).reshape(a.shape),
                    d, acc, is_leaf=A.is_sparse_leaf)
            else:
                acc = jax.tree.map(
                    lambda a, di: a + w * di.astype(jnp.float32), acc, d)
            if with_metrics:
                # the only telemetry cost in the scan: one fp32 scalar,
                # Σ w·||Δ||², for the streaming-dispersion identity
                sqsum = sqsum + drift_metrics.streaming_sq_norm(d, w)
                return (acc, wsum + w, sqsum), (l, new_ef)
            return (acc, wsum + w), (l, new_ef)
        acc0 = (T.cast(T.zeros_like(theta_t), jnp.float32), jnp.zeros(()))
        if with_metrics:
            acc0 = acc0 + (jnp.zeros(()),)
        xs = (cbs, ckeys) if efs is None else (cbs, ckeys, efs)
        carry_out, (ls, new_efs) = jax.lax.scan(serial, acc0, xs)
        acc, wsum = carry_out[:2]
        sqsum = carry_out[2] if with_metrics else jnp.zeros(())
        return acc, wsum, jnp.mean(ls), new_efs, sqsum

    compute_dtype = jnp.dtype(run.compute_dtype)

    def train_step(state: Dict, batch: Dict):
        batch = dict(batch)
        client_ids = batch.pop("client_ids", None)
        theta_master = state["params"]
        # mixed-precision round (§Perf iteration 7): the server keeps the
        # master θ/m in param_dtype; the per-round broadcast, local steps,
        # and Δ accumulation run in compute_dtype (bf16) — halves the param
        # all-gathers and activation traffic; Δ̄ is upcast before the f32
        # server update, which preserves the momentum-accumulation
        # precision the FedADC recursion needs.
        theta_t, server_ctx_state, ctx, mixed = _broadcast_inputs(
            strategy, theta_master, state["server"], fed, run)
        ref = A.reference_direction(server_ctx_state) \
            if fed.aggregator == "drag" else None
        CP, CSn = batch["tokens"].shape[:2]
        # per-round compression randomness, deterministic in (run seed,
        # round index) so replicate experiments draw independent noise
        round_key = jax.random.fold_in(jax.random.PRNGKey(run.seed),
                                       state["round"])
        pod_keys = jax.random.split(round_key, CP)
        new_dref = None
        if transport.down is not None:
            # clients everywhere train on the broadcast reconstruction;
            # only the lossy delta codec keeps reference state, and it
            # rides state["refs"] ("refs" membership is a static Python
            # fact — the lossless config traces the ref-free graph)
            dkey = jax.random.fold_in(round_key, 0xD0) if lossy_down \
                else None
            dref = state["refs"]["downlink"] if "refs" in state else None
            theta_t, ctx, new_dref = transport.broadcast(
                theta_t, ctx, dkey, dref)
        if ef_enabled:
            if client_ids is None:
                # default identification: slot i of the round is client i
                client_ids = jnp.arange(CP * CSn,
                                        dtype=jnp.int32).reshape(CP, CSn)
            efs = jax.tree.map(
                lambda x: x.reshape((CP, CSn) + x.shape[1:]),
                CS.sharded_gather(state["clients"]["ef"],
                                  client_ids.reshape(-1)))
        else:
            efs = None
        if CP == 1:
            squeezed = jax.tree.map(lambda x: x[0], batch)
            efs0 = None if efs is None else jax.tree.map(lambda x: x[0], efs)
            acc, wsum, loss, new_efs, sqsum = per_group(
                theta_t, ctx, ref, squeezed, pod_keys[0], efs0)
            group_means = jax.tree.map(
                lambda a: (a / wsum.astype(a.dtype))[None], acc)
            gweights = wsum[None]
            sq_total, w_total = sqsum, wsum
            if efs is not None:
                new_efs = jax.tree.map(lambda x: x[None], new_efs)
        else:
            if efs is None:
                accs, wsums, losses, new_efs, sqsums = jax.vmap(
                    lambda cbs, gk: per_group(theta_t, ctx, ref, cbs, gk)
                )(batch, pod_keys)
            else:
                accs, wsums, losses, new_efs, sqsums = jax.vmap(
                    lambda cbs, gk, e: per_group(theta_t, ctx, ref, cbs,
                                                 gk, e)
                )(batch, pod_keys, efs)
            group_means = jax.tree.map(
                lambda a: a / wsums.reshape((-1,) + (1,) * (a.ndim - 1)
                                            ).astype(a.dtype), accs)
            gweights = wsums
            sq_total, w_total = jnp.sum(sqsums), jnp.sum(wsums)
            loss = jnp.mean(losses)
        # per-pod weighted means recombine exactly through the shared hook:
        # Δ̄ = Σ_p W_p·Δ̄_p / Σ_p W_p = Σ_i w_i·Δ_i / Σ_i w_i by linearity.
        # The per-group sums arrive as fp32 accumulators; the mixed round
        # keeps Δ̄ in f32 for the server update, a pure-low-precision run
        # casts back to the param dtype on write.  Under the two-tier fleet
        # topology the CP pod partials chunk into fleet_regions regional
        # partials before the global combine (identity at R=1 — DESIGN.md
        # §Fleet); each pod is already a stage-1 unit, so nothing changes
        # inside the client-serial scan.
        if fed.fleet_regions > 0:
            mean_delta = FH.hierarchical_combine(group_means, gweights, fed,
                                                 strategy)
        else:
            mean_delta = strategy.server_aggregate(group_means, gweights, fed)
        mean_delta = T.cast(mean_delta,
                            jnp.float32 if mixed else jnp.dtype(
                                run.param_dtype))
        new_params, new_server = strategy.server_update(
            state["server"], theta_master, mean_delta, fed)
        new_state = {"params": new_params, "server": new_server,
                     "round": state["round"] + 1}
        if "refs" in state:
            new_state["refs"] = {"downlink": new_dref}
        if ef_enabled:
            flat_new = jax.tree.map(
                lambda x: x.reshape((-1,) + x.shape[2:]), new_efs)
            new_state["clients"] = {"ef": CS.sharded_scatter(
                state["clients"]["ef"], client_ids.reshape(-1), flat_new)}
        aux = {"loss": loss}
        if with_metrics:
            metrics = {
                "delta_dispersion": drift_metrics.streaming_dispersion(
                    sq_total, w_total, mean_delta),
                "update_norm": drift_metrics.update_norm(mean_delta),
            }
            if "m" in state["server"]:
                metrics["momentum_alignment"] = \
                    drift_metrics.momentum_alignment(state["server"]["m"],
                                                     mean_delta)
            if ef_enabled:
                metrics["ef_residual_norm"] = drift_metrics.ef_residual_norm(
                    jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]),
                                 new_efs))
            aux["telemetry"] = metrics
        return new_state, aux

    # measured-byte accounting (bugfix): the pod engine drives real wire
    # traffic through `transport` but used to leave the byte counters at
    # zero — the only tree a consumer could size was the dense master-dtype
    # reconstruction the decode side materialises (fp32 under the mixed
    # round: ~2× the actual bf16 sparse wire).  Templates come from
    # eval_shape (no allocation) on the WIRE trees: the uplink delta and
    # the broadcast both live in the wire dtype (_wire_dtype).
    state_t = state_shapes(mcfg, fed, run)
    theta_w_t, _, ctx_t = jax.eval_shape(
        lambda p, s: _broadcast_inputs(strategy, p, s, fed, run)[:3],
        state_t["params"], state_t["server"])
    transport.set_wire_templates(theta_w_t, (theta_w_t, ctx_t))

    # the pod engine's downlink reference layer: multicast accounting and
    # (when fed.downlink_unicast) per-client catch-up/resync bookkeeping —
    # host-side by design, mirroring the counters
    refs = ReferenceStore(fed, transport, telemetry=telemetry)

    def account_round(n_clients: Optional[int] = None, resync: bool = False,
                      client_ids=None):
        """Advance the measured-byte counters by one round's traffic.
        Host-side by design: callers jit train_step themselves, so the
        counters cannot advance inside it — call once per executed round.
        Multicast (default): `n_clients` dispatched clients, resync=True
        for the delta downlink's round-0 initial sync.  Unicast
        (fed.downlink_unicast): pass `client_ids` and each client is
        classified fresh/catch-up/resync against the last round it saw."""
        if client_ids is not None:
            ids = [int(c) for c in np.asarray(client_ids).reshape(-1)]
            refs.dispatch(ids, account_round.round_no)
            account_round.round_no += 1
            transport.account_uplink(len(ids))
            return
        transport.account_downlink(n_clients, resync=resync)
        transport.account_uplink(n_clients)

    account_round.round_no = 0
    train_step.transport = transport
    train_step.refs = refs
    train_step.account_round = account_round
    return train_step
