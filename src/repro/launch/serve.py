"""Serving steps for the inference shapes.

* ``prefill_step`` — full-sequence forward (logits); lowered for the
  prefill_32k shape.
* ``serve_step``   — ONE new token against a KV/state cache of seq_len;
  lowered for decode_32k / long_500k.  Greedy sampling.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.registry import get_model
from repro.models import encdec


def make_prefill_step(mcfg: ModelConfig, use_pallas: bool = False):
    model = get_model(mcfg)

    def prefill_step(params, batch):
        logits, _ = model.forward(params, batch, mcfg, use_pallas,
                                  logits_slice="last")
        return logits[:, -1].argmax(-1).astype(jnp.int32)
    return prefill_step


def make_serve_step(mcfg: ModelConfig):
    model = get_model(mcfg)

    def serve_step(params, cache, tokens, cur_pos):
        logits, cache = model.decode_step(params, cache, tokens, cur_pos, mcfg)
        next_tok = logits.argmax(-1).astype(jnp.int32)
        return next_tok[:, None], cache
    return serve_step


def cache_shapes(mcfg: ModelConfig, batch: int, max_len: int,
                 dtype=jnp.bfloat16):
    model = get_model(mcfg)
    return jax.eval_shape(
        lambda: model.init_cache(mcfg, batch, max_len, dtype))
