"""Serving steps for the inference shapes.

These are the jit'd inner steps the continuous-batching scheduler
(``repro.serving``) drives:

* ``prefill_step``       — full-sequence forward (argmax of last logits);
  lowered for the prefill_32k shape.
* ``serve_step``         — ONE new token per slot against a KV/state cache:
  per-slot positions (B,) and an ``active`` mask so slots at different
  depths (or empty slots) batch into a single call; lowered for
  decode_32k / long_500k.  Returns raw logits — sampling is the
  scheduler's job (per-request greedy / temperature / top-k).
* ``prefill_chunk_step`` — ingest a chunk of prompt tokens for ONE slot
  (batch=1 cache slice) in a single jit call, via a scan of decode steps;
  the scheduler interleaves these chunks with batched decode so a long
  prompt never stalls in-flight generation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.registry import get_model


def make_prefill_step(mcfg: ModelConfig, use_pallas: bool = False):
    model = get_model(mcfg)

    def prefill_step(params, batch):
        logits, _ = model.forward(params, batch, mcfg, use_pallas,
                                  logits_slice="last")
        return logits[:, -1].argmax(-1).astype(jnp.int32)
    return prefill_step


def make_serve_step(mcfg: ModelConfig):
    """-> serve_step(params, cache, tokens (B,1), cur_pos (B,) | scalar,
    active (B,) bool | None) -> (logits (B,V), cache)."""
    model = get_model(mcfg)

    def serve_step(params, cache, tokens, cur_pos, active=None):
        return model.decode_step(params, cache, tokens, cur_pos, mcfg,
                                 active=active)
    return serve_step


def make_prefill_chunk_step(mcfg: ModelConfig, chunk: int):
    """-> chunk_step(params, slot_cache (batch=1), tokens (1, chunk),
    pos0 scalar, n_valid scalar) -> (last_logits (1,V), slot_cache).

    Scans ``chunk`` decode steps over one slot's cache slice; positions
    run pos0..pos0+chunk-1.  Steps at/after ``n_valid`` are padding: their
    cache writes are masked out and ``last_logits`` holds the logits of
    the final *valid* token, so a partial last chunk is bit-exact."""
    model = get_model(mcfg)

    def chunk_step(params, slot_cache, tokens, pos0, n_valid):
        def body(carry, i):
            cache, last = carry
            valid = i < n_valid
            logits, cache = model.decode_step(
                params, cache, jax.lax.dynamic_slice_in_dim(tokens, i, 1, 1),
                pos0 + i, mcfg, active=valid[None])
            last = jnp.where(valid, logits.astype(jnp.float32), last)
            return (cache, last), None
        last0 = jnp.zeros((1, mcfg.vocab_size), jnp.float32)
        (slot_cache, last), _ = jax.lax.scan(
            body, (slot_cache, last0), jnp.arange(chunk))
        return last, slot_cache
    return chunk_step


def cache_shapes(mcfg: ModelConfig, batch: int, max_len: int,
                 dtype=jnp.bfloat16):
    model = get_model(mcfg)
    return jax.eval_shape(
        lambda: model.init_cache(mcfg, batch, max_len, dtype))
