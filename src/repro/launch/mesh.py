"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS before first jax init; smoke tests see the single real CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod (TPU v5e pod slice); the multi-pod mesh
    adds a leading "pod" axis of 2 (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke tests (1×1, same axis names)."""
    return jax.make_mesh((1, 1), ("data", "model"))
