"""Fused FedADC update kernels (the paper's per-step hot spot).

Every local iteration touches the full parameter vector three times
(read θ, read g, read m̄) and writes once; the server update reads three
and writes two.  Unfused, XLA materialises the intermediate (g + m̄) in HBM.
These kernels fuse the AXPY chains into single VMEM-resident passes —
arithmetic intensity is tiny (<1 flop/byte) so the win is purely removing
redundant HBM traffic (~33% fewer bytes on the local step, ~40% on the
server step).

Tensors are processed as flattened (rows, 128) tiles; the ops.py wrapper
pads each leaf to a lane-aligned size, so kernels only ever see
hardware-aligned blocks (8×128 float32 VREG tiles on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
BLOCK_ROWS = 512          # 512×128 fp32 = 256 KiB per operand in VMEM


def _axpy_kernel(x_ref, y_ref, o_ref, *, a):
    o_ref[...] = x_ref[...] + a * y_ref[...]


def _local_update_kernel(theta_ref, g_ref, mbar_ref, o_ref, *, eta):
    # θ' = θ − η·(g + m̄)   — one pass, no HBM intermediate
    o_ref[...] = theta_ref[...] - eta * (g_ref[...] + mbar_ref[...])


def _server_update_kernel(theta_ref, m_ref, delta_ref, theta_o, m_o, *,
                          gamma, alpha_eta):
    # m' = Δ̄ + γ·m ; θ' = θ − αη·m'
    m_new = delta_ref[...] + gamma * m_ref[...]
    m_o[...] = m_new
    theta_o[...] = theta_ref[...] - alpha_eta * m_new


def _tiled_call(kernel, arrays, n_out, interpret, **kw):
    """arrays: same-shape 2D (rows, LANE) operands."""
    rows = arrays[0].shape[0]
    block = min(BLOCK_ROWS, rows)
    grid = (pl.cdiv(rows, block),)
    spec = pl.BlockSpec((block, LANE), lambda i: (i, 0))
    out_shape = [jax.ShapeDtypeStruct(arrays[0].shape, arrays[0].dtype)
                 for _ in range(n_out)]
    return pl.pallas_call(
        functools.partial(kernel, **kw),
        grid=grid,
        in_specs=[spec] * len(arrays),
        out_specs=[spec] * n_out if n_out > 1 else spec,
        out_shape=out_shape if n_out > 1 else out_shape[0],
        interpret=interpret,
    )(*arrays)


def fused_axpy_2d(x, y, a, interpret=False):
    return _tiled_call(_axpy_kernel, [x, y], 1, interpret, a=a)


def local_update_2d(theta, g, m_bar, eta, interpret=False):
    return _tiled_call(_local_update_kernel, [theta, g, m_bar], 1,
                       interpret, eta=eta)


def server_update_2d(theta, m, delta_bar, gamma, alpha_eta, interpret=False):
    return _tiled_call(_server_update_kernel, [theta, m, delta_bar], 2,
                       interpret, gamma=gamma, alpha_eta=alpha_eta)
