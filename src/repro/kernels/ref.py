"""Pure-jnp oracles for every Pallas kernel.  Tests assert_allclose each
kernel (interpret=True on CPU) against these references across shape/dtype
sweeps."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# fedadc_update
# ---------------------------------------------------------------------------
def fused_axpy(x, y, a):
    """x + a*y."""
    return x + a * y


def fedadc_local_update(theta, g, m_bar, eta):
    """Heavy-ball embedded step (Alg. 3 blue): θ − η(g + m̄)."""
    return theta - eta * (g + m_bar)


def fedadc_server_update(theta, m, delta_bar, gamma, alpha_eta):
    """Alg. 3 lines 17+19: m' = Δ̄ + γ·m ; θ' = θ − αη·m'.  -> (θ', m')."""
    m_new = delta_bar + gamma * m
    return theta - alpha_eta * m_new, m_new


def weighted_delta_reduce(deltas, weights):
    """Σ_k w_k·Δ_k for a single stacked array (K, ...); accumulates in at
    least fp32 (bf16 partial sums drown the late terms as K grows; f64
    inputs keep f64), cast back to the delta dtype on write."""
    acc_t = jnp.promote_types(deltas.dtype, jnp.float32)
    out = jnp.tensordot(weights.astype(acc_t), deltas.astype(acc_t),
                        axes=([0], [0]))
    return out.astype(deltas.dtype)


@functools.partial(jax.jit, static_argnums=(3, 4))
def sparse_weighted_delta_reduce(values, indices, weights, shape, dtype):
    """Σ_k w_k · scatter(values_k @ indices_k) for one leaf without ever
    materialising the K dense reconstructions: a weighted segment-sum over
    the stacked (K, k) wire pairs into the dense `shape` template.
    Accumulates in at least fp32 (same contract as weighted_delta_reduce),
    cast to the leaf dtype on the final write.  Duplicate indices within a
    client accumulate (scatter-add semantics)."""
    n = 1
    for d in shape:        # static python ints — no host sync in the trace
        n *= d
    acc_t = jnp.promote_types(values.dtype, jnp.float32)
    wv = (weights.astype(acc_t)[:, None] * values.astype(acc_t)).reshape(-1)
    out = jax.ops.segment_sum(wv, indices.reshape(-1).astype(jnp.int32),
                              num_segments=n)
    return out.astype(dtype).reshape(shape)


# ---------------------------------------------------------------------------
# delta compression (uplink quantise/sparsify round trips)
# ---------------------------------------------------------------------------
def qsgd_quantize(v, u, scale, s):
    """QSGD stochastic uniform quantise-dequantise.  `u` is the uniform
    draw (same shape as v), `scale` the per-leaf max magnitude, `s` the
    number of magnitude levels.  -> (dequantised q, residual v − q)."""
    dtype = v.dtype
    inv = jnp.where(scale > 0,
                    jnp.asarray(float(s), dtype) / jnp.maximum(scale, 1e-30),
                    jnp.zeros((), dtype))
    y = jnp.abs(v) * inv
    lower = jnp.floor(y)
    level = lower + (u < (y - lower)).astype(dtype)
    q = jnp.sign(v) * level * (scale / jnp.asarray(float(s), dtype))
    return q, v - q


def topk_threshold_select(v, thresh):
    """Magnitude-threshold select (top-k with τ = k-th largest |v|).
    -> (selected q, residual v − q)."""
    q = jnp.where(jnp.abs(v) >= thresh, v, jnp.zeros_like(v))
    return q, v - q


# ---------------------------------------------------------------------------
# flash attention (causal, GQA, optional sliding window)
# ---------------------------------------------------------------------------
def flash_attention(q, k, v, causal=True, window=0):
    """q (B,H,L,D), k/v (B,Hk,L,D) -> (B,H,L,D).  float32 math."""
    B, H, Lq, D = q.shape
    Hk = k.shape[1]
    g = H // Hk
    qf = q.astype(jnp.float32) * (D ** -0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kf = jnp.repeat(kf, g, axis=1)
    vf = jnp.repeat(vf, g, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    qpos = jnp.arange(Lq)[:, None]
    kpos = jnp.arange(Lq)[None, :]
    mask = jnp.ones((Lq, Lq), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, vf).astype(q.dtype)


# ---------------------------------------------------------------------------
# SSD scan (sequential recurrence oracle)
# ---------------------------------------------------------------------------
def ssd_scan(x, dt, A_log, B, C, D, chunk=None):
    """Sequential SSD recurrence.  x (b,L,H,P); dt (b,L,H); B/C (b,L,H,N);
    A_log (H,); D (H,).  Matches mamba2.ssd_chunked (x pre-scaled by dt
    inside, as in the chunked version)."""
    b, L, H, P = x.shape
    N = B.shape[-1]
    f32 = jnp.float32
    xdt = x.astype(f32) * dt[..., None].astype(f32)
    decay = jnp.exp(-jnp.exp(A_log.astype(f32))[None, None] * dt.astype(f32))

    def step(h, inp):
        xt, Bt, Ct, dect = inp                      # (b,H,P),(b,H,N),(b,H,N),(b,H)
        h = h * dect[..., None, None] + jnp.einsum("bhn,bhp->bhnp", Bt, xt)
        y = jnp.einsum("bhn,bhnp->bhp", Ct, h)
        return h, y
    h0 = jnp.zeros((b, H, N, P), f32)
    _, ys = jax.lax.scan(step, h0, (jnp.moveaxis(xdt, 1, 0),
                                    jnp.moveaxis(B.astype(f32), 1, 0),
                                    jnp.moveaxis(C.astype(f32), 1, 0),
                                    jnp.moveaxis(decay, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1)
    return y + D.astype(f32)[None, None, :, None] * xdt


# ---------------------------------------------------------------------------
# self-confidence KD loss (eq. 7-9)
# ---------------------------------------------------------------------------
def kd_loss(student_logits, teacher_logits, labels, rho, lam, tau):
    """-> per-sample loss (B,)."""
    s = student_logits.astype(jnp.float32)
    t = teacher_logits.astype(jnp.float32)
    C = s.shape[-1]
    p_t = jax.nn.softmax(t / tau, -1)
    onehot = jax.nn.one_hot(labels, C, dtype=jnp.float32)
    damp = (1.0 - rho)[None, :] * p_t
    non_true = damp * (1.0 - onehot)
    true_mass = 1.0 - non_true.sum(-1, keepdims=True)
    target = non_true + onehot * true_mass
    # CE
    lse = jax.nn.logsumexp(s, -1)
    gold = jnp.sum(s * onehot, -1)
    ce = lse - gold
    # KL(target ‖ student_T)
    logp = jax.nn.log_softmax(s / tau, -1)
    tgt = jnp.clip(target, 1e-9, 1.0)
    kl = jnp.sum(tgt * (jnp.log(tgt) - logp), -1) * tau ** 2
    return (1 - lam) * ce + lam * kl
