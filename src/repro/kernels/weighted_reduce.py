"""Fused weighted-delta-reduce kernel (the semi-async server hot spot).

The server aggregate Δ̄ = Σ_k w_k·Δ_k reads K stacked parameter-sized deltas
and writes one; unfused, XLA materialises the (K, n) broadcast product in HBM
before reducing.  This kernel keeps the whole K-slab of each row-tile
VMEM-resident and emits the reduced tile in a single pass — HBM traffic is
exactly K+1 parameter-vectors per aggregate, the information-theoretic floor.

Mirrors fedadc_update.py's tiling: operands arrive as flattened (rows, 128)
lane-aligned tiles (padding handled by the ops.py wrapper), stacked to
(K, rows, 128).  The row-block is shrunk as K grows so the K·block·128 slab
stays comfortably inside VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
BLOCK_ROWS = 512          # upper bound; shrunk when K is large
VMEM_BUDGET = 4 * 1024 * 1024   # slab budget per operand set (bytes)


def _row_block(rows: int, k: int, itemsize: int) -> int:
    """Largest multiple-of-8 row block whose (K+1)-slab fits the budget."""
    per_row = (k + 1) * LANE * itemsize
    block = min(BLOCK_ROWS, max(8, (VMEM_BUDGET // per_row) // 8 * 8))
    return min(block, rows) if rows >= 8 else rows


def _weighted_reduce_kernel(w_ref, d_ref, o_ref):
    # w (K, LANE) fp32 — weight broadcast along lanes; d (K, block, LANE)
    # o (block, LANE) = Σ_k w_k · d_k   — one VMEM pass, no HBM intermediate.
    # The product/sum run in fp32 whatever the delta dtype (a bf16 partial
    # sum rounds away late clients once it outgrows the increments); the
    # tile is cast back to the wire dtype on write.
    acc = jnp.sum(w_ref[...].astype(jnp.float32)[:, None, :] *
                  d_ref[...].astype(jnp.float32), axis=0)
    o_ref[...] = acc.astype(o_ref.dtype)


def weighted_reduce_2d(deltas, weights, interpret=False):
    """deltas (K, rows, LANE), weights (K,) -> (rows, LANE) = Σ_k w_k·Δ_k,
    accumulated in fp32 and cast on write."""
    k, rows, _ = deltas.shape
    w2d = jnp.broadcast_to(weights.astype(jnp.float32)[:, None], (k, LANE))
    # budget the slab at fp32 itemsize: the in-kernel accumulation upcasts
    block = _row_block(rows, k, max(deltas.dtype.itemsize, 4))
    grid = (pl.cdiv(rows, block),)
    return pl.pallas_call(
        _weighted_reduce_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((k, LANE), lambda i: (0, 0)),
                  pl.BlockSpec((k, block, LANE), lambda i: (0, i, 0))],
        out_specs=pl.BlockSpec((block, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), deltas.dtype),
        interpret=interpret,
    )(w2d, deltas)
