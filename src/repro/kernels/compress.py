"""Fused delta-compression kernels (the uplink hot spot).

Both compressors are quantize-and-decompress round trips: the engines
transport the *decompressed* lossy delta (what the server would reconstruct
from the wire) and keep the residual as the client's error-feedback memory.
Unfused, XLA materialises the intermediate quantised tensor and the
(v − q) subtraction in HBM; these kernels emit the reconstruction AND the
residual from a single VMEM pass over the input — one read, two writes,
no intermediates.

* ``qsgd_2d`` — QSGD-style stochastic uniform quantisation: magnitudes are
  scaled into ``s`` levels, stochastically rounded (the uniform draw arrives
  as an operand so CPU-interpret and TPU runs are bit-identical to the ref),
  then dequantised in-register.
* ``threshold_select_2d`` — top-k as a per-block threshold select: the k-th
  largest magnitude is computed once per leaf upstream (``lax.top_k``); each
  block then keeps values with ``|v| ≥ τ`` and zeroes the rest, so the kernel
  itself stays a streaming elementwise pass regardless of k.

Tiling mirrors fedadc_update.py: flattened (rows, 128) lane-aligned tiles
(padding handled by the ops.py wrapper); per-leaf scalars (scale, threshold)
are broadcast along lanes like the weights in weighted_reduce.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
BLOCK_ROWS = 512          # 512×128 fp32 = 256 KiB per operand in VMEM


def _qsgd_kernel(v_ref, u_ref, scale_ref, q_ref, r_ref, *, s):
    # y = |v|·s/scale ; level = ⌊y⌋ + 1[u < frac(y)] ; q = sign(v)·level·scale/s
    v = v_ref[...]
    scale = scale_ref[0, 0]
    inv = jnp.where(scale > 0, s / jnp.maximum(scale, 1e-30), 0.0)
    y = jnp.abs(v) * inv
    lower = jnp.floor(y)
    level = lower + (u_ref[...] < (y - lower)).astype(v.dtype)
    q = jnp.sign(v) * level * (scale / s)
    q_ref[...] = q
    r_ref[...] = v - q


def _threshold_kernel(v_ref, t_ref, q_ref, r_ref):
    # q = v·1[|v| ≥ τ] ; r = v − q   (τ = per-leaf k-th largest magnitude)
    v = v_ref[...]
    keep = jnp.abs(v) >= t_ref[0, 0]
    q = jnp.where(keep, v, jnp.zeros_like(v))
    q_ref[...] = q
    r_ref[...] = v - q


def _tiled_call(kernel, arrays, scalars, interpret, **kw):
    """arrays: (rows, LANE) operands; scalars: per-leaf values broadcast to
    (1, LANE) and replicated to every block.  -> (q, residual)."""
    rows = arrays[0].shape[0]
    dtype = arrays[0].dtype
    block = min(BLOCK_ROWS, rows)
    grid = (pl.cdiv(rows, block),)
    spec = pl.BlockSpec((block, LANE), lambda i: (i, 0))
    sspec = pl.BlockSpec((1, LANE), lambda i: (0, 0))
    s2d = [jnp.broadcast_to(jnp.asarray(s, dtype).reshape(1, 1), (1, LANE))
           for s in scalars]
    out_shape = [jax.ShapeDtypeStruct(arrays[0].shape, dtype)] * 2
    return pl.pallas_call(
        functools.partial(kernel, **kw),
        grid=grid,
        in_specs=[spec] * len(arrays) + [sspec] * len(s2d),
        out_specs=[spec, spec],
        out_shape=out_shape,
        interpret=interpret,
    )(*arrays, *s2d)


def qsgd_2d(v, u, scale, s, interpret=False):
    """v, u (rows, LANE); scale scalar -> (dequantised q, residual v − q)."""
    return _tiled_call(_qsgd_kernel, [v, u], [scale], interpret, s=float(s))


def threshold_select_2d(v, thresh, interpret=False):
    """v (rows, LANE); thresh scalar -> (selected q, residual v − q)."""
    return _tiled_call(_threshold_kernel, [v], [thresh], interpret)
