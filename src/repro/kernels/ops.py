"""jit'd public wrappers around the Pallas kernels.

On non-TPU backends every kernel runs in ``interpret=True`` mode (the body
executes as plain JAX on CPU) so the whole framework stays runnable and
testable in this container; on TPU the same call sites compile to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import compress as _cp
from repro.kernels import fedadc_update as _fu
from repro.kernels import flash_attention as _fa
from repro.kernels import kd_loss as _kd
from repro.kernels import sparse_reduce as _sr
from repro.kernels import ssd_scan as _ssd
from repro.kernels import weighted_reduce as _wr

LANE = _fu.LANE


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# elementwise fused updates — applied leaf-wise over parameter pytrees
# ---------------------------------------------------------------------------
def _as_tiles(x):
    flat = x.reshape(-1)
    pad = (-flat.size) % LANE
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, LANE), pad


def _from_tiles(t, pad, shape, dtype):
    flat = t.reshape(-1)
    if pad:
        flat = flat[:flat.size - pad]
    return flat.reshape(shape).astype(dtype)


def fused_axpy(x, y, a):
    """x + a·y on a single leaf."""
    xt, pad = _as_tiles(x)
    yt, _ = _as_tiles(y.astype(x.dtype))
    out = _fu.fused_axpy_2d(xt, yt, a, interpret=_interpret())
    return _from_tiles(out, pad, x.shape, x.dtype)


def fedadc_local_update(theta, g, m_bar, eta):
    """θ − η(g + m̄) over a whole pytree."""
    def leaf(t, gi, mi):
        tt, pad = _as_tiles(t)
        gt, _ = _as_tiles(gi.astype(t.dtype))
        mt, _ = _as_tiles(mi.astype(t.dtype))
        out = _fu.local_update_2d(tt, gt, mt, eta, interpret=_interpret())
        return _from_tiles(out, pad, t.shape, t.dtype)
    return jax.tree.map(leaf, theta, g, m_bar)


def fedadc_server_update(theta, m, delta_bar, gamma, alpha_eta):
    """(θ', m') fused server update over a whole pytree."""
    def leaf(t, mi, di):
        tt, pad = _as_tiles(t)
        mt, _ = _as_tiles(mi.astype(t.dtype))
        dt, _ = _as_tiles(di.astype(t.dtype))
        to, mo = _fu.server_update_2d(tt, mt, dt, gamma, alpha_eta,
                                      interpret=_interpret())
        return (_from_tiles(to, pad, t.shape, t.dtype),
                _from_tiles(mo, pad, t.shape, t.dtype))
    pairs = jax.tree.map(leaf, theta, m, delta_bar)
    theta_new = jax.tree.map(lambda p: p[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
    m_new = jax.tree.map(lambda p: p[1], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    return theta_new, m_new


def weighted_delta_reduce(stacked, weights):
    """Σ_k w_k·Δ_k over a stacked pytree (leading axis K on every leaf).
    Weights are applied as given (normalise upstream for a weighted mean)."""
    def leaf(d):
        k = d.shape[0]
        flat = d.reshape(k, -1)
        pad = (-flat.shape[1]) % LANE
        if pad:
            flat = jnp.pad(flat, ((0, 0), (0, pad)))
        tiles = flat.reshape(k, -1, LANE)
        out = _wr.weighted_reduce_2d(tiles, weights, interpret=_interpret())
        return _from_tiles(out, pad, d.shape[1:], d.dtype)
    return jax.tree.map(leaf, stacked)


# ---------------------------------------------------------------------------
# delta compression — single-leaf quantise/sparsify round trips
# ---------------------------------------------------------------------------
def qsgd_compress_leaf(v, u, scale, s):
    """Stochastic uniform quantise-dequantise on one leaf.  `u` uniform draw
    (v's shape), `scale` per-leaf scalar, `s` static level count.
    -> (dequantised q, residual v − q), both v's shape/dtype."""
    vt, pad = _as_tiles(v)
    ut, _ = _as_tiles(u.astype(v.dtype))
    q, r = _cp.qsgd_2d(vt, ut, scale, s, interpret=_interpret())
    return (_from_tiles(q, pad, v.shape, v.dtype),
            _from_tiles(r, pad, v.shape, v.dtype))


def topk_compress_leaf(v, thresh):
    """Magnitude-threshold select on one leaf (top-k with τ precomputed).
    -> (selected q, residual v − q)."""
    vt, pad = _as_tiles(v)
    q, r = _cp.threshold_select_2d(vt, thresh, interpret=_interpret())
    return (_from_tiles(q, pad, v.shape, v.dtype),
            _from_tiles(r, pad, v.shape, v.dtype))


def topk_sparse_leaf(v, k):
    """True sparse top-k select on one leaf: the k largest-|v| entries leave
    as (values, flat indices) — the actual wire representation — and the
    residual keeps everything else (DESIGN.md §Transport).

    -> (values (k,), indices (k,) int32, residual of v's shape/dtype).

    Selection and residual are exact complements by construction (the
    residual zeroes exactly the gathered indices), so
    ``sparse_scatter_leaf(values, indices) + residual == v`` bitwise.  No
    Pallas kernel: top-k and gather/scatter lower to XLA's sort/dynamic-
    gather, which are memory-bound and already single-pass — the fused
    threshold kernel only pays off on the dense path where the select is an
    elementwise mask over the full tensor.
    """
    flat = v.reshape(-1)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    idx = idx.astype(jnp.int32)
    values = flat[idx]
    residual = flat.at[idx].set(0).reshape(v.shape)
    return values, idx, residual


def sparse_scatter_leaf(values, indices, shape, dtype):
    """Server-side decode of one sparse leaf: scatter (values, indices) into
    a dense zero tensor — one scatter per client instead of re-running the
    dense threshold pass."""
    n = int(np.prod(shape)) if shape else 1
    return jnp.zeros((n,), dtype).at[indices].set(values).reshape(shape)


@functools.partial(jax.jit, static_argnums=(3, 4))
def sparse_weighted_delta_reduce(values, indices, weights, shape, dtype):
    """Σ_k w_k · scatter(values_k @ indices_k) for one leaf: the sparse
    server aggregate at K·k cost instead of K·d (kernels/sparse_reduce.py).
    `values`/`indices` are the stacked (K, k) wire pairs of K clients
    (duplicate indices accumulate), `shape`/`dtype` the dense leaf
    template.  Accumulation is fp32 inside the kernel's revisited output
    ref; the single cast to `dtype` happens on the final write
    (cast-on-write precision contract)."""
    _, k = values.shape
    n = 1
    for dim in shape:      # static python ints — no host sync in the trace
        n *= dim
    if k == 0:
        # an empty wire contributes nothing — and a zero-width Pallas
        # block is not a thing, so short-circuit before the kernel
        return jnp.zeros(shape, dtype)
    kpad = (-k) % LANE
    if kpad:
        # (value 0, index 0) filler pairs: the weighted zeros land on
        # index 0 as exact +0.0 adds, which never perturb the sum
        values = jnp.pad(values, ((0, 0), (0, kpad)))
        indices = jnp.pad(indices, ((0, 0), (0, kpad)))
    rows = (n + LANE - 1) // LANE
    out = _sr.sparse_reduce_2d(values, indices.astype(jnp.int32), weights,
                               rows, interpret=_interpret())
    return out.reshape(-1)[:n].astype(dtype).reshape(shape)


# ---------------------------------------------------------------------------
# attention / ssd / kd
# ---------------------------------------------------------------------------
def flash_attention(q, k, v, causal=True, window=0, block_q=128, block_k=128):
    """q (B,L,H,D) model layout -> (B,L,H,D)."""
    qt = jnp.moveaxis(q, 1, 2)
    kt = jnp.moveaxis(k, 1, 2)
    vt = jnp.moveaxis(v, 1, 2)
    out = _fa.flash_attention(qt, kt, vt, causal=causal, window=window,
                              block_q=block_q, block_k=block_k,
                              interpret=_interpret())
    return jnp.moveaxis(out, 1, 2)


def ssd_scan(x, dt, A_log, B, C, D, chunk=256):
    return _ssd.ssd_scan(x, dt, A_log, B, C, D, chunk=chunk,
                         interpret=_interpret())


def kd_loss(student_logits, teacher_logits, labels, rho, lam, tau):
    return _kd.kd_loss(student_logits, teacher_logits, labels, rho, lam, tau,
                       interpret=_interpret())
