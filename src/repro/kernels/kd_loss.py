"""Fused self-confidence KD loss (FedADC+, eqs. (7)-(9)) as a Pallas kernel.

One pass over the logits row computes: teacher softmax, confidence-damped
target construction, student log-softmax, CE and KL — five softmax-family
reductions fused into a single VMEM-resident sweep instead of five separate
HBM round-trips over (B, C) tensors.  Rows are processed in batch blocks;
the class dimension stays whole in VMEM (fine up to ~32k classes at fp32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kd_kernel(s_ref, t_ref, y_ref, rho_ref, o_ref, *, lam, tau):
    s = s_ref[...].astype(jnp.float32)            # (bb, C)
    t = t_ref[...].astype(jnp.float32)
    y = y_ref[...]                                # (bb,) int32
    rho = rho_ref[...].astype(jnp.float32)        # (C,)
    C = s.shape[-1]

    # teacher softmax at temperature tau
    tm = t / tau
    tm = tm - tm.max(-1, keepdims=True)
    pt = jnp.exp(tm)
    pt = pt / pt.sum(-1, keepdims=True)

    onehot = (jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
              == y[:, None]).astype(jnp.float32)
    damp = (1.0 - rho)[None, :] * pt
    non_true = damp * (1.0 - onehot)
    true_mass = 1.0 - non_true.sum(-1, keepdims=True)
    target = non_true + onehot * true_mass

    # student CE (temperature 1)
    smax = s.max(-1, keepdims=True)
    lse = jnp.log(jnp.exp(s - smax).sum(-1, keepdims=True)) + smax
    gold = (s * onehot).sum(-1, keepdims=True)
    ce = (lse - gold)[:, 0]

    # KL(target ‖ student_T)
    st = s / tau
    stmax = st.max(-1, keepdims=True)
    st_lse = jnp.log(jnp.exp(st - stmax).sum(-1, keepdims=True)) + stmax
    logp = st - st_lse
    tgt = jnp.clip(target, 1e-9, 1.0)
    kl = (tgt * (jnp.log(tgt) - logp)).sum(-1) * tau ** 2

    o_ref[...] = (1.0 - lam) * ce + lam * kl


def kd_loss(student_logits, teacher_logits, labels, rho, lam, tau,
            block_b=128, interpret=False):
    """-> per-sample loss (B,) float32."""
    B, C = student_logits.shape
    block_b = min(block_b, B)
    grid = (pl.cdiv(B, block_b),)
    kernel = functools.partial(_kd_kernel, lam=lam, tau=tau)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, C), lambda i: (i, 0)),
            pl.BlockSpec((block_b, C), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((C,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.float32),
        interpret=interpret,
    )(student_logits, teacher_logits, labels.astype(jnp.int32), rho)
