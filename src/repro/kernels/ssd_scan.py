"""Mamba2 SSD chunked scan as a Pallas TPU kernel.

TPU adaptation of the CUDA selective-scan: instead of a warp-level
sequential scan, the sequence is split into MXU-friendly chunks; all
intra-chunk work is (Q×N)·(N×Q) / (Q×Q)·(Q×P) matmuls, and the inter-chunk
recurrence h_c = exp(a_c)·h_{c-1} + S_c rides the sequential TPU grid with
the running state (N×P) held in VMEM scratch.  Grid = (batch, heads,
chunks) with chunks innermost/sequential.

Operands arrive pre-gated (x already scaled by dt, per-step log-decay `a`
precomputed) — the cheap elementwise prologue stays in XLA where it fuses
with the surrounding ops; the kernel owns the matmul + recurrence part.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, o_ref, h_scr, *, chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(jnp.float32)          # (Q, P)
    a = a_ref[0, 0].astype(jnp.float32)          # (Q,)  log decay per step
    B = b_ref[0, 0].astype(jnp.float32)          # (Q, N)
    C = c_ref[0, 0].astype(jnp.float32)          # (Q, N)

    acum = jnp.cumsum(a)                          # (Q,)
    a_end = acum[-1]

    # intra-chunk: (C Bᵀ ⊙ decay ⊙ causal) x
    scores = C @ B.T                              # (Q, Q)
    decay = acum[:, None] - acum[None, :]
    causal = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    gate = jnp.exp(jnp.where(causal, decay, -1e30))
    y = (scores * gate) @ x                       # (Q, P)

    # inter-chunk contribution from carried state
    h_prev = h_scr[...]                           # (N, P)
    y = y + jnp.exp(acum)[:, None] * (C @ h_prev)

    # update carried state: h = exp(a_end) h_prev + Σ exp(a_end - acum) B x
    w = jnp.exp(a_end - acum)[:, None]            # (Q,1)
    h_scr[...] = jnp.exp(a_end) * h_prev + B.T @ (w * x)

    o_ref[0, 0] = y.astype(o_ref.dtype)


def ssd_scan(x, dt, A_log, B, C, D, chunk=256, interpret=False):
    """Same contract as ref.ssd_scan: x (b,L,H,P), dt (b,L,H),
    B/C (b,L,H,N), A_log (H,), D (H,) -> y (b,L,H,P)."""
    b, L, H, P = x.shape
    N = B.shape[-1]
    chunk = min(chunk, L)
    nc = pl.cdiv(L, chunk)
    f32 = jnp.float32

    xdt = x.astype(f32) * dt[..., None].astype(f32)
    a = (-jnp.exp(A_log.astype(f32))[None, None] * dt.astype(f32))  # (b,L,H)

    # layout: (b, H, L, ·) so blocks index (batch, head, chunk)
    xb = jnp.moveaxis(xdt, 2, 1)                  # (b,H,L,P)
    ab = jnp.moveaxis(a, 2, 1)                    # (b,H,L)
    Bb = jnp.moveaxis(B.astype(f32), 2, 1)        # (b,H,L,N)
    Cb = jnp.moveaxis(C.astype(f32), 2, 1)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=(b, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1, 1, chunk, N), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda bi, hi, ci: (bi, hi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P),
                               lambda bi, hi, ci: (bi, hi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b, H, L, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), f32)],
        interpret=interpret,
    )(xb, ab, Bb, Cb)
    y = jnp.moveaxis(y, 1, 2)                     # (b,L,H,P)
    return y + D.astype(f32)[None, None, :, None] * xdt
