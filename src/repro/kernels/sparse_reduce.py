"""Pallas scatter-accumulate kernel: the sparse server aggregate.

The sparse top-k uplink (transport.SparseTopKCodec) ships each client's
delta as per-leaf ``(values, indices)`` pairs, but until this kernel the
server decoded every client to dense before ``weighted_delta_reduce`` —
aggregation FLOPs and memory traffic scaled with the parameter count d
even at ``topk_frac = 0.01``.  This kernel segment-sums the K stacked
wire pairs straight into one dense output leaf:

    out[idx_{k,j}] += w_k · values_{k,j}      (K · k adds, not K · d)

Tiling: one grid step per client; the output block (the whole padded
leaf, lane-aligned) is *revisited* across the K steps — a constant
output index map keeps the running sum resident, and the fp32 output ref
IS the accumulator, so accumulation is fp32 whatever the wire dtype
(PR 5's cast-on-write precision contract; the ops.py wrapper casts to
the wire dtype exactly once, on the final write).  Duplicate indices
within a client accumulate (scatter-add), matching the segment-sum
oracle in kernels/ref.py bit for bit: both apply the weighted updates in
client-major order onto an fp32 zero buffer.

VMEM note: the output block is the full padded leaf, so a single-leaf
aggregate is VMEM-bounded at ~leaf_bytes (fp32) + K·k pairs.  That holds
comfortably for per-leaf aggregation of the assigned architectures; a
future hierarchical (regional) aggregator reusing this kernel at larger
fan-in would tile the output over row blocks and mask each client's
pairs per block — the k-cost hook the ROADMAP's million-client item
builds on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _sparse_reduce_kernel(w_ref, v_ref, i_ref, o_ref):
    # w (1, LANE) fp32 — one client's weight, broadcast along lanes for
    # lane alignment; v/i (1, kp); o (rows, LANE) fp32, revisited across
    # the K grid steps (constant index map): the ref is the accumulator.
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    wv = w_ref[0, 0] * v_ref[...].reshape(-1).astype(jnp.float32)
    flat = o_ref[...].reshape(-1)
    flat = flat.at[i_ref[...].reshape(-1)].add(wv)
    o_ref[...] = flat.reshape(o_ref.shape)


def sparse_reduce_2d(values, indices, weights, rows, interpret=False):
    """values (K, kp), indices (K, kp) int32 into the flattened (rows·LANE,)
    output, weights (K,) -> (rows, LANE) fp32 = Σ_k w_k · scatter(v_k @ i_k).

    kp and rows·LANE are lane-aligned by the ops.py wrapper (k-padding uses
    (value 0, index 0) pairs — weighted zeros accumulate as exact +0.0).
    The caller casts the fp32 result to the wire dtype (cast-on-write)."""
    k_clients, kp = values.shape
    w2d = jnp.broadcast_to(weights.astype(jnp.float32)[:, None],
                           (k_clients, LANE))
    return pl.pallas_call(
        _sparse_reduce_kernel,
        grid=(k_clients,),
        in_specs=[pl.BlockSpec((1, LANE), lambda i: (i, 0)),
                  pl.BlockSpec((1, kp), lambda i: (i, 0)),
                  pl.BlockSpec((1, kp), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, LANE), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
        interpret=interpret,
    )(w2d, values, indices)
