"""Causal flash attention (GQA + optional sliding window) as a Pallas TPU
kernel.

TPU adaptation of the FlashAttention blocking: the grid is
(batch·heads, q_blocks, k_blocks) with the K dimension innermost — on TPU
the grid is executed sequentially per core, so the online-softmax running
state (m, l, acc) lives in VMEM scratch carried across the k iterations of
one (bh, q) cell.  Block shapes are MXU-aligned (multiples of 128 on the
contracting dim).  Out-of-window / non-causal K blocks are skipped with
``pl.when`` so the sliding-window variant does O(L·W) work, which is what
makes the long_500k dense variants sub-quadratic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, block_q, block_k, n_kb, causal, window):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # visibility: causal → need k_start <= q_end; window → k_end > q_start-window
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window > 0:
        run = jnp.logical_and(run, k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bk, D)
        v = v_ref[0].astype(jnp.float32)                  # (bk, D)
        s = q @ k.T                                       # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_cur)
        corr = jnp.exp(m_prev - m_cur)
        l_scr[...] = l_scr[...] * corr + p.sum(-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + p @ v
        m_scr[...] = m_cur

    @pl.when(ki == n_kb - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=False):
    """q (B,H,L,D); k/v (B,Hk,L,D) -> (B,H,L,D)."""
    B, H, Lq, D = q.shape
    Hk = k.shape[1]
    group = H // Hk
    scale = D ** -0.5
    block_q = min(block_q, Lq)
    block_k = min(block_k, Lq)
    n_qb = pl.cdiv(Lq, block_q)
    n_kb = pl.cdiv(Lq, block_k)

    qr = q.reshape(B * H, Lq, D)
    kr = k.reshape(B * Hk, Lq, D)
    vr = v.reshape(B * Hk, Lq, D)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        n_kb=n_kb, causal=causal, window=window)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_qb, n_kb),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Lq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, Lq, D)
