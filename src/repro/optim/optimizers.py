"""Minimal optimizers over parameter pytrees (no optax dependency).

The FL strategies own the *server* update; these are used for (a) local
client steps when a strategy wants plain momentum SGD, and (b) centralized
(non-federated) baselines in the benchmarks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import tree as T


def sgd_update(params, grads, lr, weight_decay=0.0):
    if weight_decay > 0:
        grads = T.axpy(weight_decay, params, grads)
    return jax.tree.map(lambda p, g: p - lr * g, params, grads)


def momentum_init(params):
    return T.zeros_like(params)


def momentum_update(params, grads, state, lr, beta=0.9, weight_decay=0.0,
                    nesterov=False):
    if weight_decay > 0:
        grads = T.axpy(weight_decay, params, grads)
    m = T.axpy(beta, state, grads)
    upd = T.axpy(beta, m, grads) if nesterov else m
    return jax.tree.map(lambda p, u: p - lr * u, params, upd), m


def adamw_init(params):
    return {"m": T.zeros_like(params), "v": T.zeros_like(params),
            "t": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8,
                 weight_decay=0.0):
    t = state["t"] + 1
    m = jax.tree.map(lambda mi, g: b1 * mi + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda vi, g: b2 * vi + (1 - b2) * g * g, state["v"], grads)
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)

    def upd(p, mi, vi):
        mh = mi / bc1
        vh = vi / bc2
        return p - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p)
    return jax.tree.map(upd, params, m, v), {"m": m, "v": v, "t": t}
