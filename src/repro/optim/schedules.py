"""Learning-rate schedules (round-indexed for FL, step-indexed otherwise)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr):
    return lambda t: jnp.asarray(lr, jnp.float32)


def cosine_decay(lr, total, floor=0.0):
    def f(t):
        frac = jnp.clip(t / max(total, 1), 0.0, 1.0)
        return floor + (lr - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return f


def warmup_cosine(lr, warmup, total, floor=0.0):
    cos = cosine_decay(lr, max(total - warmup, 1), floor)
    def f(t):
        w = jnp.clip(t / max(warmup, 1), 0.0, 1.0)
        return jnp.where(t < warmup, lr * w, cos(t - warmup))
    return f
