from repro.optim.optimizers import (adamw_init, adamw_update, momentum_init,
                                    momentum_update, sgd_update)
from repro.optim.schedules import constant, cosine_decay, warmup_cosine
