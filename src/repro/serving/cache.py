"""Slot/page cache manager for the continuous-batching engine.

Physical layout is ONE batched cache pytree (``model.init_cache(cfg,
n_slots, max_len)``) — every leaf is (n_layers, n_slots, ...), so the
batched decode step runs over all slots in a single jit call.  On top of
that sit two accounting layers:

* **KV pages** — attention/MLA layers consume ``ceil(len / page_size)``
  pages per slot from a global pool.  Admission reserves the worst case
  (prompt + max_new tokens) up front, so an admitted request can never run
  out of cache mid-flight and no eviction path is needed.
* **SSM state slots** — recurrent leaves (mamba2 ``h``/``conv``, xLSTM
  ``C``/``n``/``m``/``c``/``h``) are fixed-size and length-independent:
  one state page per slot, whatever the sequence length.  Hybrids
  (zamba2) pay both: KV pages for their (shared) attention layers plus
  one state page.

Slot reset is a masked write of a freshly-initialised single-slot cache
(zeros / kpos=-1 / mlstm m=-1e30) into the slot's row — uniform across all
cache kinds, no per-architecture reset code.
"""
from __future__ import annotations

import math
from functools import partial
from typing import List

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, MOE, SHARED_ATTN, MAMBA2, MLSTM, SLSTM,
                                ModelConfig)
from repro.models.registry import get_model

_ATTN_KINDS = {ATTN, MOE, SHARED_ATTN}
_SSM_KINDS = {MAMBA2, MLSTM, SLSTM}


@partial(jax.jit, donate_argnums=(0,))
def _write_slot(cache, part, slot):
    return jax.tree.map(
        lambda leaf, p: jax.lax.dynamic_update_slice_in_dim(
            leaf, p.astype(leaf.dtype), slot, axis=1),
        cache, part)


@jax.jit
def _slice_slot(cache, slot):
    return jax.tree.map(
        lambda leaf: jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis=1),
        cache)


class CacheManager:
    def __init__(self, mcfg: ModelConfig, n_slots: int, max_len: int,
                 page_size: int = 64, dtype=jnp.float32,
                 total_pages: int = None):
        self.mcfg, self.n_slots, self.max_len = mcfg, n_slots, max_len
        self.page_size = page_size
        kinds = set(mcfg.blocks())
        self.has_kv = bool(kinds & _ATTN_KINDS)
        self.has_state = bool(kinds & _SSM_KINDS)
        model = get_model(mcfg)
        self.cache = model.init_cache(mcfg, n_slots, max_len, dtype)
        self._fresh = model.init_cache(mcfg, 1, max_len, dtype)
        if total_pages is None:
            total_pages = n_slots * self.pages_for(max_len)
        self.total_pages = total_pages
        self.free_pages = total_pages
        self.slot_pages: List[int] = [0] * n_slots
        self._free_slots: List[int] = list(range(n_slots - 1, -1, -1))

    # -- page accounting ---------------------------------------------------
    def pages_for(self, length: int) -> int:
        """Pages a sequence of ``length`` tokens occupies in this arch's
        cache: KV pages (capped at the physical ring size) + one
        fixed-size state page for recurrent layers."""
        pages = 0
        if self.has_kv:
            eff = min(length, self.max_len)
            pages += math.ceil(max(eff, 1) / self.page_size)
        if self.has_state:
            pages += 1
        return pages

    def can_admit(self, total_len: int) -> bool:
        return (bool(self._free_slots)
                and self.pages_for(total_len) <= self.free_pages)

    # -- slot lifecycle ----------------------------------------------------
    def admit(self, total_len: int) -> int:
        """Reserve a slot + pages for a request of ``total_len`` tokens
        (prompt + max_new) and reset the slot's cache row."""
        if not self.can_admit(total_len):
            raise RuntimeError("admit() called with no capacity; "
                               "check can_admit() first")
        slot = self._free_slots.pop()
        pages = self.pages_for(total_len)
        self.slot_pages[slot] = pages
        self.free_pages -= pages
        self.cache = _write_slot(self.cache, self._fresh,
                                 jnp.asarray(slot, jnp.int32))
        return slot

    def free(self, slot: int) -> None:
        self.free_pages += self.slot_pages[slot]
        self.slot_pages[slot] = 0
        self._free_slots.append(slot)

    # -- slot I/O for chunked prefill --------------------------------------
    def slot_view(self, slot: int):
        """The slot's (batch=1) cache slice, for the prefill-chunk step."""
        return _slice_slot(self.cache, jnp.asarray(slot, jnp.int32))

    def write_slot(self, slot: int, part) -> None:
        self.cache = _write_slot(self.cache, part,
                                 jnp.asarray(slot, jnp.int32))
