"""Request types + FIFO admission queue for the continuous-batching engine.

A request's lifecycle: QUEUED (waiting for a slot) -> PREFILL (prompt being
ingested chunk-by-chunk) -> DECODE (in the batched decode set) -> FINISHED.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import List, Optional

QUEUED = "queued"
PREFILL = "prefill"
DECODE = "decode"
FINISHED = "finished"


@dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling.  temperature<=0 means greedy (argmax);
    top_k<=0 means no top-k truncation.  ``seed`` keys a per-request,
    per-position PRNG stream, so stochastic sampling for a request is
    reproducible regardless of what else shares the batch."""
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    sampling: SamplingParams = field(default_factory=SamplingParams)
    arrival_t: float = 0.0

    # progress (engine-owned)
    state: str = QUEUED
    slot: Optional[int] = None
    prefilled: int = 0                       # prompt tokens ingested
    out_tokens: List[int] = field(default_factory=list)
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None


@dataclass(frozen=True)
class RequestOutput:
    rid: int
    prompt: List[int]
    tokens: List[int]
    arrival_t: float
    first_token_t: float
    finish_t: float

    @property
    def latency(self) -> float:
        return self.finish_t - self.arrival_t

    @property
    def ttft(self) -> float:
        """Time to first token."""
        return self.first_token_t - self.arrival_t

    @property
    def itl(self) -> Optional[float]:
        """Mean inter-token latency; None for single-token requests (no
        gap exists)."""
        if len(self.tokens) < 2:
            return None
        return (self.finish_t - self.first_token_t) / (len(self.tokens) - 1)


class RequestQueue:
    """FIFO admission queue."""

    def __init__(self):
        self._q: deque = deque()

    def add(self, req: Request) -> None:
        self._q.append(req)

    def peek(self) -> Optional[Request]:
        return self._q[0] if self._q else None

    def pop(self) -> Request:
        return self._q.popleft()

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)
