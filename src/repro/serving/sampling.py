"""Per-request token sampling for the batched decode step.

One jit'd function over the whole slot batch: each row carries its own
(temperature, top_k, seed, counter).  temperature<=0 selects greedy for
that row; top_k<=0 disables truncation.  The PRNG key for a row is
``fold_in(PRNGKey(seed), counter)`` where ``counter`` is the request's
output position — sampling depends only on (request seed, position,
logits), never on batch composition, so a request samples identically
whether it runs alone or packed with seven neighbours.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def sample_tokens(logits, temps, top_ks, seeds, counters):
    """logits (B,V); temps (B,) f32; top_ks/seeds/counters (B,) int32
    -> tokens (B,) int32."""
    logits = logits.astype(jnp.float32)
    B, V = logits.shape
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    # top-k truncation with per-row dynamic k: threshold at the k-th
    # largest logit (ties above the threshold stay in)
    sorted_desc = -jnp.sort(-logits, axis=-1)
    k_idx = jnp.clip(top_ks - 1, 0, V - 1).astype(jnp.int32)
    thresh = jnp.take_along_axis(sorted_desc, k_idx[:, None], axis=-1)
    cut = (top_ks > 0)[:, None] & (logits < thresh)
    scaled = jnp.where(cut, -jnp.inf,
                       logits / jnp.maximum(temps, 1e-6)[:, None])

    def row_gumbel(seed, counter):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), counter)
        return jax.random.gumbel(key, (V,), jnp.float32)
    g = jax.vmap(row_gumbel)(seeds, counters)
    sampled = jnp.argmax(scaled + g, axis=-1).astype(jnp.int32)
    return jnp.where(temps <= 0, greedy, sampled)
