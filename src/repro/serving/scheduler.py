"""Continuous-batching scheduler: admission + per-step work selection.

Policy, in one place:

* **Admission** — FIFO, no reordering: the queue head is admitted as soon
  as a slot is free AND the cache can reserve its worst-case footprint
  (prompt + max_new tokens).  Head-of-line blocking is deliberate; it
  keeps per-request latency predictable under overload.
* **Prefill-chunking** — per engine step, at most ONE chunk of ONE
  prefilling request is ingested (round-robin over prefilling slots),
  then every in-flight request decodes one token.  A 32k prompt therefore
  delays each decode step by one chunk (``prefill_chunk`` tokens), never
  by the whole prompt.
* **Decode** — all DECODE slots advance together in a single batched call;
  free/prefilling slots ride along masked-inactive.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.serving.cache import CacheManager
from repro.serving.request import (DECODE, PREFILL, Request, RequestQueue)


@dataclass(frozen=True)
class SchedulerConfig:
    n_slots: int = 8
    max_len: int = 256           # per-slot cache capacity (tokens)
    prefill_chunk: int = 16      # prompt tokens ingested per engine step
    page_size: int = 64          # tokens per KV page (accounting granule)


class Scheduler:
    def __init__(self, cfg: SchedulerConfig, cachemgr: CacheManager):
        self.cfg = cfg
        self.cachemgr = cachemgr
        self.queue = RequestQueue()
        self.slots: List[Optional[Request]] = [None] * cfg.n_slots
        self._prefill_rr = 0

    def submit(self, req: Request) -> None:
        self.queue.add(req)

    def admit_ready(self) -> List[Request]:
        """Admit queue-head requests while slot + page capacity lasts."""
        admitted = []
        while self.queue:
            head = self.queue.peek()
            total = len(head.prompt) + head.max_new_tokens
            if not self.cachemgr.can_admit(total):
                break
            req = self.queue.pop()
            req.slot = self.cachemgr.admit(total)
            req.state = PREFILL
            self.slots[req.slot] = req
            admitted.append(req)
        return admitted

    def next_prefill(self) -> Optional[Request]:
        """Round-robin over slots still ingesting their prompt."""
        n = self.cfg.n_slots
        for off in range(n):
            slot = (self._prefill_rr + off) % n
            req = self.slots[slot]
            if req is not None and req.state == PREFILL:
                self._prefill_rr = (slot + 1) % n
                return req
        return None

    def decode_requests(self) -> List[Tuple[int, Request]]:
        return [(s, r) for s, r in enumerate(self.slots)
                if r is not None and r.state == DECODE]

    def release(self, req: Request) -> None:
        self.slots[req.slot] = None
        self.cachemgr.free(req.slot)

    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slots)
