"""Continuous-batching serving engine (DESIGN.md §Serving)."""
from repro.serving.cache import CacheManager
from repro.serving.engine import ServingEngine
from repro.serving.request import (Request, RequestOutput, RequestQueue,
                                   SamplingParams)
from repro.serving.sampling import sample_tokens
from repro.serving.scheduler import Scheduler, SchedulerConfig
from repro.telemetry import latency_summary

__all__ = ["CacheManager", "ServingEngine", "Request", "RequestOutput",
           "RequestQueue", "SamplingParams", "sample_tokens", "Scheduler",
           "SchedulerConfig", "latency_summary"]
