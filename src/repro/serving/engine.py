"""Continuous-batching serving engine for the registered architectures.

Drives the jit'd inner steps from ``repro.launch.serve`` over a fixed-slot
batch: admitted requests prefill in chunks (one slot at a time, batch=1
cache slice) interleaved with batched single-token decode of every
in-flight request (per-slot positions + active mask).  Works for all
decoder-only registry archs — attention ring/KV caches, MLA latent caches,
and mamba2/xlstm/zamba2 recurrent state slots — because the cache is an
opaque pytree to the engine; only ``CacheManager`` accounting looks at the
block kinds.

Greedy decode of a request is bit-identical whether it runs alone or
batched (per-row cache isolation + masked writes); stochastic sampling is
also batch-composition-independent because the PRNG stream is keyed on
(request seed, output position).  Capacity-limited MoE is the documented
exception: routing competes across the batch (DESIGN.md §MoE).

    engine = ServingEngine(cfg, params, n_slots=8, max_len=256)
    engine.add_request(prompt_tokens, max_new_tokens=32)
    outputs = engine.run()
"""
from __future__ import annotations

import functools
import time
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.launch.serve import make_prefill_chunk_step, make_serve_step
from repro.models.registry import get_model
from repro.serving.cache import CacheManager
from repro.serving.request import (DECODE, FINISHED, Request, RequestOutput,
                                   SamplingParams)
from repro.serving.sampling import sample_tokens
from repro.serving.scheduler import Scheduler, SchedulerConfig
from repro.telemetry import Telemetry


# jit'd inner steps are cached on the (hashable, frozen) ModelConfig so
# every engine over the same arch shares one compilation — a fresh engine
# per benchmark level / test does not pay a recompile
@functools.lru_cache(maxsize=None)
def _jit_serve_step(mcfg: ModelConfig):
    return jax.jit(make_serve_step(mcfg), donate_argnums=(1,))


@functools.lru_cache(maxsize=None)
def _jit_chunk_step(mcfg: ModelConfig, chunk: int):
    return jax.jit(make_prefill_chunk_step(mcfg, chunk))


class ServingEngine:
    def __init__(self, mcfg: ModelConfig, params=None,
                 sched: SchedulerConfig = None, dtype=jnp.float32,
                 init_seed: int = 0, telemetry: Telemetry = None):
        if mcfg.is_encoder_decoder:
            raise ValueError(
                "ServingEngine serves decoder-only archs; enc-dec (whisper) "
                "uses the batch-synchronous path (examples/serve_demo.py)")
        self.mcfg = mcfg
        self.sched_cfg = sched or SchedulerConfig()
        model = get_model(mcfg)
        if params is None:
            params = model.init(jax.random.PRNGKey(init_seed), mcfg)
        self.params = params
        self.cachemgr = CacheManager(
            mcfg, self.sched_cfg.n_slots, self.sched_cfg.max_len,
            page_size=self.sched_cfg.page_size, dtype=dtype)
        self.scheduler = Scheduler(self.sched_cfg, self.cachemgr)
        self._decode_step = _jit_serve_step(mcfg)
        self._chunk_step = _jit_chunk_step(mcfg, self.sched_cfg.prefill_chunk)
        self._next_rid = 0
        self.n_steps = 0
        self.telemetry = telemetry if telemetry is not None \
            else Telemetry.disabled("serving")
        if not self.telemetry.engine:
            self.telemetry.engine = "serving"

    # ------------------------------------------------------------------
    def add_request(self, prompt: Sequence[int], max_new_tokens: int = 16,
                    sampling: SamplingParams = None) -> int:
        if len(prompt) < 1 or max_new_tokens < 1:
            raise ValueError("need a non-empty prompt and max_new_tokens>=1")
        total = len(prompt) + max_new_tokens
        if self.cachemgr.has_kv and total > self.sched_cfg.max_len:
            raise ValueError(
                f"request needs {total} cache positions > max_len="
                f"{self.sched_cfg.max_len} (KV cache would wrap)")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, [int(t) for t in prompt], max_new_tokens,
                      sampling or SamplingParams(),
                      arrival_t=time.perf_counter())
        self.scheduler.submit(req)
        return rid

    def has_work(self) -> bool:
        return self.scheduler.has_work()

    # ------------------------------------------------------------------
    def step(self) -> List[RequestOutput]:
        """One scheduler step: admit, one prefill chunk, one batched decode
        step.  Returns the requests that finished during this step."""
        tel = self.telemetry
        finished: List[Request] = []
        self.scheduler.admit_ready()
        req = self.scheduler.next_prefill()
        if req is not None:
            with tel.tracer.span("prefill_chunk"):
                self._prefill_one_chunk(req, finished)
        dec = self.scheduler.decode_requests()
        if dec:
            with tel.tracer.span("decode_step"):
                self._decode_all(dec, finished)
        self.n_steps += 1
        if tel.enabled:
            # scheduler gauges + per-step token counter: cheap host ints
            tel.counters.set("serving.queue_depth", len(self.scheduler.queue))
            tel.counters.set("serving.slots_occupied",
                             sum(r is not None for r in self.scheduler.slots))
            tel.counters.inc("serving.steps")
        outs = [self._output(r) for r in finished]
        for o in outs:
            tel.record_request(o)
        return outs

    def run(self, max_steps: int = 100_000) -> List[RequestOutput]:
        """Drive steps until queue and slots drain; outputs by rid.  With
        telemetry enabled, one ``summary`` event (latency percentiles, span
        timings, counters) is emitted after the drain."""
        outputs: List[RequestOutput] = []
        steps = 0
        while self.has_work():
            outputs.extend(self.step())
            steps += 1
            if steps >= max_steps:
                raise RuntimeError(f"engine did not drain in {max_steps} steps")
        outputs = sorted(outputs, key=lambda o: o.rid)
        if self.telemetry.enabled and outputs:
            self.telemetry.emit_summary(outputs)
        return outputs

    # ------------------------------------------------------------------
    def _prefill_one_chunk(self, req: Request, finished: List[Request]):
        C = self.sched_cfg.prefill_chunk
        P = len(req.prompt)
        n = min(C, P - req.prefilled)
        buf = np.zeros((1, C), np.int32)
        buf[0, :n] = req.prompt[req.prefilled:req.prefilled + n]
        last_logits, part = self._chunk_step(
            self.params, self.cachemgr.slot_view(req.slot),
            jnp.asarray(buf), jnp.asarray(req.prefilled, jnp.int32),
            jnp.asarray(n, jnp.int32))
        self.cachemgr.write_slot(req.slot, part)
        req.prefilled += n
        if req.prefilled == P:
            tok = int(np.asarray(self._sample_rows(last_logits, [req], [0]))[0])
            req.out_tokens.append(tok)
            req.first_token_t = time.perf_counter()
            req.state = DECODE
            if len(req.out_tokens) >= req.max_new_tokens:
                self._finish(req, finished)

    def _decode_all(self, dec, finished: List[Request]):
        # full-width (n_slots) arrays so sample_tokens compiles once;
        # inactive rows sample garbage that is never read
        B = self.sched_cfg.n_slots
        tokens = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        active = np.zeros((B,), bool)
        temps = np.zeros((B,), np.float32)
        top_ks = np.zeros((B,), np.int32)
        seeds = np.zeros((B,), np.int32)
        counters = np.zeros((B,), np.int32)
        for slot, r in dec:
            tokens[slot, 0] = r.out_tokens[-1]
            pos[slot] = len(r.prompt) + len(r.out_tokens) - 1
            active[slot] = True
            temps[slot] = r.sampling.temperature
            top_ks[slot] = r.sampling.top_k
            seeds[slot] = r.sampling.seed
            counters[slot] = len(r.out_tokens)
        logits, self.cachemgr.cache = self._decode_step(
            self.params, self.cachemgr.cache, jnp.asarray(tokens),
            jnp.asarray(pos), jnp.asarray(active))
        toks = np.asarray(sample_tokens(
            logits, jnp.asarray(temps), jnp.asarray(top_ks),
            jnp.asarray(seeds), jnp.asarray(counters)))
        for slot, r in dec:
            r.out_tokens.append(int(toks[slot]))
            if len(r.out_tokens) >= r.max_new_tokens:
                self._finish(r, finished)

    def _sample_rows(self, logits, reqs: List[Request], rows: List[int]):
        """Sample one token per request from ``logits`` rows ``rows``."""
        sel = jnp.asarray(np.asarray(rows, np.int32))
        temps = jnp.asarray([r.sampling.temperature for r in reqs],
                            jnp.float32)
        top_ks = jnp.asarray([r.sampling.top_k for r in reqs], jnp.int32)
        seeds = jnp.asarray([r.sampling.seed for r in reqs], jnp.int32)
        counters = jnp.asarray([len(r.out_tokens) for r in reqs], jnp.int32)
        return sample_tokens(logits[sel], temps, top_ks, seeds, counters)

    def _finish(self, req: Request, finished: List[Request]):
        req.state = FINISHED
        req.finish_t = time.perf_counter()
        self.scheduler.release(req)
        finished.append(req)

    @staticmethod
    def _output(req: Request) -> RequestOutput:
        return RequestOutput(req.rid, req.prompt, list(req.out_tokens),
                             req.arrival_t, req.first_token_t, req.finish_t)
