"""Sharding rules for the pod engine.

Layout: 2-D (data, model) mesh per pod; optional leading "pod" axis.

* parameters — FSDP over "data" on the non-TP dim, tensor-parallel over
  "model" on the contraction-friendly dim (column-parallel for up/qkv
  projections, row-parallel for down/output projections, expert-parallel on
  the expert dim for MoE).
* FL server state (momentum m, control variates) — same spec as the
  parameter it mirrors: the FedADC momentum is a full-size vector and MUST
  shard exactly like θ or every round pays a reshard.
* batches — client dims replicated/pod-sharded, sample dim over "data".
* decode caches — batch over "data" when divisible, else sequence; heads
  over "model" when divisible, else head_dim.

Every rule is divisibility-guarded: a dim that doesn't divide its mesh axis
falls back to replicated rather than failing to lower.
"""
from __future__ import annotations

from typing import Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# param dict keys (the name of the dict that owns the "w"/"b" leaf)
COLUMN_PARALLEL = {
    "wq", "wk", "wv", "wuq", "wuk", "wuv", "wdq", "wdkv", "gate", "up",
    "in_proj", "wx", "w_if", "fc1", "f1", "f2", "f3", "head", "router",
    "vis_proj", "lm_head",
}
ROW_PARALLEL = {"wo", "down", "out_proj", "fc2"}


def _axis(mesh: Mesh, name):
    if isinstance(name, tuple):
        n = 1
        for a in name:
            n *= _axis(mesh, a)
        return n
    return mesh.shape[name] if name in mesh.shape else 1


def _div(dim: int, mesh: Mesh, axis: str):
    return axis if dim % max(_axis(mesh, axis), 1) == 0 else None


def _path_keys(path) -> Tuple[str, ...]:
    return tuple(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def spec_for_param(path, shape, mesh: Mesh, fsdp="data", tp="model",
                   mode="train"):
    """mode="train": FSDP×TP (params gathered per use — right when the same
    weights are re-read H×CS times per round and HBM is the binding
    constraint).  mode="serve": no FSDP — dense weights TP-sharded and
    replicated over "data", MoE experts expert-parallel over "data" × TP
    over "model"; eliminates the per-layer param all-gathers that dominate
    the inference collective term (§Perf iteration 6)."""
    keys = _path_keys(path)
    owner = keys[-2] if len(keys) >= 2 else keys[-1]
    leafname = keys[-1]
    rank = len(shape)
    if mode == "serve":
        fsdp = None

    def guard(spec):
        # enforce divisibility dim-by-dim; pad leading None for stacked runs
        out = [None] * rank
        trailing = len(spec)
        for i, ax in enumerate(spec):
            dim_idx = rank - trailing + i
            if dim_idx < 0 or ax is None:
                continue
            if shape[dim_idx] % max(_axis(mesh, ax), 1) == 0:
                out[dim_idx] = ax
        return P(*out)

    if leafname == "emb":
        return guard((tp, fsdp))
    if owner == "experts":                       # (E, d, f) / (E, f, d)
        if mode == "serve":                      # expert-parallel over data
            if leafname in ("gate", "up"):
                return guard(("data", None, tp))
            return guard(("data", tp, None))     # down
        if leafname in ("gate", "up"):
            return guard((tp, fsdp, None))
        return guard((tp, None, fsdp))           # down
    if leafname in ("conv_w", "conv_b", "A_log", "D", "dt_bias", "scale",
                    "bias", "r"):
        return P(*([None] * rank))
    if leafname == "pos_dec":
        return guard((None, fsdp))
    if leafname == "b":
        return P(*([None] * rank))
    if leafname == "w" or owner in COLUMN_PARALLEL | ROW_PARALLEL:
        name = owner if leafname in ("w", "b") else leafname
        if name in COLUMN_PARALLEL:
            return guard((fsdp, tp))
        if name in ROW_PARALLEL:
            return guard((tp, fsdp))
    return P(*([None] * rank))


def param_shardings(params_shapes, mesh: Mesh, fsdp="data", tp="model",
                    mode="train", fsdp_over_pod=False, tp_off=False):
    if fsdp_over_pod and "pod" in mesh.shape:
        fsdp = ("pod", "data")
    if tp_off:
        # sub-1B archs: L²-sized TP partial-sum all-reduces (e.g. the mLSTM
        # parallel form contracting the sharded P dim) dwarf the param
        # traffic — pure FSDP/data-parallel wins (§Perf iteration 11)
        tp = None
    """ShapeDtypeStruct/array pytree -> NamedSharding pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    shardings = [NamedSharding(mesh, spec_for_param(p, np.shape(l), mesh,
                                                    fsdp, tp, mode=mode))
                 for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, shardings)


# ---------------------------------------------------------------------------
# decode caches
# ---------------------------------------------------------------------------
def spec_for_cache(path, shape, mesh: Mesh, data="data", tp="model"):
    keys = _path_keys(path)
    leafname = keys[-1]
    rank = len(shape)
    if leafname == "kpos" or rank <= 1:
        return P(*([None] * rank))

    def pick(dims):
        """dims: list of (dim_idx, axis_pref) tried in order per axis."""
        out = [None] * rank
        used = set()
        for dim_idx, ax in dims:
            if dim_idx >= rank or ax in used or out[dim_idx] is not None:
                continue
            if shape[dim_idx] % max(_axis(mesh, ax), 1) == 0 \
                    and shape[dim_idx] >= _axis(mesh, ax):
                out[dim_idx] = ax
                used.add(ax)
        return P(*out)

    # layouts: stacked-run caches have a leading layer dim
    off = 1 if rank >= 4 or (rank == 3 and leafname in ("kpos",)) else 0
    if leafname in ("k", "v", "xk", "xv"):       # (L?, B, S, Hk, hd)
        b, s, h, d = rank - 4, rank - 3, rank - 2, rank - 1
        return pick([(b, data), (s, data), (h, tp), (d, tp)])
    if leafname in ("c_kv", "k_rope"):           # (L?, B, S, r)
        b, s, r = rank - 3, rank - 2, rank - 1
        return pick([(b, data), (s, data), (r, tp)])
    if leafname == "h":                          # (L?, B, H, N, P)
        b, h, n, p = rank - 4, rank - 3, rank - 2, rank - 1
        return pick([(b, data), (h, tp), (p, tp), (n, data)])
    if leafname == "C":                          # mlstm (L?, B, H, P, P)
        b, h, p1, p2 = rank - 4, rank - 3, rank - 2, rank - 1
        return pick([(b, data), (h, tp), (p1, tp), (p2, data)])
    if leafname in ("n", "m", "conv", "c"):
        b = rank - 2 if leafname in ("n", "c") else rank - 2
        # generic: try batch dim then last dim
        return pick([(rank - 3 if rank >= 3 else 0, data), (rank - 1, tp)])
    return P(*([None] * rank))


def cache_shardings(cache_shapes, mesh: Mesh, data="data", tp="model"):
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    shardings = [NamedSharding(mesh, spec_for_cache(p, np.shape(l), mesh,
                                                    data, tp))
                 for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, shardings)


# ---------------------------------------------------------------------------
# batches
# ---------------------------------------------------------------------------
def train_batch_spec(mesh: Mesh, multi_pod: bool):
    """tokens/labels (CP, CS, H, b, L): client-parallel dim over "pod"."""
    lead = "pod" if (multi_pod and "pod" in mesh.shape) else None
    return P(lead, None, None, "data", None)


def serve_batch_spec(mesh: Mesh, batch: int, multi_pod: bool):
    axes = []
    if multi_pod and "pod" in mesh.shape and batch % (
            _axis(mesh, "pod") * _axis(mesh, "data")) == 0:
        axes = [("pod", "data")]
    elif batch % _axis(mesh, "data") == 0:
        axes = ["data"]
    else:
        axes = [None]
    return P(axes[0], None)
