"""Fleet-scale aggregation bench: flat vs two-tier hierarchical, K ∈
{10^3, 10^4, 10^5} simulated clients (DESIGN.md §Fleet; emits
BENCH_fleet.json).

Substrate-level on purpose: no model training, just the round substrate
the fleet subsystem changes — a ``FleetScheduler`` cohort, a
``PagedClientStore`` EF gather/scatter per round, seeded synthetic
deltas, and the repo's real ``weighted_mean`` reduction — so the
K=10^5 cell costs seconds, not hours.  Per (fleet, mode) cell:

* **flat** stages the whole cohort's wires as one (C, d) block and runs
  one global ``weighted_mean`` — the O(C·d) server staging footprint the
  ROADMAP flagged.
* **hier** walks the cohort's regions sequentially: each regional block
  (k_r, d) is staged, reduced to a partial, and FREED before the next
  region is built; the global combine then reduces the (R, d) partial
  stack — exactly ``hierarchical_aggregate``'s split, so peak staging
  drops from O(C·d) to O((C/R)·d + R·d).

Peak host bytes are measured from the actual ``.nbytes`` of live staged
blocks plus the store's resident high-water mark — deterministic given
the seed, so the CI gate compares them within tolerance.  Wall-clock
fields ride SKIP_KEY-named keys (``rounds_per_s``, ``*_per_round``);
the headline booleans (``hier_le_flat_peak_at_1e5``, ``budget_ok_at_1e5``)
and the deterministic byte ratio are the gated claims, and the
``rounds_per_s`` ratio is ``--require``-pinned finite without being
tolerance-compared.

``--smoke`` keeps all three K cells (the committed JSON's list lengths
must match CI's fresh run) and only trims the round count; every gated
field is round-count invariant — staging peaks repeat identically each
round, and the store peak hits its budget-bound ceiling during the first
round's scatter because the cohort's pages exceed the budget.
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.base import FedConfig
from repro.federated import aggregation as A
from repro.federated.fleet import FleetScheduler, PagedClientStore
from repro.telemetry.tracer import Counters

FLEETS = (1_000, 10_000, 100_000)
COHORT = 256
REGIONS = 8
DIM = 8192                      # 32 KiB fp32 page per client
BUDGET_PAGES = 64               # < COHORT pages -> steady-state spilling


def _run_mode(fleet: int, hierarchical: bool, rounds: int, seed: int = 0):
    """Drive `rounds` substrate rounds; returns the cell dict."""
    d = DIM
    budget = BUDGET_PAGES * d * 4
    fed = FedConfig(n_clients=fleet, clients_per_round=COHORT,
                    fleet_regions=REGIONS if hierarchical else 0)
    counters = Counters()
    store = PagedClientStore(budget_bytes=budget, counters=counters)
    store.register("ef", lambda: np.zeros((d,), np.float32))
    sched = FleetScheduler(fed, n_regions=REGIONS if hierarchical else 1,
                           seed=seed)
    rng = np.random.RandomState(seed)
    peak_staging = 0
    t0 = time.time()
    for _ in range(rounds):
        cohort = sched.sample_cohort()
        groups = (cohort.region_slices() if hierarchical
                  else ((0, len(cohort.clients)),))
        partials, gw = [], []
        for start, size in groups:
            ids = cohort.clients[start:start + size]
            efs = store.gather("ef", ids)                    # (size, d)
            deltas = jnp.asarray(rng.randn(size, d).astype(np.float32))
            wires = deltas + efs
            w = jnp.ones((size,), jnp.float32)
            m = A.weighted_mean(wires, w)
            jax.block_until_ready(m)
            staged = (int(efs.nbytes + deltas.nbytes + wires.nbytes)
                      + sum(int(p.nbytes) for p in partials))
            peak_staging = max(peak_staging, staged)
            partials.append(m)
            gw.append(jnp.sum(w))
            store.scatter("ef", ids, wires * 0.5)            # EF update
            del efs, deltas, wires                           # free the block
        if hierarchical:
            gmean = A.weighted_mean(jnp.stack(partials), jnp.stack(gw))
        else:
            gmean = partials[0]
        jax.block_until_ready(gmean)
    wall = time.time() - t0
    snap = counters.snapshot()
    peak_store = int(store.peak_resident_bytes)
    return {
        "fleet": fleet,
        "mode": "hier" if hierarchical else "flat",
        "regions": REGIONS if hierarchical else 0,
        "cohort": COHORT,
        "d": d,
        "store_budget_bytes": budget,
        "peak_staging_bytes": int(peak_staging),
        "peak_store_bytes": peak_store,
        "peak_host_bytes": int(peak_staging) + peak_store,
        "budget_ok": bool(peak_store <= budget),
        "spills_per_round": round(snap.get("store.spills", 0) / rounds, 1),
        "loads_per_round": round(snap.get("store.loads", 0) / rounds, 1),
        "rounds_per_s": round(rounds / wall, 2),
    }


def main(rows=None, out_json="BENCH_fleet.json", smoke=False):
    rows = rows if rows is not None else []
    rounds = 2 if smoke else 3
    cells = []
    for fleet in FLEETS:
        for hierarchical in (False, True):
            cell = _run_mode(fleet, hierarchical, rounds)
            cells.append(cell)
            rows.append(emit(
                f"fleet.K{fleet}.{cell['mode']}",
                1e6 / cell["rounds_per_s"],
                f"peak_host_mb={cell['peak_host_bytes'] / 2**20:.1f};"
                f"spills_per_round={cell['spills_per_round']}"))
    at_1e5 = {c["mode"]: c for c in cells if c["fleet"] == 100_000}
    report = {
        "cohort": COHORT,
        "regions": REGIONS,
        "d": DIM,
        "rounds_per_cell": rounds,
        "cells": cells,
        "headline": {
            "hier_le_flat_peak_at_1e5": bool(
                at_1e5["hier"]["peak_host_bytes"]
                <= at_1e5["flat"]["peak_host_bytes"]),
            "budget_ok_at_1e5": bool(at_1e5["hier"]["budget_ok"]
                                     and at_1e5["flat"]["budget_ok"]),
            "peak_host_hier_over_flat_at_1e5": round(
                at_1e5["hier"]["peak_host_bytes"]
                / at_1e5["flat"]["peak_host_bytes"], 4),
            "rounds_per_s_ratio_hier_vs_flat_at_1e5": round(
                at_1e5["hier"]["rounds_per_s"]
                / at_1e5["flat"]["rounds_per_s"], 3),
        },
    }
    with open(out_json, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {out_json}")
    assert report["headline"]["budget_ok_at_1e5"], (
        "paged store exceeded its resident-bytes budget at K=1e5")
    assert report["headline"]["hier_le_flat_peak_at_1e5"], (
        "hierarchical peak host bytes no longer <= flat at K=1e5")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_fleet.json")
    ap.add_argument("--smoke", action="store_true",
                    help="fewer rounds per cell; all K cells kept")
    args = ap.parse_args()
    main(out_json=args.out, smoke=args.smoke)
