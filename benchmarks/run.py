"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Modules:
  fig1_acceleration  — Fig. 1 a-c  (FedADC vs FedAvg vs SlowMo, s=2,3,4)
  fig2_robustness    — Fig. 2      (FedADC robustness to skew; red vs blue)
  table1_sota        — Table I     (vs MOON/FedGKD/FedNTD/FedDyn/FedProx/
                                     SCAFFOLD/FedRS, 2 regimes)
  fig5_scale         — Fig. 5/6    (low participation, many clients)
  fig7_personalization — Fig. 7    (classifier calibration, 3 regularisers)
  clustering         — Sec. IV-E   (class-coverage client selection)
  kernels_bench      — Pallas kernels µs/call + derived bytes/flops
  roofline_report    — §Roofline terms per (arch × shape × mesh) from the
                       dry-run artifacts
  straggler_bench    — wall-clock-to-accuracy, sync vs semi-async FedADC
                       under a 4× straggler fleet (DESIGN.md §Heterogeneity)
  serving_bench      — continuous batching vs serial decode: offered-load
                       sweep, tokens/sec + p50/p95 latency
                       (DESIGN.md §Serving; emits BENCH_serving.json)
  comm_load          — Sec. II-A   analytic bytes/round per strategy, side by
                       side with measured per-client wire bytes through each
                       compressor (DESIGN.md §Compression)
  comm_sweep         — accuracy-vs-uplink-bytes frontier, strategy ×
                       compressor on the non-IID benchmark (emits
                       BENCH_comm.json)
  telemetry_bench    — telemetry-enabled vs disabled sync rounds: the
                       DESIGN.md §Telemetry ≤5% overhead contract,
                       measured (emits BENCH_telemetry.json)
  fleet_bench        — flat vs two-tier hierarchical aggregation at
                       K ∈ {1e3,1e4,1e5} simulated clients: rounds/s +
                       peak host bytes with a paged, budget-bounded
                       client store (DESIGN.md §Fleet; emits
                       BENCH_fleet.json)
"""
import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args()

    from benchmarks import (ablation_beta, clustering, comm_load, comm_sweep,
                            fig1_acceleration, fig2_robustness, fig5_scale,
                            fig7_personalization, fleet_bench, kernels_bench,
                            lm_round, roofline_report, serving_bench,
                            straggler_bench, table1_sota, telemetry_bench)
    mods = {
        "kernels_bench": kernels_bench,
        "comm_load": comm_load,
        "comm_sweep": comm_sweep,
        "roofline_report": roofline_report,
        "fig1_acceleration": fig1_acceleration,
        "fig2_robustness": fig2_robustness,
        "table1_sota": table1_sota,
        "fig5_scale": fig5_scale,
        "fig7_personalization": fig7_personalization,
        "clustering": clustering,
        "lm_round": lm_round,
        "ablation_beta": ablation_beta,
        "straggler_bench": straggler_bench,
        "serving_bench": serving_bench,
        "telemetry_bench": telemetry_bench,
        "fleet_bench": fleet_bench,
    }
    picked = (args.only.split(",") if args.only else list(mods))
    print("name,us_per_call,derived")
    rows = []
    for name in picked:
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        try:
            mods[name].main(rows)
        except Exception as e:  # pragma: no cover - keep harness robust
            print(f"{name},0,ERROR:{e!r}", flush=True)
        print(f"# {name} took {time.time()-t0:.1f}s", flush=True)
    print(f"# total rows: {len(rows)}")


if __name__ == "__main__":
    main()
