"""Sec. IV-E: data-aware client selection — class-coverage-constrained
sampling vs uniform random at low participation (paper: +2.1% on CIFAR-10
s=2, C=0.1)."""
from benchmarks.common import dataset, emit, partitions, run_fl

ROUNDS = 50


def main(rows=None):
    data = dataset()
    rows = rows if rows is not None else []
    parts = partitions(data[1], 20, "sort", 2)
    accs = {}
    for selector in ("random", "class_coverage"):
        r = run_fl("fedadc", parts, data, rounds=ROUNDS, eta=0.01,
                   clients_per_round=3, selector=selector)
        accs[selector] = r["acc"]
        rows.append(emit(f"clustering.{selector}", r["us_per_round"],
                         f"{r['acc']:.3f}"))
    rows.append(emit("clustering.coverage_minus_random", 0,
                     f"{accs['class_coverage'] - accs['random']:+.3f}"))
    return rows


if __name__ == "__main__":
    main()
