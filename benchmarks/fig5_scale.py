"""Fig. 5/6: large-scale low-participation regime — FedADC+ vs FedDyn with
many clients and small participation ratio (paper: 500-1000 clients,
C=0.01-0.02; here 50 clients, C=0.06)."""
from benchmarks.common import dataset, emit, partitions, run_fl

ROUNDS = 50


def main(rows=None):
    data = dataset()
    rows = rows if rows is not None else []
    parts = partitions(data[1], 50, "dir", 0.3)
    # the paper's stress regime: MANY local epochs at low participation is
    # where FedDyn's dynamic regularisation destabilises (Fig. 5b)
    for name, strat, kw in (
            ("fedadc+", "fedadc", dict(eta=0.01, distill=True)),
            ("feddyn", "feddyn", dict(eta=0.05)),
            ("fedavg", "fedavg", dict(eta=0.05))):
        r = run_fl(strat, parts, data, rounds=ROUNDS, n_clients=50,
                   clients_per_round=3, local_steps=20, **kw)
        rows.append(emit(f"fig5.C0.06.{name}", r["us_per_round"],
                         f"{r['acc']:.3f}"))
    return rows


if __name__ == "__main__":
    main()
