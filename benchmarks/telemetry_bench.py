"""Telemetry overhead bench: the §Telemetry ≤5% contract, measured.

Runs the same synchronous FedADC configuration twice — telemetry disabled
(the default) and enabled with in-jit drift diagnostics + span tracing —
and compares wall-clock per round after a shared warmup.  The enabled run
pays exactly one extra host fetch per round (the metric scalar tree) and a
handful of in-jit reductions; the bench asserts the measured overhead
stays within the documented 5% budget and emits ``BENCH_telemetry.json``
for the CI bench-smoke gate (``overhead_le_5pct`` is the committed
boolean; the raw ratio rides a wall-clock-named key the regression walk
skips).

Also sanity-checks the contract's other half while it is at it: the
enabled and disabled runs must produce identical final accuracy — the
observability path is not allowed to touch the numerics.
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from benchmarks.common import dataset, emit, partitions, run_fl
from repro.telemetry import Telemetry

MAX_OVERHEAD = 0.05


def _timed_run(parts, data, rounds, warmup, telemetry):
    # one throwaway run compiles the round/eval functions for this config
    # (jit caches are keyed on the traced program, which differs between
    # the metric and no-metric round functions)
    run_fl("fedadc", parts, data, rounds=warmup, n_clients=20, seed=0,
           telemetry=Telemetry(engine="sim") if telemetry else None)
    t0 = time.perf_counter()
    r = run_fl("fedadc", parts, data, rounds=rounds, n_clients=20, seed=0,
               telemetry=Telemetry(engine="sim") if telemetry else None)
    jax.block_until_ready(r["sim"].params)  # barrier before stopping the clock
    return time.perf_counter() - t0, r


def main(rows=None, rounds=40, warmup=4, out_json="BENCH_telemetry.json"):
    rows = rows if rows is not None else []
    data = dataset()
    parts = partitions(data[1], 20, "sort", 2, seed=0)
    wall_off, r_off = _timed_run(parts, data, rounds, warmup, False)
    wall_on, r_on = _timed_run(parts, data, rounds, warmup, True)
    ratio = wall_on / wall_off
    overhead = ratio - 1.0
    rows.append(emit("telemetry.sync_round_overhead",
                     wall_on / rounds * 1e6, f"{overhead:+.2%}"))
    identical = bool(r_on["acc"] == r_off["acc"])
    rows.append(emit("telemetry.enabled_acc_identical", 0, identical))
    report = {
        "rounds": rounds,
        "wall_ratio_on_vs_off": round(ratio, 4),
        "overhead_le_5pct": bool(overhead <= MAX_OVERHEAD),
        "enabled_acc_identical": identical,
    }
    with open(out_json, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {out_json}")
    assert identical, "telemetry-enabled run changed the accuracy"
    assert overhead <= MAX_OVERHEAD, (
        f"telemetry overhead {overhead:+.2%} exceeds the documented "
        f"{MAX_OVERHEAD:.0%} budget")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--out", default="BENCH_telemetry.json")
    args = ap.parse_args()
    main(rounds=args.rounds, out_json=args.out)
