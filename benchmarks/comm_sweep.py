"""Accuracy-vs-bytes frontier (the measured version of Sec. II-A).

Three sweeps on the synthetic non-IID benchmark (sorted 2-class shards, the
paper's hardest skew):

* **sync** — strategy × uplink codec on the synchronous simulator: final
  accuracy against the *measured* bytes the transport wire formats actually
  carry, in both directions (downlink is the real (θ_t, ctx) broadcast
  tree, measured — not the analytic n·4·clients floor).
* **async** — the ROADMAP-requested ``topk_frac``/``qsgd_bits`` ×
  staleness axis on the semi-async engine: each compression knob runs under
  a bimodal straggler fleet with buffered-K aggregation, with and without
  staleness discounting, so the frontier shows how lossy uplinks compose
  with stale pseudo-gradients (EF mass is conserved across drops).
* **downlink** — the downlink frontier: FedADC under the per-direction
  downlink codecs, headlined by the momentum-aware Δm̄ reference-coded
  broadcast (``delta``), which drives measured downlink from the naive 2×
  raw θ (the wire tree carries m̄_t) to ~1× — the paper's overlapped
  broadcast, now measured — while staying bit-lossless; ``delta+topk`` /
  ``delta+qsgd`` push below 1× by compressing the θ-delta itself.

Headline check (asserted into the JSON, gated in CI): top-k 10% with error
feedback stays within 2 accuracy points of the uncompressed FedADC run
while shrinking measured uplink bytes ≥ 5×.

Emits ``BENCH_comm.json`` plus the repo-standard CSV rows.  The committed
JSON is produced by the default (smoke-scale) configuration so the CI
``bench-smoke`` job can regenerate it deterministically and diff within
tolerance; ``--rounds`` scales the sweep up for real frontier plots.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from benchmarks.common import (HeteroConfig, dataset, emit, partitions,
                               run_fl, run_fl_async)
from repro.telemetry import Telemetry

STRATEGIES = ("fedavg", "slowmo", "fedadc")
COMPRESSORS = (
    ("none", {"compressor": "none"}),
    ("topk10_ef", {"compressor": "topk", "topk_frac": 0.10,
                   "error_feedback": True}),
    ("qsgd4_ef", {"compressor": "qsgd", "qsgd_bits": 4,
                  "error_feedback": True}),
)

# async axis: compression knobs × staleness handling, under stragglers
ASYNC_KNOBS = (
    ("topk5_ef", {"compressor": "topk", "topk_frac": 0.05,
                  "error_feedback": True}),
    ("topk20_ef", {"compressor": "topk", "topk_frac": 0.20,
                   "error_feedback": True}),
    ("qsgd2_ef", {"compressor": "qsgd", "qsgd_bits": 2,
                  "error_feedback": True}),
    ("qsgd8_ef", {"compressor": "qsgd", "qsgd_bits": 8,
                  "error_feedback": True}),
)
ASYNC_STALENESS = (
    ("stale_none", {"buffer_k": 2, "staleness_mode": "none"}),
    ("stale_poly", {"buffer_k": 2, "staleness_mode": "poly",
                    "staleness_factor": 0.5}),
)
ASYNC_HETERO = HeteroConfig(enabled=True, speed_dist="bimodal",
                            straggler_frac=0.25, straggler_slowdown=4.0,
                            seed=0)

# downlink frontier: FedADC × per-direction downlink codecs.  The
# "down_none" baseline is not re-run: it is the sync sweep's
# ("fedadc", "none") cell (byte-for-byte the same configuration), reused
# in main() instead of duplicating the longest 90-round run.
DOWNLINK_KNOBS = (
    ("down_delta", {"downlink_compressor": "delta"}),
    ("down_delta_topk10", {"downlink_compressor": "delta+topk",
                           "downlink_topk_frac": 0.10}),
    ("down_delta_qsgd8", {"downlink_compressor": "delta+qsgd",
                          "downlink_qsgd_bits": 8}),
)

# intermittent participation × catch-up horizon: the unicast downlink under
# clients that miss rounds (HeteroConfig availability thinning on the async
# engine).  The horizon is accounting-only — the trajectory is identical
# across it — but the bytes are not: staleness ≤ horizon rides the cheap
# chained θ-delta, horizon 0 degenerates to a full-θ resync per revisit.
INTERMITTENT_GRID = tuple((av, h) for av in (1.0, 0.5) for h in (0, 4))


def _cell(name_kv, r):
    s = r["sim"]
    cell = dict(name_kv)
    cell.update({
        "acc": round(r["acc"], 4),
        "uplink_bytes": int(s.uplink_bytes),
        "uplink_bytes_raw": int(s.uplink_bytes_raw),
        "downlink_bytes": int(s.downlink_bytes),
        "downlink_bytes_raw": int(s.downlink_bytes_raw),
        "bytes_reduction": round(s.uplink_bytes_raw / s.uplink_bytes, 2),
        "us_per_round": r["us_per_round"],
    })
    return cell


def _drift_cell(tel: Telemetry):
    """First/last points of each per-round drift metric — the curve's
    endpoints are the deterministic, tolerance-friendly summary the CI
    gate can diff (the full curve rides the JSONL export, not the bench
    JSON)."""
    dc = list(tel.drift_curve)
    first, last = dc[0], dc[-1]
    out = {}
    for k in sorted(last):
        if k == "round":
            continue
        out[f"{k}_first"] = round(float(first.get(k, last[k])), 5)
        out[f"{k}_last"] = round(float(last[k]), 5)
    out["rounds_recorded"] = len(dc)
    return out


def sweep(rounds=90, n_clients=20, seed=0):
    data = dataset()
    parts = partitions(data[1], n_clients, "sort", 2, seed=seed)
    cells, drift = [], {}
    for strat in STRATEGIES:
        for cname, extra in COMPRESSORS:
            tel = Telemetry(engine="sim")
            r = run_fl(strat, parts, data, rounds=rounds,
                       n_clients=n_clients, seed=seed, extra_fed=extra,
                       telemetry=tel)
            cells.append(_cell({"strategy": strat, "compressor": cname}, r))
            drift[f"{strat}_{cname}"] = _drift_cell(tel)
    return cells, drift


def _down_ratio(cell):
    # measured broadcast bytes against the raw θ a client uploads — the
    # paper's "no additional communication load" axis
    return round(cell["downlink_bytes"] / cell["uplink_bytes_raw"], 3)


def downlink_sweep(base_cell, rounds=90, n_clients=20, seed=0):
    """FedADC downlink frontier.  `base_cell` is the sync sweep's
    ("fedadc", "none") cell, reused as the "down_none" baseline."""
    data = dataset()
    parts = partitions(data[1], n_clients, "sort", 2, seed=seed)
    down_none = dict(base_cell, downlink="down_none",
                     downlink_vs_uplink_raw=_down_ratio(base_cell))
    down_none.pop("compressor", None)
    cells = [down_none]
    for dname, extra in DOWNLINK_KNOBS:
        r = run_fl("fedadc", parts, data, rounds=rounds,
                   n_clients=n_clients, seed=seed, extra_fed=extra)
        cell = _cell({"strategy": "fedadc", "downlink": dname}, r)
        cell["downlink_vs_uplink_raw"] = _down_ratio(cell)
        cells.append(cell)
    return cells


def async_sweep(rounds=80, n_clients=20, seed=0):
    data = dataset()
    parts = partitions(data[1], n_clients, "sort", 2, seed=seed)
    cells, drift = [], {}
    for cname, comp in ASYNC_KNOBS:
        for sname, stale in ASYNC_STALENESS:
            extra = dict(comp)
            extra.update(stale)
            tel = Telemetry(engine="async")
            r = run_fl_async("fedadc", parts, data, hetero=ASYNC_HETERO,
                             rounds=rounds, n_clients=n_clients, seed=seed,
                             extra_fed=extra, telemetry=tel)
            cell = _cell({"compressor": cname, "staleness": sname}, r)
            cell["mean_staleness"] = round(r["sim"].staleness_hist.mean(), 3)
            cells.append(cell)
            drift[f"async_{cname}_{sname}"] = _drift_cell(tel)
    return cells, drift


def intermittent_sweep(rounds=40, n_clients=20, seed=0):
    """FedADC + lossless delta + unicast on the async engine over the
    availability × resync_horizon grid, with the per-class byte totals the
    CI gate pins."""
    data = dataset()
    parts = partitions(data[1], n_clients, "sort", 2, seed=seed)
    cells = []
    for av, h in INTERMITTENT_GRID:
        het = HeteroConfig(enabled=True, speed_dist="bimodal",
                           straggler_frac=0.25, straggler_slowdown=4.0,
                           availability=av, seed=0)
        extra = {"downlink_compressor": "delta", "downlink_unicast": True,
                 "resync_horizon": h, "buffer_k": 2}
        r = run_fl_async("fedadc", parts, data, hetero=het, rounds=rounds,
                         n_clients=n_clients, seed=seed, extra_fed=extra)
        s = r["sim"]
        t = s.transport
        n_catchup, n_resync = int(s.refs.catchups), int(s.refs.resyncs)
        cells.append({
            "availability": av, "resync_horizon": h,
            "acc": round(r["acc"], 4),
            "downlink_bytes": int(s.downlink_bytes),
            "downlink_bytes_raw": int(s.downlink_bytes_raw),
            "catchups": n_catchup, "resyncs": n_resync,
            "catchup_bytes": int(n_catchup * t._down_nbytes),
            "resync_bytes": int(n_resync * t._down_raw),
            "us_per_round": r["us_per_round"],
        })
    return cells


def main(rows=None, rounds=90, async_rounds=80, intermittent_rounds=40,
         out_json="BENCH_comm.json"):
    rows = rows if rows is not None else []
    cells, drift = sweep(rounds=rounds)
    by = {(c["strategy"], c["compressor"]): c for c in cells}
    for c in cells:
        rows.append(emit(
            f"comm_sweep.{c['strategy']}.{c['compressor']}",
            c["us_per_round"],
            f"acc={c['acc']};up_MB={c['uplink_bytes']/2**20:.2f};"
            f"down_MB={c['downlink_bytes']/2**20:.2f};"
            f"reduction={c['bytes_reduction']:.2f}x"))
    async_cells, async_drift = async_sweep(rounds=async_rounds)
    drift.update(async_drift)
    for c in async_cells:
        rows.append(emit(
            f"comm_sweep.async.fedadc.{c['compressor']}.{c['staleness']}",
            c["us_per_round"],
            f"acc={c['acc']};up_MB={c['uplink_bytes']/2**20:.2f};"
            f"stale={c['mean_staleness']:.2f};"
            f"reduction={c['bytes_reduction']:.2f}x"))
    intermittent_cells = intermittent_sweep(rounds=intermittent_rounds)
    for c in intermittent_cells:
        rows.append(emit(
            f"comm_sweep.intermittent.av{c['availability']}"
            f".h{c['resync_horizon']}", c["us_per_round"],
            f"acc={c['acc']};down_MB={c['downlink_bytes']/2**20:.2f};"
            f"catchups={c['catchups']};resyncs={c['resyncs']}"))
    downlink_cells = downlink_sweep(by[("fedadc", "none")], rounds=rounds)
    for c in downlink_cells:
        rows.append(emit(
            f"comm_sweep.downlink.fedadc.{c['downlink']}",
            c["us_per_round"],
            f"acc={c['acc']};down_MB={c['downlink_bytes']/2**20:.2f};"
            f"down_vs_up_raw={c['downlink_vs_uplink_raw']:.3f}x"))
    d = drift["fedadc_none"]
    rows.append(emit(
        "comm_sweep.drift.fedadc_none", 0,
        f"disp_last={d['delta_dispersion_last']};"
        f"align_last={d['momentum_alignment_last']};"
        f"norm_last={d['update_norm_last']}"))
    base = by[("fedadc", "none")]
    topk = by[("fedadc", "topk10_ef")]
    acc_gap = base["acc"] - topk["acc"]
    reduction = topk["bytes_reduction"]
    rows.append(emit("comm_sweep.fedadc_topk10_vs_uncompressed", 0,
                     f"acc_gap={acc_gap:.4f};bytes_reduction={reduction:.2f}x"))
    down_by = {c["downlink"]: c for c in downlink_cells}
    d_none, d_delta = down_by["down_none"], down_by["down_delta"]
    delta_ratio = d_delta["downlink_vs_uplink_raw"]
    rows.append(emit(
        "comm_sweep.fedadc_delta_downlink_vs_naive", 0,
        f"delta={delta_ratio:.3f}x;naive="
        f"{d_none['downlink_vs_uplink_raw']:.3f}x;"
        f"lossless_acc_equal={d_delta['acc'] == d_none['acc']}"))
    inter = {(c["availability"], c["resync_horizon"]): c
             for c in intermittent_cells}
    i_h4, i_h0 = inter[(0.5, 4)], inter[(0.5, 0)]
    rows.append(emit(
        "comm_sweep.unicast_catchup_vs_resync", 0,
        f"h4_MB={i_h4['downlink_bytes']/2**20:.2f};"
        f"h0_MB={i_h0['downlink_bytes']/2**20:.2f};"
        f"catchup_lt_resync={i_h4['downlink_bytes'] < i_h0['downlink_bytes']};"
        f"acc_equal={i_h4['acc'] == i_h0['acc']}"))
    report = {
        "benchmark": "synthetic non-IID (sorted 2-class shards)",
        "rounds": rounds,
        "async_rounds": async_rounds,
        "intermittent_rounds": intermittent_rounds,
        "cells": cells,
        "async_cells": async_cells,
        "downlink_cells": downlink_cells,
        "intermittent_cells": intermittent_cells,
        # per-round in-jit drift diagnostics (curve endpoints; underscore
        # keys so the CI --require gate can address them as dotted paths)
        "drift": drift,
        "headline": {
            "fedadc_acc_uncompressed": base["acc"],
            "fedadc_acc_topk10_ef": topk["acc"],
            "acc_gap": round(acc_gap, 4),
            "bytes_reduction": reduction,
            "within_2pts": bool(acc_gap <= 0.02),
            "reduction_ge_5x": bool(reduction >= 5.0),
            # measured (not analytic) downlink: FedADC's naive broadcast
            # carries m̄_t, so its wire tree is 2× the parameter bytes ...
            "fedadc_downlink_vs_uplink_raw": round(
                base["downlink_bytes_raw"] / base["uplink_bytes_raw"], 2),
            "downlink_measured": True,
            # ... and the momentum-aware Δm̄ reference-coded broadcast
            # recovers the paper's overlapped ~1× (round 0 pays the full
            # initial sync; every later round ships θ-delta bytes with the
            # derived ctx at 0), bit-lossless vs the plain broadcast
            "fedadc_downlink_delta_vs_uplink_raw": delta_ratio,
            "downlink_delta_le_1p1": bool(delta_ratio <= 1.1),
            "downlink_delta_lossless": bool(
                d_delta["acc"] == d_none["acc"]),
            # intermittent participation: catch-up deltas within the
            # horizon are strictly cheaper than per-revisit full-θ resyncs
            # for the same (accounting-invariant) trajectory
            "unicast_catchup_lt_resync": bool(
                i_h4["downlink_bytes"] < i_h0["downlink_bytes"]
                and i_h4["acc"] == i_h0["acc"]),
        },
    }
    with open(out_json, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {out_json}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI: pin the committed-JSON configuration "
                         "(90 sync / 80 async rounds) regardless of --rounds")
    ap.add_argument("--rounds", type=int, default=90)
    ap.add_argument("--async-rounds", type=int, default=80)
    ap.add_argument("--intermittent-rounds", type=int, default=40)
    ap.add_argument("--out", default="BENCH_comm.json")
    args = ap.parse_args()
    main(rounds=90 if args.smoke else args.rounds,
         async_rounds=80 if args.smoke else args.async_rounds,
         intermittent_rounds=40 if args.smoke
         else args.intermittent_rounds,
         out_json=args.out)
