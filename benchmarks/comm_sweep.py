"""Accuracy-vs-bytes frontier (the measured version of Sec. II-A).

Two sweeps on the synthetic non-IID benchmark (sorted 2-class shards, the
paper's hardest skew):

* **sync** — strategy × uplink codec on the synchronous simulator: final
  accuracy against the *measured* bytes the transport wire formats actually
  carry, in both directions (downlink is the real (θ_t, ctx) broadcast
  tree, measured — not the analytic n·4·clients floor).
* **async** — the ROADMAP-requested ``topk_frac``/``qsgd_bits`` ×
  staleness axis on the semi-async engine: each compression knob runs under
  a bimodal straggler fleet with buffered-K aggregation, with and without
  staleness discounting, so the frontier shows how lossy uplinks compose
  with stale pseudo-gradients (EF mass is conserved across drops).

Headline check (asserted into the JSON, gated in CI): top-k 10% with error
feedback stays within 2 accuracy points of the uncompressed FedADC run
while shrinking measured uplink bytes ≥ 5×.

Emits ``BENCH_comm.json`` plus the repo-standard CSV rows.  The committed
JSON is produced by the default (smoke-scale) configuration so the CI
``bench-smoke`` job can regenerate it deterministically and diff within
tolerance; ``--rounds`` scales the sweep up for real frontier plots.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from benchmarks.common import (HeteroConfig, dataset, emit, partitions,
                               run_fl, run_fl_async)

STRATEGIES = ("fedavg", "slowmo", "fedadc")
COMPRESSORS = (
    ("none", {"compressor": "none"}),
    ("topk10_ef", {"compressor": "topk", "topk_frac": 0.10,
                   "error_feedback": True}),
    ("qsgd4_ef", {"compressor": "qsgd", "qsgd_bits": 4,
                  "error_feedback": True}),
)

# async axis: compression knobs × staleness handling, under stragglers
ASYNC_KNOBS = (
    ("topk5_ef", {"compressor": "topk", "topk_frac": 0.05,
                  "error_feedback": True}),
    ("topk20_ef", {"compressor": "topk", "topk_frac": 0.20,
                   "error_feedback": True}),
    ("qsgd2_ef", {"compressor": "qsgd", "qsgd_bits": 2,
                  "error_feedback": True}),
    ("qsgd8_ef", {"compressor": "qsgd", "qsgd_bits": 8,
                  "error_feedback": True}),
)
ASYNC_STALENESS = (
    ("stale_none", {"buffer_k": 2, "staleness_mode": "none"}),
    ("stale_poly", {"buffer_k": 2, "staleness_mode": "poly",
                    "staleness_factor": 0.5}),
)
ASYNC_HETERO = HeteroConfig(enabled=True, speed_dist="bimodal",
                            straggler_frac=0.25, straggler_slowdown=4.0,
                            seed=0)


def _cell(name_kv, r):
    s = r["sim"]
    cell = dict(name_kv)
    cell.update({
        "acc": round(r["acc"], 4),
        "uplink_bytes": int(s.uplink_bytes),
        "uplink_bytes_raw": int(s.uplink_bytes_raw),
        "downlink_bytes": int(s.downlink_bytes),
        "downlink_bytes_raw": int(s.downlink_bytes_raw),
        "bytes_reduction": round(s.uplink_bytes_raw / s.uplink_bytes, 2),
        "us_per_round": r["us_per_round"],
    })
    return cell


def sweep(rounds=90, n_clients=20, seed=0):
    data = dataset()
    parts = partitions(data[1], n_clients, "sort", 2, seed=seed)
    cells = []
    for strat in STRATEGIES:
        for cname, extra in COMPRESSORS:
            r = run_fl(strat, parts, data, rounds=rounds,
                       n_clients=n_clients, seed=seed, extra_fed=extra)
            cells.append(_cell({"strategy": strat, "compressor": cname}, r))
    return cells


def async_sweep(rounds=80, n_clients=20, seed=0):
    data = dataset()
    parts = partitions(data[1], n_clients, "sort", 2, seed=seed)
    cells = []
    for cname, comp in ASYNC_KNOBS:
        for sname, stale in ASYNC_STALENESS:
            extra = dict(comp)
            extra.update(stale)
            r = run_fl_async("fedadc", parts, data, hetero=ASYNC_HETERO,
                             rounds=rounds, n_clients=n_clients, seed=seed,
                             extra_fed=extra)
            cell = _cell({"compressor": cname, "staleness": sname}, r)
            cell["mean_staleness"] = round(
                float(np.mean(r["sim"].staleness_seen)), 3)
            cells.append(cell)
    return cells


def main(rows=None, rounds=90, async_rounds=80, out_json="BENCH_comm.json"):
    rows = rows if rows is not None else []
    cells = sweep(rounds=rounds)
    by = {(c["strategy"], c["compressor"]): c for c in cells}
    for c in cells:
        rows.append(emit(
            f"comm_sweep.{c['strategy']}.{c['compressor']}",
            c["us_per_round"],
            f"acc={c['acc']};up_MB={c['uplink_bytes']/2**20:.2f};"
            f"down_MB={c['downlink_bytes']/2**20:.2f};"
            f"reduction={c['bytes_reduction']:.2f}x"))
    async_cells = async_sweep(rounds=async_rounds)
    for c in async_cells:
        rows.append(emit(
            f"comm_sweep.async.fedadc.{c['compressor']}.{c['staleness']}",
            c["us_per_round"],
            f"acc={c['acc']};up_MB={c['uplink_bytes']/2**20:.2f};"
            f"stale={c['mean_staleness']:.2f};"
            f"reduction={c['bytes_reduction']:.2f}x"))
    base = by[("fedadc", "none")]
    topk = by[("fedadc", "topk10_ef")]
    acc_gap = base["acc"] - topk["acc"]
    reduction = topk["bytes_reduction"]
    rows.append(emit("comm_sweep.fedadc_topk10_vs_uncompressed", 0,
                     f"acc_gap={acc_gap:.4f};bytes_reduction={reduction:.2f}x"))
    report = {
        "benchmark": "synthetic non-IID (sorted 2-class shards)",
        "rounds": rounds,
        "async_rounds": async_rounds,
        "cells": cells,
        "async_cells": async_cells,
        "headline": {
            "fedadc_acc_uncompressed": base["acc"],
            "fedadc_acc_topk10_ef": topk["acc"],
            "acc_gap": round(acc_gap, 4),
            "bytes_reduction": reduction,
            "within_2pts": bool(acc_gap <= 0.02),
            "reduction_ge_5x": bool(reduction >= 5.0),
            # measured (not analytic) downlink: FedADC's broadcast carries
            # m̄_t, so its wire tree is 2× the parameter bytes
            "fedadc_downlink_vs_uplink_raw": round(
                base["downlink_bytes_raw"] / base["uplink_bytes_raw"], 2),
            "downlink_measured": True,
        },
    }
    with open(out_json, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {out_json}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI: pin the committed-JSON configuration "
                         "(90 sync / 80 async rounds) regardless of --rounds")
    ap.add_argument("--rounds", type=int, default=90)
    ap.add_argument("--async-rounds", type=int, default=80)
    ap.add_argument("--out", default="BENCH_comm.json")
    args = ap.parse_args()
    main(rounds=90 if args.smoke else args.rounds,
         async_rounds=80 if args.smoke else args.async_rounds,
         out_json=args.out)
