"""Accuracy-vs-uplink-bytes frontier (the measured version of Sec. II-A).

Sweeps strategy × compressor on the synthetic non-IID benchmark (sorted
2-class shards, the paper's hardest skew) and reports, per cell, the final
accuracy together with the *measured* uplink bytes the compression wire
formats actually transport — turning the paper's analytic comm-load table
into an accuracy/bandwidth trade-off.

Headline check (asserted into the JSON, gated in CI): top-k 10% with error
feedback stays within 2 accuracy points of the uncompressed FedADC run
while shrinking measured uplink bytes ≥ 5×.

Emits ``BENCH_comm.json`` plus the repo-standard CSV rows.  The committed
JSON is produced by the default (smoke-scale) configuration so the CI
``bench-smoke`` job can regenerate it deterministically and diff within
tolerance; ``--rounds`` scales the sweep up for real frontier plots.
"""
from __future__ import annotations

import argparse
import json

from benchmarks.common import dataset, emit, partitions, run_fl

STRATEGIES = ("fedavg", "slowmo", "fedadc")
COMPRESSORS = (
    ("none", {"compressor": "none"}),
    ("topk10_ef", {"compressor": "topk", "topk_frac": 0.10,
                   "error_feedback": True}),
    ("qsgd4_ef", {"compressor": "qsgd", "qsgd_bits": 4,
                  "error_feedback": True}),
)


def sweep(rounds=90, n_clients=20, seed=0):
    data = dataset()
    parts = partitions(data[1], n_clients, "sort", 2, seed=seed)
    cells = []
    for strat in STRATEGIES:
        for cname, extra in COMPRESSORS:
            r = run_fl(strat, parts, data, rounds=rounds,
                       n_clients=n_clients, seed=seed, extra_fed=extra)
            s = r["sim"]
            cells.append({
                "strategy": strat,
                "compressor": cname,
                "acc": round(r["acc"], 4),
                "uplink_bytes": int(s.uplink_bytes),
                "uplink_bytes_raw": int(s.uplink_bytes_raw),
                "bytes_reduction": round(
                    s.uplink_bytes_raw / s.uplink_bytes, 2),
                "us_per_round": r["us_per_round"],
            })
    return cells


def main(rows=None, rounds=90, out_json="BENCH_comm.json"):
    rows = rows if rows is not None else []
    cells = sweep(rounds=rounds)
    by = {(c["strategy"], c["compressor"]): c for c in cells}
    for c in cells:
        rows.append(emit(
            f"comm_sweep.{c['strategy']}.{c['compressor']}",
            c["us_per_round"],
            f"acc={c['acc']};up_MB={c['uplink_bytes']/2**20:.2f};"
            f"reduction={c['bytes_reduction']:.2f}x"))
    base = by[("fedadc", "none")]
    topk = by[("fedadc", "topk10_ef")]
    acc_gap = base["acc"] - topk["acc"]
    reduction = topk["bytes_reduction"]
    rows.append(emit("comm_sweep.fedadc_topk10_vs_uncompressed", 0,
                     f"acc_gap={acc_gap:.4f};bytes_reduction={reduction:.2f}x"))
    report = {
        "benchmark": "synthetic non-IID (sorted 2-class shards)",
        "rounds": rounds,
        "cells": cells,
        "headline": {
            "fedadc_acc_uncompressed": base["acc"],
            "fedadc_acc_topk10_ef": topk["acc"],
            "acc_gap": round(acc_gap, 4),
            "bytes_reduction": reduction,
            "within_2pts": bool(acc_gap <= 0.02),
            "reduction_ge_5x": bool(reduction >= 5.0),
        },
    }
    with open(out_json, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {out_json}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI: pin the committed-JSON configuration "
                         "(90 rounds) regardless of --rounds")
    ap.add_argument("--rounds", type=int, default=90)
    ap.add_argument("--out", default="BENCH_comm.json")
    args = ap.parse_args()
    main(rounds=90 if args.smoke else args.rounds, out_json=args.out)
