"""Aggregate the dry-run roofline baselines (results/baseline_*.jsonl) into
the §Roofline table: three terms, dominant bottleneck, MODEL_FLOPS ratio."""
import json
import os

from benchmarks.common import emit

RESULTS = [("singlepod", "results/baseline_singlepod.jsonl"),
           ("multipod", "results/baseline_multipod.jsonl")]


def load(path):
    if not os.path.exists(path):
        return []
    rows = []
    seen = {}
    for line in open(path):
        r = json.loads(line)
        seen[(r["arch"], r["shape"])] = r     # last write wins
    return list(seen.values())


def main(rows=None):
    rows = rows if rows is not None else []
    for mesh_name, path in RESULTS:
        for r in load(path):
            key = f"roofline.{mesh_name}.{r['arch']}.{r['shape']}"
            if r["status"] != "ok":
                rows.append(emit(key, 0, f"status={r['status']}"))
                continue
            dom = r["dominant"]
            derived = (f"dom={dom};compute_s={r['compute_s']:.3g};"
                       f"memory_s={r['memory_s']:.3g};"
                       f"collective_s={r['collective_s']:.3g};"
                       f"hbm_gb={r['per_device_hbm_gb']:.2f};"
                       f"useful={min(r.get('useful_flop_frac', 0), 99):.2f}")
            rows.append(emit(key, r["t_compile_s"] * 1e6, derived))
    return rows


if __name__ == "__main__":
    main()
