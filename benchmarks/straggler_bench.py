"""Straggler bench: simulated wall-clock-to-accuracy, synchronous vs
semi-async FedADC under a 4× straggler fleet (DESIGN.md §Heterogeneity).

The synchronous engine barriers every round on the slowest selected client,
so a 25% population of 4×-slower stragglers inflates round time ~4× whenever
one is sampled; the semi-async engine flushes the fastest buffer_k deltas and
lets stragglers arrive late with staleness-discounted momentum.  Reported:
virtual time (units = local steps on the reference client) to reach the
target accuracy, and final accuracy.

CSV rows reuse the ``name,us_per_call,derived`` format with the middle
column holding raw virtual time and `derived` the final accuracy.
"""
from __future__ import annotations

from benchmarks.common import dataset, emit, partitions
from repro.configs.base import FedConfig, HeteroConfig
from repro.federated.async_engine import AsyncFederatedSimulator
from repro.federated.simulator import SimConfig

TARGET_ACC = 0.30
STRAGGLERS = HeteroConfig(enabled=True, speed_dist="bimodal",
                          straggler_frac=0.25, straggler_slowdown=4.0,
                          seed=0)


def run_mode(data, parts, *, buffer_k, rounds, eval_every=2):
    x, y, xt, yt = data
    # both modes keep the same fleet of 8 clients in flight; sync barriers
    # on all 8, semi-async flushes on the fastest 4
    fed = FedConfig(strategy="fedadc", local_steps=8, clients_per_round=8,
                    n_clients=20, eta=0.02, beta_global=0.7, beta_local=0.7,
                    buffer_k=buffer_k, staleness_mode="poly",
                    staleness_factor=0.5)
    sim = SimConfig(model="cnn", n_classes=10, batch_size=32, rounds=rounds,
                    eval_every=eval_every, cnn_width=8, seed=0)
    eng = AsyncFederatedSimulator(fed, sim, STRAGGLERS, x, y, xt, yt, parts)
    hist = eng.run()
    t_target = next((h["t"] for h in hist if h["acc"] >= TARGET_ACC),
                    float("inf"))
    return hist, t_target, eng


def main(rows=None):
    rows = rows if rows is not None else []
    data = dataset()
    parts = partitions(data[1], 20, "sort", 2)
    # synchronous barrier: buffer_k == clients_per_round
    h_sync, t_sync, _ = run_mode(data, parts, buffer_k=0, rounds=20)
    # semi-async: flush on the fastest half of the wave
    h_semi, t_semi, eng = run_mode(data, parts, buffer_k=4, rounds=60)
    rows.append(emit("straggler.sync.t_to_target", t_sync,
                     f"{h_sync[-1]['acc']:.3f}"))
    rows.append(emit("straggler.semi.t_to_target", t_semi,
                     f"{h_semi[-1]['acc']:.3f}"))
    speedup = t_sync / t_semi if t_semi > 0 else float("nan")
    rows.append(emit("straggler.semi_vs_sync_speedup", 0, f"{speedup:.2f}x"))
    rows.append(emit("straggler.semi.max_staleness", 0,
                     eng.staleness_hist.max))
    return rows


if __name__ == "__main__":
    main()
