"""Fig. 7: personalization via classifier calibration on top of FedADC+ —
per-client local test accuracy vs the global model, with none/prox/KD head
regularisers (paper: +3.3 – 4.1%)."""
import jax.numpy as jnp
import numpy as np

from benchmarks.common import dataset, emit, partitions, run_fl
from repro.core.personalization import calibrate_head
from repro.data.partition import class_counts

ROUNDS = 50


def main(rows=None):
    data = dataset()
    x, y, xt, yt = data
    rows = rows if rows is not None else []
    # fewer rounds + stronger skew: the paper's personalization gain needs
    # a global model with per-client headroom (its CIFAR-100 global ~45%)
    parts = partitions(y, 20, "dir", 0.1)
    r = run_fl("fedadc", parts, data, rounds=20, eta=0.01, distill=True)
    simr = r["sim"]
    counts = class_counts(y, parts, 10)

    # per-client local test split: sample test indices matching client's
    # class distribution
    rng = np.random.RandomState(0)
    global_accs, pers_accs = {reg: [] for reg in ("none", "prox", "kd")}, {}
    pers_accs = {reg: [] for reg in ("none", "prox", "kd")}
    gaccs = []
    for ci, p in enumerate(parts[:10]):
        classes = np.unique(y[p])
        te_mask = np.isin(yt, classes)
        xte, yte = xt[te_mask], yt[te_mask]
        if len(xte) == 0:
            continue
        logits = simr.apply(simr.params, jnp.asarray(xte))
        gaccs.append(float(jnp.mean(jnp.argmax(logits, -1)
                                    == jnp.asarray(yte))))
        for reg in ("none", "prox", "kd"):
            pp = calibrate_head(simr.params, simr.apply, "head",
                                x[p], y[p], jnp.asarray(counts[ci]),
                                steps=60, batch_size=32, eta=0.05, reg=reg)
            logits = simr.apply(pp, jnp.asarray(xte))
            pers_accs[reg].append(float(jnp.mean(
                jnp.argmax(logits, -1) == jnp.asarray(yte))))
    g = float(np.mean(gaccs))
    rows.append(emit("fig7.global_model_local_acc", r["us_per_round"],
                     f"{g:.3f}"))
    for reg in ("none", "prox", "kd"):
        pa = float(np.mean(pers_accs[reg]))
        rows.append(emit(f"fig7.personalized.{reg}", 0, f"{pa:.3f}"))
        rows.append(emit(f"fig7.gain.{reg}", 0, f"{pa - g:+.3f}"))
    return rows


if __name__ == "__main__":
    main()
