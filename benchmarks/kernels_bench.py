"""Kernel micro-benchmarks: wall-µs per call (CPU interpret mode — the
numbers gauge dispatch overhead, not TPU perf) plus DERIVED analytic
bytes-moved / FLOPs per call, which are the hardware-independent terms the
roofline uses.

The ``sparse_aggregate`` section sweeps ``topk_frac`` and emits
``BENCH_kernels.json`` for the CI bench-smoke gate: the analytic
aggregate-FLOPs cells are deterministic (regression-checked within
tolerance and ``--require``-pinned), while the measured µs/speedup fields
ride wall-clock-named keys the gate's walk skips.  Both sides of the
speedup are the jnp reference paths (what the engines run on CPU, where
Pallas is interpret-mode) — dense-decode reconstructs all K clients then
reduces at K·d cost, sparse-native segment-sums the wire at K·k."""
import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels import ops
from repro.kernels import ref as kref

TOPK_FRACS = (0.01, 0.05, 0.1, 0.25)


def _time(fn, *args, iters=5):
    fn(*args)                                  # compile/warm
    t0 = time.time()
    for _ in range(iters):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.time() - t0) / iters * 1e6


def sparse_aggregate_section(rows, K=16, d=1 << 20, seed=0):
    """Server-aggregate cost vs top-k fraction: K clients' (values,
    indices) wires summed into one dense (d,) leaf.  The aggregate FLOPs
    are measured from the actual wire shapes the encode produced — 2·K·d
    multiply-adds for dense-decode (every reconstructed element enters the
    reduction), 2·K·k for sparse-native — so the work ratio is 1/frac and
    ``ge_4x_at_0p1`` (the CI-gated bool) asserts the sparse aggregate does
    ≥4× less aggregation work at topk_frac=0.1.  Wall-clock µs/speedup
    ride SKIP_KEY-named fields: on this CPU both paths bottleneck on
    XLA's serial scatter (dense-decode scatters the same K·k elements to
    reconstruct before it reduces), which compresses the wall ratio at
    large frac — the wall ratio recovers as frac shrinks (largest at
    0.01), and on TPU the Pallas kernel keeps the k-scaling at every
    frac."""
    rng = np.random.RandomState(seed)
    w = jnp.asarray(rng.uniform(0.2, 1.0, K).astype(np.float32))

    @jax.jit
    def dense_decode_agg(values, indices):
        dense = jax.vmap(
            lambda v, i: jnp.zeros((d,), v.dtype).at[i].set(v))(
                values, indices)
        return kref.weighted_delta_reduce(dense, w)

    def sparse_agg(values, indices):
        return kref.sparse_weighted_delta_reduce(values, indices, w,
                                                 (d,), jnp.float32)

    cells = []
    for frac in TOPK_FRACS:
        k = int(np.ceil(frac * d))
        values = jnp.asarray(rng.randn(K, k).astype(np.float32))
        indices = jnp.asarray(
            np.stack([rng.choice(d, size=k, replace=False)
                      for _ in range(K)]).astype(np.int32))
        us_dense = _time(dense_decode_agg, values, indices)
        us_sparse = _time(sparse_agg, values, indices)
        cell = {
            "topk_frac": frac,
            "k": k,
            "flops_dense": 2 * K * d,
            "flops_sparse": 2 * K * k,
            "flops_ratio": round(2 * K * d / (2 * K * k), 2),
            "us_dense": round(us_dense, 1),
            "us_sparse": round(us_sparse, 1),
            "speedup": round(us_dense / us_sparse, 2),
        }
        cells.append(cell)
        rows.append(emit(f"kernel.sparse_aggregate.K{K}.frac{frac}",
                         us_sparse,
                         f"speedup={cell['speedup']};"
                         f"flops_ratio={cell['flops_ratio']}"))
    at_0p1 = next(c for c in cells if c["topk_frac"] == 0.1)
    return {
        "K": K,
        "d": d,
        "cells": cells,
        # sparse FLOPs grow with k while dense-decode's stay pinned at K·d
        "flops_scale_with_k": bool(
            all(c["flops_sparse"] == 2 * K * c["k"]
                and c["flops_dense"] == 2 * K * d for c in cells)
            and all(a["flops_sparse"] < b["flops_sparse"]
                    for a, b in zip(cells, cells[1:]))),
        "ge_4x_at_0p1": bool(at_0p1["flops_ratio"] >= 4.0),
        "speedup_at_0p1": at_0p1["speedup"],
    }


def main(rows=None, out_json="BENCH_kernels.json"):
    rows = rows if rows is not None else []
    # fused local update: 3 reads + 1 write vs 4 reads + 2 writes unfused
    n = 1 << 20
    theta = {"p": jnp.ones((n,), jnp.float32)}
    g = {"p": jnp.full((n,), 0.1, jnp.float32)}
    m = {"p": jnp.full((n,), 0.01, jnp.float32)}
    us = _time(jax.jit(lambda t, gg, mm: ops.fedadc_local_update(
        t, gg, mm, 0.05)), theta, g, m)
    moved = 4 * n * 4
    rows.append(emit("kernel.fedadc_local_update.1M", us,
                     f"bytes_moved={moved};vs_unfused={6*n*4}"))

    us = _time(jax.jit(lambda t, mm, d: ops.fedadc_server_update(
        t, mm, d, 0.1, 0.05)), theta, m, g)
    rows.append(emit("kernel.fedadc_server_update.1M", us,
                     f"bytes_moved={5*n*4};vs_unfused={8*n*4}"))

    # weighted-delta-reduce: K+1 vectors moved vs 2K+1 unfused (broadcast
    # product materialised)
    K = 8
    stacked = {"p": jnp.ones((K, n), jnp.float32)}
    w = jnp.full((K,), 1.0 / K)
    us = _time(jax.jit(lambda d, ww: ops.weighted_delta_reduce(d, ww)),
               stacked, w)
    rows.append(emit(f"kernel.weighted_delta_reduce.K{K}.1M", us,
                     f"bytes_moved={(K+1)*n*4};vs_unfused={(2*K+1)*n*4}"))

    # flash attention 1×4×512×64
    B, H, L, D = 1, 4, 512, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, L, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, L, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, L, H, D), jnp.float32)
    us = _time(jax.jit(lambda a, b, c: ops.flash_attention(a, b, c)), q, k, v)
    flops = 4 * B * H * L * L * D
    rows.append(emit("kernel.flash_attention.512", us, f"flops={flops}"))

    # ssd scan
    b, Lq, Hh, P, N = 1, 512, 4, 64, 64
    x = jax.random.normal(ks[0], (b, Lq, Hh, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, Lq, Hh)))
    A_log = jnp.zeros((Hh,))
    Bm = jax.random.normal(ks[2], (b, Lq, Hh, N))
    Cm = jax.random.normal(ks[0], (b, Lq, Hh, N))
    Dv = jnp.ones((Hh,))
    us = _time(jax.jit(lambda *a: ops.ssd_scan(*a, chunk=128)),
               x, dt, A_log, Bm, Cm, Dv)
    chunk = 128
    nc = Lq // chunk
    intra = b * Hh * nc * (2 * chunk * chunk * N + 2 * chunk * chunk * P)
    rows.append(emit("kernel.ssd_scan.512", us, f"flops~={intra}"))

    # kd loss
    Bb, C = 256, 1000
    s = jax.random.normal(ks[0], (Bb, C))
    t = jax.random.normal(ks[1], (Bb, C))
    y = jax.random.randint(ks[2], (Bb,), 0, C)
    rho = jax.random.uniform(ks[0], (C,))
    us = _time(jax.jit(lambda *a: ops.kd_loss(*a, 0.35, 1.0)), s, t, y, rho)
    rows.append(emit("kernel.kd_loss.256x1000", us,
                     f"bytes_fused={2*Bb*C*4};vs_unfused~={5*2*Bb*C*4}"))

    report = {"sparse_aggregate": sparse_aggregate_section(rows)}
    with open(out_json, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {out_json}")
    assert report["sparse_aggregate"]["ge_4x_at_0p1"], (
        "sparse-native aggregate no longer does ≥4× less aggregation "
        "work than dense-decode at topk_frac=0.1")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_kernels.json")
    args = ap.parse_args()
    main(out_json=args.out)
