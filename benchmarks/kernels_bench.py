"""Kernel micro-benchmarks: wall-µs per call (CPU interpret mode — the
numbers gauge dispatch overhead, not TPU perf) plus DERIVED analytic
bytes-moved / FLOPs per call, which are the hardware-independent terms the
roofline uses."""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.kernels import ops


def _time(fn, *args, iters=5):
    fn(*args)                                  # compile/warm
    t0 = time.time()
    for _ in range(iters):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.time() - t0) / iters * 1e6


def main(rows=None):
    rows = rows if rows is not None else []
    # fused local update: 3 reads + 1 write vs 4 reads + 2 writes unfused
    n = 1 << 20
    theta = {"p": jnp.ones((n,), jnp.float32)}
    g = {"p": jnp.full((n,), 0.1, jnp.float32)}
    m = {"p": jnp.full((n,), 0.01, jnp.float32)}
    us = _time(jax.jit(lambda t, gg, mm: ops.fedadc_local_update(
        t, gg, mm, 0.05)), theta, g, m)
    moved = 4 * n * 4
    rows.append(emit("kernel.fedadc_local_update.1M", us,
                     f"bytes_moved={moved};vs_unfused={6*n*4}"))

    us = _time(jax.jit(lambda t, mm, d: ops.fedadc_server_update(
        t, mm, d, 0.1, 0.05)), theta, m, g)
    rows.append(emit("kernel.fedadc_server_update.1M", us,
                     f"bytes_moved={5*n*4};vs_unfused={8*n*4}"))

    # weighted-delta-reduce: K+1 vectors moved vs 2K+1 unfused (broadcast
    # product materialised)
    K = 8
    stacked = {"p": jnp.ones((K, n), jnp.float32)}
    w = jnp.full((K,), 1.0 / K)
    us = _time(jax.jit(lambda d, ww: ops.weighted_delta_reduce(d, ww)),
               stacked, w)
    rows.append(emit(f"kernel.weighted_delta_reduce.K{K}.1M", us,
                     f"bytes_moved={(K+1)*n*4};vs_unfused={(2*K+1)*n*4}"))

    # flash attention 1×4×512×64
    B, H, L, D = 1, 4, 512, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, L, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, L, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, L, H, D), jnp.float32)
    us = _time(jax.jit(lambda a, b, c: ops.flash_attention(a, b, c)), q, k, v)
    flops = 4 * B * H * L * L * D
    rows.append(emit("kernel.flash_attention.512", us, f"flops={flops}"))

    # ssd scan
    b, Lq, Hh, P, N = 1, 512, 4, 64, 64
    x = jax.random.normal(ks[0], (b, Lq, Hh, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, Lq, Hh)))
    A_log = jnp.zeros((Hh,))
    Bm = jax.random.normal(ks[2], (b, Lq, Hh, N))
    Cm = jax.random.normal(ks[0], (b, Lq, Hh, N))
    Dv = jnp.ones((Hh,))
    us = _time(jax.jit(lambda *a: ops.ssd_scan(*a, chunk=128)),
               x, dt, A_log, Bm, Cm, Dv)
    chunk = 128
    nc = Lq // chunk
    intra = b * Hh * nc * (2 * chunk * chunk * N + 2 * chunk * chunk * P)
    rows.append(emit("kernel.ssd_scan.512", us, f"flops~={intra}"))

    # kd loss
    Bb, C = 256, 1000
    s = jax.random.normal(ks[0], (Bb, C))
    t = jax.random.normal(ks[1], (Bb, C))
    y = jax.random.randint(ks[2], (Bb,), 0, C)
    rho = jax.random.uniform(ks[0], (C,))
    us = _time(jax.jit(lambda *a: ops.kd_loss(*a, 0.35, 1.0)), s, t, y, rho)
    rows.append(emit("kernel.kd_loss.256x1000", us,
                     f"bytes_fused={2*Bb*C*4};vs_unfused~={5*2*Bb*C*4}"))
    return rows


if __name__ == "__main__":
    main()
