"""Paper Sec. II-A (communication load): FedADC's uplink equals FedAvg's;
the downlink additionally carries the momentum/model-difference broadcast
(2× naive, 1× when Δ̄-broadcast overlaps compute as the paper proposes).

Two tables per architecture, side by side:

* **analytic** — the paper's own bytes/round accounting (n_params × dtype
  bytes × clients), per strategy.
* **measured** — what the compression subsystem would actually put on the
  wire per client upload, from the real parameter pytree of the arch
  (``jax.eval_shape``, no allocation) through each compressor's exact wire
  format (repro.federated.compression.wire_nbytes).

The measured column is what ``benchmarks/comm_sweep.py`` trades against
accuracy; here it is reported against the analytic floor so the two
accountings can be compared at a glance.
"""
import jax

from benchmarks.common import emit
from repro.configs import ARCHS
from repro.federated import compression as C
from repro.models.registry import get_model


def bytes_per_round(n_params, clients, dtype_bytes=4):
    p = n_params * dtype_bytes
    return {
        # uplink: every selected client pushes Δ_i
        "fedavg":        {"up": clients * p, "down": clients * p},
        "slowmo":        {"up": clients * p, "down": clients * p},
        # naive FedADC: pull θ_t AND m_t
        "fedadc_naive":  {"up": clients * p, "down": clients * 2 * p},
        # overlapped (paper): S_{t+1} pre-receives (θ_t, m_t) during round t
        # compute; at t+1 only Δ̄_t is pulled on the critical path
        "fedadc_overlap": {"up": clients * p, "down": clients * p},
    }


def param_shapes(arch: str):
    """Parameter pytree of the arch as ShapeDtypeStructs (no allocation)."""
    mcfg = ARCHS[arch]
    model = get_model(mcfg)
    return jax.eval_shape(lambda r: model.init(r, mcfg),
                          jax.random.PRNGKey(0))


MEASURED = (
    ("raw", None),
    ("topk10", C.TopKCompressor(0.10)),
    ("qsgd4", C.QSGDCompressor(4)),
    ("qsgd8", C.QSGDCompressor(8)),
)


def main(rows=None):
    rows = rows if rows is not None else []
    for arch in ("qwen3-4b", "qwen3-14b"):
        n = ARCHS[arch].param_count()
        table = bytes_per_round(n, clients=4)
        base = table["fedavg"]["down"]
        for strat, t in table.items():
            rows.append(emit(
                f"comm.{arch}.{strat}", 0,
                f"up_GB={t['up']/2**30:.2f};down_GB={t['down']/2**30:.2f};"
                f"down_vs_fedavg={t['down']/base:.2f}x"))
        # measured per-client upload bytes through the compression wire
        # formats, against the analytic raw uplink as the reference
        shapes = param_shapes(arch)
        raw = C.raw_nbytes(shapes)
        analytic_up = n * 4
        for name, comp in MEASURED:
            b = raw if comp is None else comp.wire_nbytes(shapes)
            rows.append(emit(
                f"comm.{arch}.measured.{name}", 0,
                f"up_GB_per_client={b/2**30:.3f};"
                f"vs_analytic={b/analytic_up:.3f}x;"
                f"vs_raw={raw/b:.2f}x_smaller"))
    return rows


if __name__ == "__main__":
    main()
