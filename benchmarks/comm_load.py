"""Paper Sec. II-A (communication load): FedADC's uplink equals FedAvg's;
the downlink additionally carries the momentum/model-difference broadcast
(2× naive, 1× when Δ̄-broadcast overlaps compute as the paper proposes).

Two accountings per architecture, side by side:

* **analytic** — the paper's own bytes/round table (n_params × dtype bytes
  × clients), per strategy.
* **measured** — what the transport layer actually puts on the wire, in
  BOTH directions, from the real parameter pytree of the arch
  (``jax.eval_shape``, no allocation):

  - uplink: each compressor codec's exact wire format
    (``Transport.uplink_wire_nbytes``);
  - downlink: the (θ_t, ctx) broadcast tree the strategy really ships —
    FedADC's ctx carries m̄_t, so its measured naive downlink is 2× the
    parameter bytes *by construction of the wire tree*, not by analytic
    assumption — under the pluggable downlink codecs.

The measured numbers are what ``benchmarks/comm_sweep.py`` trades against
accuracy; here they are reported against the analytic floor so the two
accountings can be compared at a glance.
"""
import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import ARCHS
from repro.configs.base import FedConfig
from repro.core.strategies import get_strategy
from repro.federated import compression as C
from repro.federated.reference import ReferenceStore
from repro.federated.transport import Transport
from repro.models.registry import get_model


def bytes_per_round(n_params, clients, dtype_bytes=4):
    p = n_params * dtype_bytes
    return {
        # uplink: every selected client pushes Δ_i
        "fedavg":        {"up": clients * p, "down": clients * p},
        "slowmo":        {"up": clients * p, "down": clients * p},
        # naive FedADC: pull θ_t AND m_t
        "fedadc_naive":  {"up": clients * p, "down": clients * 2 * p},
        # overlapped (paper): S_{t+1} pre-receives (θ_t, m_t) during round t
        # compute; at t+1 only Δ̄_t is pulled on the critical path
        "fedadc_overlap": {"up": clients * p, "down": clients * p},
    }


def param_shapes(arch: str):
    """Parameter pytree of the arch as ShapeDtypeStructs (no allocation)."""
    mcfg = ARCHS[arch]
    model = get_model(mcfg)
    return jax.eval_shape(lambda r: model.init(r, mcfg),
                          jax.random.PRNGKey(0))


def broadcast_template(strategy_name: str, shapes, fed: FedConfig):
    """The (θ_t, ctx) downlink wire tree as ShapeDtypeStructs — ctx is what
    ``strategy.client_setup`` really broadcasts (m̄_t for FedADC, θ_t for
    FedProx, nothing for FedAvg)."""
    s = get_strategy(strategy_name)
    server = jax.eval_shape(s.server_init, shapes)
    ctx = jax.eval_shape(lambda ss, p: s.client_setup(ss, p, fed),
                         server, shapes)
    return (shapes, ctx)


UPLINK = (
    ("raw", {}),
    ("topk10", {"compressor": "topk", "topk_frac": 0.10}),
    ("qsgd4", {"compressor": "qsgd", "qsgd_bits": 4}),
    ("qsgd8", {"compressor": "qsgd", "qsgd_bits": 8}),
)
DOWNLINK = (
    ("raw", {}),
    ("topk10", {"downlink_compressor": "topk", "downlink_topk_frac": 0.10}),
    ("qsgd8", {"downlink_compressor": "qsgd", "downlink_qsgd_bits": 8}),
    # momentum-aware reference-coded broadcast: steady-state bytes are the
    # θ-delta through the inner codec; a derivable ctx (FedADC's m̄) is 0
    ("delta", {"downlink_compressor": "delta"}),
    ("delta_topk10", {"downlink_compressor": "delta+topk",
                      "downlink_topk_frac": 0.10}),
    ("delta_qsgd8", {"downlink_compressor": "delta+qsgd",
                     "downlink_qsgd_bits": 8}),
)


def _unicast_totals(fed: FedConfig, tpl, schedule):
    """Accounting-only replay of a participation schedule through the
    unicast ReferenceStore (no training): round v dispatches schedule[v],
    each client classified fresh/catch-up/resync against its last version."""
    t = Transport(fed)
    t.set_wire_templates(tpl[0], tpl)
    refs = ReferenceStore(fed, t)
    for v, clients in enumerate(schedule):
        refs.dispatch(clients, v)
    return t.downlink_bytes, int(refs.catchups), int(refs.resyncs)


def _multicast_totals(fed: FedConfig, tpl, schedule):
    t = Transport(fed)
    t.set_wire_templates(tpl[0], tpl)
    for v, clients in enumerate(schedule):
        t.account_downlink(len(clients), resync=(v == 0))
    return t.downlink_bytes


def unicast_rows(rows, arch: str, shapes, rounds=12, n_clients=8):
    """Unicast vs multicast downlink bytes side by side, per (lossless
    delta) codec spelling: under full participation the per-client
    schedule degenerates to the multicast one byte-for-byte; under
    intermittent participation the catch-up horizon is what separates
    cheap chained deltas from full-θ resyncs."""
    full = [list(range(n_clients))] * rounds
    rng = np.random.RandomState(0)
    intermittent = [[c for c in range(n_clients) if rng.rand() < 0.5]
                    for _ in range(rounds)]
    for codec in ("delta", "delta+identity"):
        for h in (4, 0):
            fed = FedConfig(strategy="fedadc", downlink_compressor=codec,
                            downlink_unicast=True, resync_horizon=h,
                            n_clients=n_clients)
            tpl = broadcast_template("fedadc", shapes, fed)
            mcast = _multicast_totals(fed, tpl, full)
            ucast, _, _ = _unicast_totals(fed, tpl, full)
            ib, cu, rs = _unicast_totals(fed, tpl, intermittent)
            rows.append(emit(
                f"comm.{arch}.unicast.{codec.replace('+', '_')}.h{h}", 0,
                f"full_unicast_GB={ucast/2**30:.3f};"
                f"full_multicast_GB={mcast/2**30:.3f};"
                f"full_eq_multicast={ucast == mcast};"
                f"intermittent_GB={ib/2**30:.3f};"
                f"catchups={cu};resyncs={rs}"))
    return rows


def main(rows=None):
    rows = rows if rows is not None else []
    for arch in ("qwen3-4b", "qwen3-14b"):
        n = ARCHS[arch].param_count()
        table = bytes_per_round(n, clients=4)
        base = table["fedavg"]["down"]
        for strat, t in table.items():
            rows.append(emit(
                f"comm.{arch}.{strat}", 0,
                f"up_GB={t['up']/2**30:.2f};down_GB={t['down']/2**30:.2f};"
                f"down_vs_fedavg={t['down']/base:.2f}x"))
        shapes = param_shapes(arch)
        raw = C.raw_nbytes(shapes)
        analytic_up = n * 4
        # measured per-client uplink bytes through each codec's wire format
        for name, kw in UPLINK:
            b = Transport(FedConfig(**kw)).uplink_wire_nbytes(shapes)
            rows.append(emit(
                f"comm.{arch}.measured.up.{name}", 0,
                f"up_GB_per_client={b/2**30:.3f};"
                f"vs_analytic={b/analytic_up:.3f}x;"
                f"vs_raw={raw/b:.2f}x_smaller"))
        # measured per-client downlink bytes: the real (θ_t, ctx) broadcast
        # tree per strategy × downlink codec — fedadc's naive 2× shows up
        # because its wire tree carries m̄_t, not because we multiplied by 2
        for strat in ("fedavg", "slowmo", "fedadc"):
            for name, kw in DOWNLINK:
                fed = FedConfig(strategy=strat, **kw)
                tpl = broadcast_template(strat, shapes, fed)
                b = Transport(fed).downlink_wire_nbytes(tpl)
                rows.append(emit(
                    f"comm.{arch}.measured.down.{strat}.{name}", 0,
                    f"down_GB_per_client={b/2**30:.3f};"
                    f"vs_raw_params={b/raw:.2f}x"))
        # the headline the ROADMAP asked for: FedADC's Δm̄-coded broadcast
        # back at ~1× raw θ (naive wire: 2×, because the tree carries m̄_t)
        fed = FedConfig(strategy="fedadc", downlink_compressor="delta")
        tpl = broadcast_template("fedadc", shapes, fed)
        b = Transport(fed).downlink_wire_nbytes(tpl)
        naive = Transport(FedConfig(strategy="fedadc")
                          ).downlink_wire_nbytes(tpl)
        rows.append(emit(
            f"comm.{arch}.fedadc_delta_downlink", 0,
            f"vs_raw_params={b/raw:.3f}x;naive={naive/raw:.2f}x;"
            f"le_1p1={b <= 1.1 * raw}"))
        unicast_rows(rows, arch, shapes)
    return rows


if __name__ == "__main__":
    main()
