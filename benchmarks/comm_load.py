"""Paper Sec. II-A (communication load): FedADC's uplink equals FedAvg's;
the downlink additionally carries the momentum/model-difference broadcast
(2× naive, 1× when Δ̄-broadcast overlaps compute as the paper proposes).

Analytic bytes/round per strategy for a chosen arch, plus the overlap
accounting — this is the paper's own table, made concrete per architecture.
"""
import jax

from benchmarks.common import emit
from repro.configs import ARCHS


def bytes_per_round(n_params, clients, dtype_bytes=4):
    p = n_params * dtype_bytes
    return {
        # uplink: every selected client pushes Δ_i
        "fedavg":        {"up": clients * p, "down": clients * p},
        "slowmo":        {"up": clients * p, "down": clients * p},
        # naive FedADC: pull θ_t AND m_t
        "fedadc_naive":  {"up": clients * p, "down": clients * 2 * p},
        # overlapped (paper): S_{t+1} pre-receives (θ_t, m_t) during round t
        # compute; at t+1 only Δ̄_t is pulled on the critical path
        "fedadc_overlap": {"up": clients * p, "down": clients * p},
    }


def main(rows=None):
    rows = rows if rows is not None else []
    for arch in ("qwen3-4b", "qwen3-14b"):
        n = ARCHS[arch].param_count()
        table = bytes_per_round(n, clients=4)
        base = table["fedavg"]["down"]
        for strat, t in table.items():
            rows.append(emit(
                f"comm.{arch}.{strat}", 0,
                f"up_GB={t['up']/2**30:.2f};down_GB={t['down']/2**30:.2f};"
                f"down_vs_fedavg={t['down']/base:.2f}x"))
    return rows


if __name__ == "__main__":
    main()
