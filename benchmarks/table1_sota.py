"""Table I: FedADC / FedADC+ vs SOTA FL baselines on two regimes
(sort-and-partition s=2, and Dirichlet α=0.3), C=0.2.  Miniaturised: 20
clients, 50 rounds, synthetic class-Gaussian images."""
from benchmarks.common import dataset, emit, partitions, run_fl

ROUNDS = 50
METHODS = [
    ("fedavg", dict(eta=0.05)),
    ("moon", dict(eta=0.05)),
    ("fedgkd", dict(eta=0.05)),
    ("fedntd", dict(eta=0.05)),
    ("feddyn", dict(eta=0.05, extra_fed={"feddyn_alpha": 0.01})),
    ("fedprox", dict(eta=0.05, extra_fed={"mu_prox": 0.01})),
    ("scaffold", dict(eta=0.05)),
    ("fedadc", dict(eta=0.01)),
    ("fedadc+", dict(eta=0.01)),
    ("fedrs", dict(eta=0.05)),          # sort-and-partition only (paper)
]


def main(rows=None):
    data = dataset()
    rows = rows if rows is not None else []
    results = {}
    for setting, kind, param in (("s2", "sort", 2), ("dir0.3", "dir", 0.3)):
        parts = partitions(data[1], 20, kind, param)
        for name, kw in METHODS:
            if name == "fedrs" and kind != "sort":
                continue                 # paper: FedRS needs missing classes
            strat = "fedadc" if name == "fedadc+" else name
            distill = name == "fedadc+"
            r = run_fl(strat, parts, data, rounds=ROUNDS, distill=distill,
                       **{k: v for k, v in kw.items() if k != "extra_fed"},
                       extra_fed=kw.get("extra_fed"))
            results[(setting, name)] = r["acc"]
            rows.append(emit(f"table1.{setting}.{name}", r["us_per_round"],
                             f"{r['acc']:.3f}"))
        ours = max(results[(setting, "fedadc")],
                   results[(setting, "fedadc+")])
        best_baseline = max(v for (st, n), v in results.items()
                            if st == setting and not n.startswith("fedadc"))
        rows.append(emit(f"table1.{setting}.ours_minus_best_baseline", 0,
                         f"{ours - best_baseline:+.3f}"))
    return rows


if __name__ == "__main__":
    main()
