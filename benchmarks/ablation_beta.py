"""Ablations on the paper's momentum-embedding knob (Sec. II: "by playing
with β_local it is possible to seek different strategies"; β_local =
β_global = β is the paper's default to keep the hyper-parameter count at
FedAvg's level).

(a) β sweep (the paper's grid) at s=2;
(b) β_local ∈ {0, β/2, β} with β_global = β fixed — β_local = 0 recovers
    pure SlowMo (momentum only at the server, no drift control), so the
    gap between β_local = 0 and β_local = β isolates the *drift-control*
    contribution of the momentum embedding from the *acceleration* one.
"""
from benchmarks.common import dataset, emit, partitions, run_fl

ROUNDS = 50


def main(rows=None):
    data = dataset()
    rows = rows if rows is not None else []
    parts = partitions(data[1], 20, "sort", 2)

    for beta in (0.6, 0.7, 0.8, 0.9):
        r = run_fl("fedadc", parts, data, rounds=ROUNDS, eta=0.01, beta=beta)
        rows.append(emit(f"ablation.beta{beta}", r["us_per_round"],
                         f"{r['acc']:.3f}"))

    accs = {}
    for frac, name in ((0.0, "0"), (0.5, "half"), (1.0, "full")):
        r = run_fl("fedadc", parts, data, rounds=ROUNDS, eta=0.01,
                   beta=0.7, extra_fed={"beta_local": 0.7 * frac,
                                        "beta_global": 0.7})
        accs[name] = r["acc"]
        rows.append(emit(f"ablation.beta_local_{name}", r["us_per_round"],
                         f"{r['acc']:.3f}"))
    rows.append(emit("ablation.drift_control_gain", 0,
                     f"{accs['full'] - accs['0']:+.3f} "
                     f"(beta_local=beta vs beta_local=0≡SlowMo)"))
    return rows


if __name__ == "__main__":
    main()
