"""Beyond-paper: the pod engine's FedADC vs FedAvg on federated LM
fine-tuning (domain-skewed Markov token streams, reduced qwen3-family
model) — evidence the momentum-embedding transfers from the paper's vision
tasks to the large-model regime the assigned architectures represent."""
import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_arch
from repro.configs.base import FedConfig, RunConfig
from repro.data.synthetic import make_token_dataset
from repro.launch.train import init_state, make_train_step

ROUNDS = 60


def run(strategy, eta, seed=0):
    base = get_arch("qwen3-4b").reduced()
    mcfg = replace(base, n_layers=2, d_model=256, d_ff=704, vocab_size=1024,
                   n_heads=4, n_kv_heads=2, head_dim=64)
    fed = FedConfig(strategy=strategy, local_steps=4, clients_per_round=4,
                    eta=eta, beta_global=0.7, beta_local=0.7)
    run_cfg = RunConfig(remat="none")
    seq = 64
    tokens, domains = make_token_dataset(512, seq + 1, mcfg.vocab_size,
                                         seed=0)
    clients = [np.where(domains == d)[0] for d in range(8)]
    held = tokens[:64]

    state = init_state(jax.random.PRNGKey(seed), mcfg, fed, run_cfg)
    step = jax.jit(make_train_step(mcfg, fed, run_cfg))
    rng = np.random.RandomState(seed)
    b = 2
    t0 = time.time()
    for r in range(ROUNDS):
        picks = rng.choice(len(clients), fed.clients_per_round, replace=False)
        bt = np.zeros((1, 4, 4, b, seq + 1), np.int32)
        for ci, c in enumerate(picks):
            sel = rng.choice(clients[c], (4, b))
            bt[0, ci] = tokens[sel]
        state, m = step(state, {"tokens": jnp.asarray(bt[..., :-1]),
                                "labels": jnp.asarray(bt[..., 1:])})
    # barrier + stop the clock BEFORE the eval trace, so the timed window
    # covers exactly the ROUNDS dispatched steps
    jax.block_until_ready(state["params"])
    us_per_round = (time.time() - t0) / ROUNDS * 1e6
    # held-out eval loss over all domains
    from repro.models.registry import get_model
    model = get_model(mcfg)
    ev = jax.jit(lambda p, batch: model.loss_fn(p, batch, mcfg)[0])
    loss = float(ev(state["params"],
                    {"tokens": jnp.asarray(held[:, :-1]),
                     "labels": jnp.asarray(held[:, 1:])}))
    return loss, us_per_round


def main(rows=None):
    rows = rows if rows is not None else []
    losses = {}
    for strat, eta in (("fedavg", 0.05), ("fedadc", 0.05)):
        loss, us = run(strat, eta)
        losses[strat] = loss
        rows.append(emit(f"lm_round.{strat}.heldout_loss", us, f"{loss:.4f}"))
    rows.append(emit("lm_round.fedadc_minus_fedavg", 0,
                     f"{losses['fedadc'] - losses['fedavg']:+.4f} "
                     f"(negative = FedADC better)"))
    return rows


if __name__ == "__main__":
    main()
