"""Serving bench: continuous batching vs serial one-at-a-time decode.

Offered-load sweep: the same request set (random prompt lengths, fixed
generation budget) is pushed through the ServingEngine at increasing slot
counts (concurrency = offered load, closed-loop: every request is queued
at t=0 and waits for a slot).  Reported per level: generated tokens/sec
and p50/p95 end-to-end request latency.  ``n_slots=1`` IS the serial
baseline — one request at a time through the identical prefill-chunk +
decode-step path — so the speedup column isolates the scheduler/batching
win from kernel effects.

Emits ``BENCH_serving.json`` and the repo-standard ``name,us_per_call,
derived`` CSV rows (middle column = wall-µs per generated token).

``--smoke`` runs the CI job: 8 requests through a 4-slot scheduler and
asserts greedy outputs are identical to the serial engine.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.base import ModelConfig
from repro.models.registry import get_model
from repro.serving import SchedulerConfig, ServingEngine, latency_summary

TINY = ModelConfig(arch_id="serving-bench-tiny", n_layers=2, d_model=128,
                   n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=512,
                   max_seq_len=512)
MAX_LEN = 128
GEN = 48


def make_requests(n, seed=0, lo=6, hi=17):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, TINY.vocab_size, rng.randint(lo, hi)).tolist()
            for _ in range(n)]


def run_level(params, prompts, n_slots, prefill_chunk=16):
    eng = ServingEngine(TINY, params=params, sched=SchedulerConfig(
        n_slots=n_slots, max_len=MAX_LEN, prefill_chunk=prefill_chunk,
        page_size=32))
    t0 = time.perf_counter()
    for p in prompts:
        eng.add_request(p, max_new_tokens=GEN)
    outs = eng.run()
    # barrier on the device-resident KV cache before stopping the clock
    jax.block_until_ready(eng.cachemgr.cache)
    wall = time.perf_counter() - t0
    tokens = sum(len(o.tokens) for o in outs)
    return {
        "n_slots": n_slots,
        "n_requests": len(prompts),
        "gen_tokens": tokens,
        "wall_s": round(wall, 3),
        "tokens_per_s": round(tokens / wall, 1),
        # TTFT/ITL/e2e percentiles from the shared telemetry helper — the
        # same summary the engine's telemetry `summary` event reports
        "latency": latency_summary(outs),
        "engine_steps": eng.n_steps,
    }, outs


def smoke(out_json="BENCH_serving_smoke.json"):
    """CI job: 8 requests through the 4-slot scheduler, greedy outputs
    bit-identical to the serial engine.  Emits a JSON of the deterministic
    counters (token/step counts, not wall-clock) so the bench-smoke gate
    can diff it against the committed copy."""
    model = get_model(TINY)
    params = model.init(jax.random.PRNGKey(0), TINY)
    prompts = make_requests(8)
    res_b, batched = run_level(params, prompts, n_slots=4)
    res_s, serial = run_level(params, prompts, n_slots=1)
    assert [o.tokens for o in batched] == [o.tokens for o in serial], \
        "batched greedy output diverged from serial"
    report = {
        "n_requests": len(prompts),
        "gen_tokens": res_b["gen_tokens"],
        "engine_steps_batched": res_b["engine_steps"],
        "engine_steps_serial": res_s["engine_steps"],
        "batched_equals_serial": True,
    }
    with open(out_json, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {out_json}")
    print(f"serving smoke OK: {len(prompts)} requests, "
          f"{sum(len(o.tokens) for o in batched)} tokens, "
          f"batched == serial")


def main(rows=None, n_requests=16, levels=(1, 2, 4, 8),
         out_json="BENCH_serving.json"):
    rows = rows if rows is not None else []
    model = get_model(TINY)
    params = model.init(jax.random.PRNGKey(0), TINY)
    prompts = make_requests(n_requests)
    results = []
    for n_slots in levels:
        run_level(params, prompts[:2], n_slots)      # warmup/compile
        res, _ = run_level(params, prompts, n_slots)
        results.append(res)
        us_per_tok = res["wall_s"] / res["gen_tokens"] * 1e6
        lat = res["latency"]
        rows.append(emit(f"serving.slots{n_slots}.tokens_per_s", us_per_tok,
                         res["tokens_per_s"]))
        rows.append(emit(f"serving.slots{n_slots}.p50_p95_s", us_per_tok,
                         f"{lat['e2e_s']['p50']}/{lat['e2e_s']['p95']}"))
        rows.append(emit(f"serving.slots{n_slots}.ttft_itl_p50_s", us_per_tok,
                         f"{lat['ttft_s']['p50']}/{lat['itl_s']['p50']}"))
    base = results[0]["tokens_per_s"]
    peak = results[-1]["tokens_per_s"]
    speedup = peak / base
    rows.append(emit("serving.batch_vs_serial_speedup", 0,
                     f"{speedup:.2f}x"))
    report = {"model": TINY.arch_id, "max_len": MAX_LEN, "gen": GEN,
              "levels": results, "speedup_vs_serial": round(speedup, 2)}
    with open(out_json, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {out_json}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI: 8 requests through the scheduler + identity "
                         "check vs serial")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--out", default=None,
                    help="JSON output path (default depends on mode)")
    args = ap.parse_args()
    if args.smoke:
        smoke(out_json=args.out or "BENCH_serving_smoke.json")
    else:
        main(n_requests=args.requests,
             out_json=args.out or "BENCH_serving.json")
