"""Fig. 2: FedADC convergence for s = 2,3,4 — robustness of the FINAL
accuracy to data skew (paper: similar final level, slower convergence for
smaller s), plus nesterov (red) vs heavy-ball (blue) variants."""
from benchmarks.common import dataset, emit, partitions, run_fl

ROUNDS = 60


def main(rows=None):
    data = dataset()
    rows = rows if rows is not None else []
    finals = {}
    for s in (2, 3, 4):
        parts = partitions(data[1], 20, "sort", s)
        r = run_fl("fedadc", parts, data, rounds=ROUNDS, eta=0.01,
                   eval_every=ROUNDS // 3)
        finals[s] = r["acc"]
        mid = r["hist"][0]["acc"]
        rows.append(emit(f"fig2.s{s}.final", r["us_per_round"],
                         f"{r['acc']:.3f}"))
        rows.append(emit(f"fig2.s{s}.early", 0, f"{mid:.3f}"))
    spread = max(finals.values()) - min(finals.values())
    rows.append(emit("fig2.final_acc_spread", 0, f"{spread:.3f}"))
    # red vs blue variants at s=2
    parts = partitions(data[1], 20, "sort", 2)
    for variant in ("nesterov", "heavyball"):
        r = run_fl("fedadc", parts, data, rounds=ROUNDS, eta=0.01,
                   extra_fed={"variant": variant})
        rows.append(emit(f"fig2.s2.{variant}", r["us_per_round"],
                         f"{r['acc']:.3f}"))
    return rows


if __name__ == "__main__":
    main()
