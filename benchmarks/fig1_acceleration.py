"""Fig. 1 (a-c): FedADC vs FedAvg vs SlowMo under sort-and-partition
non-iid data, s ∈ {2,3,4}.  Paper claim: FedADC ≥ SlowMo > FedAvg, gap
widening as s shrinks."""
from benchmarks.common import dataset, emit, partitions, run_fl

ROUNDS = 60


def main(rows=None):
    data = dataset()
    rows = rows if rows is not None else []
    for s in (2, 3, 4):
        parts = partitions(data[1], 20, "sort", s)
        accs = {}
        for strat, eta in (("fedavg", 0.05), ("slowmo", 0.01),
                           ("fedadc", 0.01)):
            r = run_fl(strat, parts, data, rounds=ROUNDS, eta=eta)
            accs[strat] = r["acc"]
            rows.append(emit(f"fig1.s{s}.{strat}", r["us_per_round"],
                             f"{r['acc']:.3f}"))
        gap = accs["fedadc"] - accs["fedavg"]
        rows.append(emit(f"fig1.s{s}.fedadc_minus_fedavg", 0, f"{gap:+.3f}"))
    return rows


if __name__ == "__main__":
    main()
