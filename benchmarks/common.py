"""Shared benchmark infrastructure.

The paper's experiments are CIFAR-10/100 with 100 clients × 500 rounds; on
this CPU container each benchmark runs a calibrated miniature (synthetic
class-Gaussian images, 20 clients, tens of rounds) that preserves the
qualitative orderings the paper reports.  Every benchmark prints
``name,us_per_call,derived`` CSV rows (us_per_call = wall-µs per
communication round; derived = the table's headline metric).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs.base import FedConfig, HeteroConfig
from repro.data.partition import dirichlet_partition, sort_and_partition
from repro.data.synthetic import make_image_dataset
from repro.federated.async_engine import AsyncFederatedSimulator
from repro.federated.simulator import FederatedSimulator, SimConfig

_DATA_CACHE: Dict = {}


def dataset(n_classes=10, image_size=16, n_train=3000, n_test=600, seed=0,
            noise=0.6):
    key = (n_classes, image_size, n_train, n_test, seed, noise)
    if key not in _DATA_CACHE:
        _DATA_CACHE[key] = make_image_dataset(n_train, n_test, n_classes,
                                              image_size=image_size,
                                              seed=seed, noise=noise)
    return _DATA_CACHE[key]


def partitions(y, n_clients, kind, param, seed=0):
    if kind == "sort":
        return sort_and_partition(y, n_clients, int(param), seed)
    return dirichlet_partition(y, n_clients, float(param), seed)


def run_fl(strategy, parts, data, *, rounds=60, n_clients=20,
           clients_per_round=4, local_steps=8, eta=0.02, beta=0.7,
           batch_size=32, selector="random", distill=False,
           n_classes=10, model="cnn", seed=0, eval_every=None,
           extra_fed=None, telemetry=None) -> Dict:
    x, y, xt, yt = data
    fed_kw = dict(strategy=strategy, local_steps=local_steps,
                  clients_per_round=clients_per_round, n_clients=n_clients,
                  eta=eta, beta_global=beta, beta_local=beta,
                  distill=distill)
    if extra_fed:
        fed_kw.update(extra_fed)
    fed = FedConfig(**fed_kw)
    # explicit None-check: eval_every=0 must not silently become `rounds`
    sim = SimConfig(model=model, n_classes=n_classes, batch_size=batch_size,
                    rounds=rounds,
                    eval_every=rounds if eval_every is None else eval_every,
                    cnn_width=8, selector=selector, seed=seed)
    s = FederatedSimulator(fed, sim, x, y, xt, yt, parts,
                           telemetry=telemetry)
    t0 = time.time()
    hist = s.run()
    jax.block_until_ready(s.params)  # barrier before stopping the clock
    wall = time.time() - t0
    return {"acc": hist[-1]["acc"], "loss": hist[-1]["loss"],
            "us_per_round": wall / rounds * 1e6, "hist": hist, "sim": s}


def run_fl_async(strategy, parts, data, *, hetero: HeteroConfig, rounds=60,
                 n_clients=20, clients_per_round=4, local_steps=8, eta=0.02,
                 beta=0.7, batch_size=32, n_classes=10, model="cnn", seed=0,
                 extra_fed=None, telemetry=None) -> Dict:
    """run_fl's semi-async twin: the virtual-clock engine under a
    heterogeneous fleet, with the same calibrated miniature."""
    x, y, xt, yt = data
    fed_kw = dict(strategy=strategy, local_steps=local_steps,
                  clients_per_round=clients_per_round, n_clients=n_clients,
                  eta=eta, beta_global=beta, beta_local=beta)
    if extra_fed:
        fed_kw.update(extra_fed)
    fed = FedConfig(**fed_kw)
    sim = SimConfig(model=model, n_classes=n_classes, batch_size=batch_size,
                    rounds=rounds, eval_every=rounds, cnn_width=8, seed=seed)
    s = AsyncFederatedSimulator(fed, sim, hetero, x, y, xt, yt, parts,
                                telemetry=telemetry)
    t0 = time.time()
    hist = s.run()
    jax.block_until_ready(s.params)  # barrier before stopping the clock
    wall = time.time() - t0
    return {"acc": hist[-1]["acc"], "loss": hist[-1]["loss"],
            "us_per_round": wall / rounds * 1e6, "hist": hist, "sim": s}


def emit(name: str, us: float, derived) -> str:
    row = f"{name},{us:.0f},{derived}"
    print(row, flush=True)
    return row
