"""Bench-smoke regression gate (CI).

Compares a freshly emitted ``BENCH_*.json`` against the committed copy:
every numeric field reachable at the same path must agree within a relative
tolerance (default 20%), and booleans/strings must match exactly.  Wall-
clock-derived fields (runner-speed dependent) are skipped by key pattern so
the gate checks *what* the benchmark measured, not how fast the runner was.

The tolerance is relative with an absolute floor (``--atol``): derived
difference-of-large-numbers fields (e.g. an accuracy *gap* of 0.0017) must
not be gated orders of magnitude tighter than the quantities they were
computed from.

``--require`` names dotted paths (e.g. ``headline.downlink_measured``,
``async_cells``, ``drift.fedadc_none``) that must exist and be
truthy/non-empty in the FRESH output of every compared pair — the walk
itself is committed-driven, so this is how the gate pins *new* sections a
refactor promised (a fresh file that silently stopped emitting them would
otherwise still pass).  Everything *under* a required path is additionally
checked to be well-formed — numeric leaves must be finite (a drift metric
that collapsed to NaN/inf is a regression even though NaN != NaN would
slip through an equality diff) — while wall-clock keys inside the section
stay skipped.

Usage:  python benchmarks/check_regression.py fresh.json:committed.json \\
            [--tol 0.2] [--atol 0.01] [--require path ...]
Exit code 1 on any violation, with a per-path report.
"""
from __future__ import annotations

import argparse
import json
import math
import re
import sys

# runner-speed dependent fields; excluded from the gate
SKIP_KEY = re.compile(
    r"(wall|latency|per_s|per_round|per_tok|us_|_us|speedup|time)", re.I)


def _walk(fresh, committed, path, tol, atol, errors):
    if isinstance(committed, dict):
        if not isinstance(fresh, dict):
            errors.append(f"{path}: type changed ({type(fresh).__name__})")
            return
        for k, cv in committed.items():
            if SKIP_KEY.search(str(k)):
                continue
            if k not in fresh:
                errors.append(f"{path}.{k}: missing from fresh output")
                continue
            _walk(fresh[k], cv, f"{path}.{k}", tol, atol, errors)
    elif isinstance(committed, list):
        if not isinstance(fresh, list) or len(fresh) != len(committed):
            errors.append(f"{path}: list length {len(fresh) if isinstance(fresh, list) else '?'} "
                          f"!= {len(committed)}")
            return
        for i, (fv, cv) in enumerate(zip(fresh, committed)):
            _walk(fv, cv, f"{path}[{i}]", tol, atol, errors)
    elif isinstance(committed, bool):
        if fresh is not committed:
            errors.append(f"{path}: {fresh!r} != committed {committed!r}")
    elif isinstance(committed, (int, float)):
        if not isinstance(fresh, (int, float)):
            errors.append(f"{path}: non-numeric {fresh!r}")
        else:
            bound = max(tol * abs(committed), atol)
            diff = abs(fresh - committed)
            if diff > bound:
                errors.append(f"{path}: {fresh} vs committed {committed} "
                              f"(|diff| {diff:.4g} > {bound:.4g})")
    else:
        if fresh != committed:
            errors.append(f"{path}: {fresh!r} != committed {committed!r}")


def _check_finite(node, path, errors):
    """Numeric leaves under a required section must be finite; wall-clock
    keys are skipped exactly as in the committed-driven walk."""
    if isinstance(node, dict):
        for k, v in node.items():
            if SKIP_KEY.search(str(k)):
                continue
            _check_finite(v, f"{path}.{k}", errors)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            _check_finite(v, f"{path}[{i}]", errors)
    elif isinstance(node, float) and not math.isfinite(node):
        errors.append(f"required path {path}: non-finite value {node!r}")


def _check_required(fresh, paths, errors):
    for dotted in paths:
        node = fresh
        ok = True
        for part in dotted.split("."):
            if isinstance(node, dict) and part in node:
                node = node[part]
            else:
                ok = False
                break
        if not ok:
            errors.append(f"required path {dotted!r} missing from fresh "
                          f"output")
        elif isinstance(node, (list, dict)) and not node:
            errors.append(f"required path {dotted!r} is empty")
        elif node is False or node is None:
            errors.append(f"required path {dotted!r} is {node!r}")
        else:
            _check_finite(node, dotted, errors)


def compare(fresh_path: str, committed_path: str, tol: float = 0.2,
            atol: float = 0.01, require=()):
    with open(fresh_path) as f:
        fresh = json.load(f)
    with open(committed_path) as f:
        committed = json.load(f)
    errors: list = []
    _walk(fresh, committed, "$", tol, atol, errors)
    _check_required(fresh, require, errors)
    return errors


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("pairs", nargs="+",
                    help="fresh.json:committed.json pairs")
    ap.add_argument("--tol", type=float, default=0.2)
    ap.add_argument("--atol", type=float, default=0.01)
    ap.add_argument("--require", nargs="*", default=[],
                    help="dotted paths that must exist (truthy/non-empty) "
                         "in every fresh output")
    args = ap.parse_args()
    failed = False
    for pair in args.pairs:
        fresh, committed = pair.split(":")
        errors = compare(fresh, committed, args.tol, args.atol,
                         args.require)
        if errors:
            failed = True
            print(f"REGRESSION {fresh} vs {committed}:")
            for e in errors:
                print(f"  {e}")
        else:
            print(f"OK {fresh} vs {committed} (tol {args.tol:.0%})")
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
